"""Launch-layer integration: step bundles lower+compile on a local mesh,
trainer checkpoints and resumes, serve decodes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import SMOKE_MESH, ShapeConfig, TrainConfig
from repro.launch.mesh import make_smoke_mesh
from repro.launch.step_builders import bundle_for


pytestmark = pytest.mark.slow  # minutes-long; PR CI runs -m 'not slow'


@pytest.mark.parametrize("arch,kind", [
    ("qwen3-8b", "train"), ("qwen3-8b", "decode"),
    ("granite-moe-1b-a400m", "train"), ("zamba2-1.2b", "decode"),
    ("hubert-xlarge", "prefill"),
])
def test_bundle_lowers_and_compiles(arch, kind):
    cfg = smoke_config(arch)
    mesh = make_smoke_mesh()
    shape = ShapeConfig(name="t", seq_len=32,
                        global_batch=4, kind=kind)
    b = bundle_for(kind, cfg, shape, mesh, SMOKE_MESH,
                   TrainConfig(microbatches=2 if kind == "train" else 1))
    with mesh:
        compiled = jax.jit(b.fn, in_shardings=b.in_shardings,
                           out_shardings=b.out_shardings
                           ).lower(*b.in_specs).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    ca = compiled.cost_analysis()
    assert ca is not None


def test_train_step_executes_and_learns():
    from repro.data import lm_batch_iterator
    from repro.optim.optimizers import adamw_init

    cfg = smoke_config("granite-3-8b")
    mesh = make_smoke_mesh()
    shape = ShapeConfig(name="t", seq_len=32, global_batch=4, kind="train")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=30)
    b = bundle_for("train", cfg, shape, mesh, SMOKE_MESH, tcfg)
    params, _ = b.model.init(jax.random.key(0))
    opt = adamw_init(params, tcfg)
    fn = jax.jit(b.fn)
    it = lm_batch_iterator(0, 4, 32, cfg.vocab_size)
    losses = []
    with mesh:
        for step in range(30):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, m = fn(params, opt, batch, jnp.int32(step))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses[-1])


def test_trainer_checkpoint_restart(tmp_path):
    """Kill/restart semantics: second invocation resumes from step 10."""
    from repro.launch.train import main as train_main

    d = str(tmp_path / "ck")
    rc = train_main(["--arch", "granite-moe-1b-a400m", "--steps", "10",
                     "--batch", "2", "--seq", "16", "--ckpt-dir", d,
                     "--ckpt-every", "5"])
    assert rc in (0, 1)  # 10 steps may not strictly reduce a MoE loss
    from repro.checkpoint.ckpt import list_steps
    assert list_steps(d), "no checkpoint written"
    # resume and continue to 14
    rc = train_main(["--arch", "granite-moe-1b-a400m", "--steps", "14",
                     "--batch", "2", "--seq", "16", "--ckpt-dir", d,
                     "--ckpt-every", "5"])
    assert rc in (0, 1)  # short continuation may not strictly reduce loss
    assert max(list_steps(d)) >= 10


def test_fl_round_bundle_on_pod_mesh():
    """The paper-technique step lowers when a pod axis exists (uses the
    2-device CPU mesh via axis sizes (2,1,1))."""
    import dataclasses
    from repro.configs.base import MeshConfig
    if jax.device_count() < 2:
        mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        mcfg = MeshConfig(shape=(1, 1, 1),
                          axis_names=("pod", "data", "model"))
        n_pods = 1
    else:
        mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
        mcfg = MeshConfig(shape=(2, 1, 1),
                          axis_names=("pod", "data", "model"))
        n_pods = 2
    cfg = smoke_config("qwen3-8b")
    shape = ShapeConfig(name="t", seq_len=16, global_batch=2 * n_pods,
                        kind="train")
    tcfg = TrainConfig(crosspod_compression="int8")
    b = bundle_for("fl_round", cfg, shape, mesh, mcfg, tcfg, local_steps=2)
    with mesh:
        compiled = jax.jit(b.fn, in_shardings=b.in_shardings,
                           out_shardings=b.out_shardings
                           ).lower(*b.in_specs).compile()
    assert compiled is not None
