"""Scenario layer: spec round-trips, validation messages, preset
bit-for-bit equivalence with the legacy ``*_env`` constructors, graph
presets, and fl_train's --scenario/flag precedence."""
import dataclasses
import json
import random

import pytest

from repro.core import Fabric, FLMessage, ObjectStore, VirtualPayload, \
    make_backend
from repro.core.netsim import (NCAL, Environment, geo_distributed_env,
                               geo_proximal_env, lan_env)
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import (TOPOLOGY_PRESETS, ChannelSpec, EdgeSpec,
                            FaultSpec, FleetSpec, Scenario, ScenarioError,
                            StrategySpec, TopologySpec, build_runtime,
                            with_overrides)

LEGACY = {"lan": lan_env, "geo_proximal": geo_proximal_env,
          "geo_distributed": geo_distributed_env}


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------

def _preset_scenarios():
    for kind in TOPOLOGY_PRESETS:
        yield Scenario(name=f"rt:{kind}",
                       topology=TopologySpec.preset(kind, num_clients=9))


def test_roundtrip_every_preset():
    for s in _preset_scenarios():
        assert Scenario.from_dict(s.to_dict()) == s
        assert Scenario.from_json(s.to_json()) == s


def _random_scenario(rng: random.Random) -> Scenario:
    kind = rng.choice(TOPOLOGY_PRESETS)
    n = rng.randint(1, 20)
    edges = tuple(
        EdgeSpec(src=f"client{rng.randrange(n)}", dst="server",
                 bw_single_mb=rng.uniform(1, 500),
                 bw_multi_mb=rng.uniform(500, 3000),
                 latency_ms=rng.uniform(0.1, 200),
                 max_conns=rng.choice([0, 4, 16]),
                 symmetric=rng.random() < 0.5)
        for _ in range(rng.randrange(3)))
    return Scenario(
        name=f"rand{rng.randrange(1000)}", seed=rng.randrange(100),
        topology=TopologySpec(kind=kind, num_clients=n, edges=edges),
        fleet=FleetSpec(tier=rng.choice(["small", "big"]),
                        local_steps=rng.randint(1, 8)),
        channel=ChannelSpec(backend=rng.choice(["grpc", "grpc+s3", "auto"]),
                            compression=rng.choice(["none", "qsgd",
                                                    "topk:0.1"]),
                            wire_codec=rng.choice(["none", "zlib",
                                                   "zlib:9"]),
                            chunk_mb=rng.choice([0.0, 4.0])),
        faults=FaultSpec(link_loss=rng.choice([0.0, 0.1]),
                         nack_rtts=rng.choice([1.0, 2.0])),
        strategy=StrategySpec(mode=rng.choice(["sync", "fedbuff", "hier"]),
                              rounds=rng.randint(1, 9),
                              buffer_k=rng.randrange(5)))


def test_roundtrip_randomized_specs():
    rng = random.Random(7)
    for _ in range(25):
        s = _random_scenario(rng)
        assert Scenario.from_dict(s.to_dict()) == s
        # and through an actual JSON wire (tuples -> lists -> tuples)
        assert Scenario.from_dict(json.loads(json.dumps(s.to_dict()))) == s


# ---------------------------------------------------------------------------
# validation: readable failures
# ---------------------------------------------------------------------------

def test_unknown_key_raises_with_path():
    d = Scenario().to_dict()
    d["topology"]["bandwith"] = 3
    with pytest.raises(ScenarioError, match=r"scenario\.topology.*bandwith"):
        Scenario.from_dict(d)


def test_unknown_toplevel_key_lists_valid_keys():
    with pytest.raises(ScenarioError, match="unknown key.*topologyy"):
        Scenario.from_dict({"topologyy": {}})


def test_unknown_edge_key_names_the_edge_index():
    d = Scenario().to_dict()
    d["topology"]["edges"] = [{"src": "client0", "dst": "server",
                               "bw_single_mb": 1, "bw_multi_mb": 2,
                               "latency_ms": 1, "colour": "red"}]
    with pytest.raises(ScenarioError, match=r"edges\[0\].*colour"):
        Scenario.from_dict(d)


def test_invalid_edge_endpoint_raises():
    spec = TopologySpec(kind="star", num_clients=2, edges=(
        EdgeSpec("client9", "server", 10, 100, 5),))
    with pytest.raises(ScenarioError, match="client9.*names no host"):
        spec.build()


def test_nonpositive_edge_bandwidth_raises():
    spec = TopologySpec(num_clients=2, edges=(
        EdgeSpec("client0", "server", 0, 100, 5),))
    with pytest.raises(ScenarioError, match="positive"):
        spec.build()


def test_bad_preset_and_mode_and_loss():
    with pytest.raises(ScenarioError, match="unknown preset"):
        TopologySpec(kind="mesh").build()
    with pytest.raises(ScenarioError, match="strategy.mode"):
        Scenario(strategy=StrategySpec(mode="chaotic")).validate()
    with pytest.raises(ScenarioError, match="link_loss"):
        Scenario(faults=FaultSpec(link_loss=1.0)).validate()
    with pytest.raises(ScenarioError, match="channel.compression"):
        Scenario(channel=ChannelSpec(compression="gzip")).validate()


# ---------------------------------------------------------------------------
# preset envs == legacy constructors, bit for bit
# ---------------------------------------------------------------------------

def test_preset_hosts_match_legacy_envs():
    for name, legacy in LEGACY.items():
        for n in (4, 7, 14):
            built = TopologySpec.preset(name, num_clients=n).build()
            ref = legacy(n)
            assert built.name == ref.name
            assert built.server == ref.server
            assert built.clients == ref.clients
            assert built.trusted == ref.trusted
            assert built.has_object_store == ref.has_object_store


def _legacy_graphless(env: Environment) -> Environment:
    """The same hosts with no link graph: link() falls back to the
    historical implicit rule — the pre-scenario timing reference."""
    return dataclasses.replace(env, links=None)


def _fig2_trace(env, backend):
    """Fig-2-style concurrent broadcast timing over one WAN link."""
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    be = make_backend(backend, env, fabric, "server", store=store)
    msgs = [FLMessage("m", "server", env.clients[-1].host_id,
                      payload=VirtualPayload(64 << 20, tag=f"c{i}"))
            for i in range(8)]
    done, arrives = be.broadcast(msgs, 0.0)
    return (done, tuple(arrives))


def _fig5_trace(env, backend):
    """Fig-5-style full synchronous round timing."""
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    clients = [FLClient(h.host_id,
                        make_backend(backend, env, fabric, h.host_id,
                                     store=store), sim_train_s=20.0)
               for h in env.clients]
    server = FLServer(make_backend(backend, env, fabric, "server",
                                   store=store), clients, local_steps=1,
                      live=False)
    rep = server.run_round(VirtualPayload(128 << 20, tag="r0"))
    return (rep.round_time, tuple(sorted(rep.server.items())))


def _fig6_trace(env, backend):
    """Fig-6-style event-driven run: the full loop trace."""
    from repro.fl.async_strategies import FedBuffStrategy
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    clients = [FLClient(h.host_id,
                        make_backend(backend, env, fabric, h.host_id,
                                     store=store), sim_train_s=30.0)
               for h in env.clients]
    sched = FLScheduler(make_backend(backend, env, fabric, "server",
                                     store=store), clients,
                        FedBuffStrategy(buffer_k=3,
                                        staleness_exponent=0.5),
                        local_steps=1)
    sched.run(VirtualPayload(32 << 20, tag="t"), max_aggregations=4)
    return tuple(sched.loop.trace)


@pytest.mark.parametrize("env_name,backend", [
    ("geo_distributed", "grpc"), ("geo_distributed", "grpc+s3"),
    ("geo_proximal", "grpc"), ("lan", "mpi_generic"),
    ("lan", "mpi_mem_buff"),
])
def test_preset_graph_traces_bit_for_bit(env_name, backend):
    """The explicit graph must reproduce the implicit region-pair rule
    exactly on fig2/5/6-style workloads (same floats, same event order)."""
    built = TopologySpec.preset(env_name, num_clients=7).build()
    legacy = _legacy_graphless(built)
    assert built.links and legacy.links is None
    for tracer in (_fig2_trace, _fig5_trace, _fig6_trace):
        assert tracer(built, backend) == tracer(legacy, backend), \
            f"{tracer.__name__} diverged for {env_name}/{backend}"


def test_make_env_is_the_preset_shim():
    from repro.core.netsim import make_env
    with pytest.warns(DeprecationWarning, match="TopologySpec.preset"):
        env = make_env("geo_distributed", 5)
    assert env.links  # graph-built
    assert env == TopologySpec.preset("geo_distributed", 5).build()


# ---------------------------------------------------------------------------
# graph presets + explicit edges
# ---------------------------------------------------------------------------

def test_star_graph_is_hub_and_spoke():
    env = TopologySpec.preset("star", 6).build()
    assert len(env.links) == 2 * 6  # hub<->client only
    assert all("server" in (a, b) for a, b in env.links)


def test_ring_graph_has_bottleneck_client_edges():
    env = TopologySpec.preset("ring", 14).build()
    e = env.link("client0", "client1")  # ncal ~ oregon
    r0, r1 = env.clients[0].region, env.clients[1].region
    assert e.region.bw_single == min(r0.bw_single, r1.bw_single)
    assert e.region.latency == r0.latency + r1.latency


def test_multi_hub_graph_has_intra_region_dc_edges():
    env = TopologySpec.preset("multi_hub", 14).build()
    # clients 0 and 7 share ncal (round-robin over 7 regions)
    assert env.link("client0", "client7").region.name == "lan_tcp"
    # cross-region pairs fall back to the WAN rule
    assert env.link("client0", "client1").region.name == "oregon"


def test_edge_spec_overrides_preset_link_and_caps_conns():
    spec = TopologySpec(kind="geo_distributed", num_clients=3, edges=(
        EdgeSpec("client2", "server", bw_single_mb=10, bw_multi_mb=1000,
                 latency_ms=50, max_conns=4),))
    env = spec.build()
    e = env.link("client2", "server")
    assert e.region.latency == pytest.approx(50e-3)
    # max_conns folds into the saturation bandwidth
    assert e.region.bw_multi == pytest.approx(4 * 10 * 1024 ** 2)
    # symmetric by default
    assert env.link("server", "client2").region is e.region
    # untouched edges keep the preset rule
    assert env.link("client1", "server").region.name == "oregon"


def test_asymmetric_edge_shorthand_roundtrip():
    s = Scenario(name="asym", topology=TopologySpec(
        num_clients=2, edges=(
            EdgeSpec("client0", "server", 100, 1000, 10,
                     rev_bw_single_mb=5, rev_bw_multi_mb=50,
                     rev_latency_ms=80),)))
    assert Scenario.from_dict(json.loads(json.dumps(s.to_dict()))) == s


def test_asymmetric_edge_builds_directed_pair():
    env = TopologySpec(num_clients=2, edges=(
        EdgeSpec("client0", "server", 100, 1000, 10,
                 rev_bw_single_mb=5, rev_latency_ms=80),)).build()
    fwd = env.link("client0", "server")
    rev = env.link("server", "client0")
    assert fwd.region.bw_single == 100 * 1024 ** 2
    assert fwd.region.latency == pytest.approx(10e-3)
    assert rev.region.bw_single == 5 * 1024 ** 2
    assert rev.region.latency == pytest.approx(80e-3)
    # unset rev components inherit the forward values
    assert rev.region.bw_multi == fwd.region.bw_multi


def test_asymmetric_edge_rejects_symmetric_false():
    spec = TopologySpec(num_clients=2, edges=(
        EdgeSpec("client0", "server", 100, 1000, 10, symmetric=False,
                 rev_bw_single_mb=5),))
    with pytest.raises(ScenarioError, match="directed-pair"):
        spec.check()


def test_asymmetric_edge_rejects_lone_negative_rev_bandwidth():
    """A typo'd negative rev_* must error, not silently fall back to a
    symmetric edge (asymmetric-intent detection uses != 0, not > 0)."""
    spec = TopologySpec(num_clients=2, edges=(
        EdgeSpec("client0", "server", 100, 1000, 10,
                 rev_bw_single_mb=-5),))
    with pytest.raises(ScenarioError, match="rev_.*positive"):
        spec.check()


def test_backend_consumes_asymmetric_edge():
    """The declared thin uplink must actually slow sends one way only."""
    rt = build_runtime(Scenario(
        name="asym", channel=ChannelSpec(backend="grpc"),
        topology=TopologySpec(num_clients=2, edges=(
            EdgeSpec("client0", "server", bw_single_mb=200,
                     bw_multi_mb=2000, latency_ms=5,
                     rev_bw_single_mb=2, rev_bw_multi_mb=20),))))
    msg_up = FLMessage("m", "client0", "server",
                       payload=VirtualPayload(16 << 20, tag="u"))
    msg_dn = FLMessage("m", "server", "client0",
                       payload=VirtualPayload(16 << 20, tag="d"))
    t_up = rt.make_backend("client0").isend(msg_up, 0.0).arrive
    t_dn = rt.make_backend("server").isend(msg_dn, 0.0).arrive
    assert t_dn > 10 * t_up  # the reverse leg is ~100x thinner


def test_backend_consumes_custom_edge():
    """A declared slow edge must actually slow that backend's sends."""
    fast = build_runtime(Scenario(name="fast"))
    slow = build_runtime(Scenario(name="slow", topology=TopologySpec(
        edges=(EdgeSpec("client0", "server", bw_single_mb=1,
                        bw_multi_mb=2, latency_ms=500),))))
    msg = FLMessage("m", "server", "client0",
                    payload=VirtualPayload(8 << 20, tag="x"))
    t_fast = fast.make_backend("server").isend(msg, 0.0).arrive
    t_slow = slow.make_backend("server").isend(
        dataclasses.replace(msg), 0.0).arrive
    assert t_slow > 10 * t_fast


# ---------------------------------------------------------------------------
# fl_train: --scenario + override precedence
# ---------------------------------------------------------------------------

def _resolve(tmp_path, spec_dict, argv):
    from repro.launch.fl_train import _parser, resolve_scenario
    path = tmp_path / "sc.json"
    path.write_text(json.dumps(spec_dict))
    ap = _parser()
    return resolve_scenario(ap.parse_args(["--scenario", str(path)] + argv),
                            ap)


def test_fl_train_flag_overrides_scenario(tmp_path):
    spec = {"name": "t", "topology": {"kind": "multi_hub",
                                      "num_clients": 6},
            "channel": {"backend": "grpc", "chunk_mb": 4.0},
            "strategy": {"mode": "hier", "rounds": 9}}
    # unset flags: the spec wins
    sc = _resolve(tmp_path, spec, [])
    assert sc.topology.kind == "multi_hub" and sc.strategy.rounds == 9
    assert sc.channel.chunk_mb == 4.0
    # set flags: the flag wins, everything else stays from the spec
    sc = _resolve(tmp_path, spec, ["--rounds", "2", "--backend", "grpc+s3"])
    assert sc.strategy.rounds == 2
    assert sc.channel.backend == "grpc+s3"
    assert sc.topology.kind == "multi_hub"
    assert sc.channel.chunk_mb == 4.0


def test_fl_train_wire_domain_compression_routes_to_wire_codec(tmp_path):
    sc = _resolve(tmp_path, {"name": "t"}, ["--compression", "zlib:9"])
    assert sc.channel.wire_codec == "zlib:9"
    assert sc.channel.compression == "none"


def test_fl_train_rejects_bad_scenario(tmp_path):
    from repro.launch.fl_train import _parser, resolve_scenario
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"strategy": {"mode": "chaotic"}}))
    ap = _parser()
    with pytest.raises(SystemExit):
        resolve_scenario(ap.parse_args(["--scenario", str(path)]), ap)


def test_with_overrides_skips_none_and_rejects_unknown():
    sc = Scenario()
    assert with_overrides(sc, {"channel.backend": None}) == sc
    out = with_overrides(sc, {"faults.link_loss": 0.2})
    assert out.faults.link_loss == 0.2 and sc.faults.link_loss == 0.0
    with pytest.raises(ScenarioError, match="not a field"):
        with_overrides(sc, {"channel.nope": 1})


def test_relay_conns_reaches_the_strategy_through_fl_config():
    from repro.fl import make_strategy
    sc = Scenario(strategy=StrategySpec(mode="hier", relay_conns=32))
    assert make_strategy(sc.fl_config()).relay_conns == 32


def test_two_different_wire_codecs_rejected_at_validate():
    sc = Scenario(channel=ChannelSpec(compression="zlib:1",
                                      wire_codec="zlib:9"))
    with pytest.raises(ScenarioError, match="two wire codecs"):
        sc.validate()


def test_runtime_builds_fault_model_from_spec():
    rt = build_runtime(Scenario(name="f", seed=3,
                                faults=FaultSpec(link_loss=0.1,
                                                 max_retries=7,
                                                 nack_rtts=2.0)))
    fm = rt.fabric.fault_model
    assert fm is not None and fm.chunk_loss_rate == 0.1
    assert fm.max_retries == 7 and fm.nack_rtts == 2.0 and fm.seed == 3
    assert build_runtime(Scenario(name="c")).fabric.fault_model is None
