"""Non-blocking send path: isend handles, the serializer busy-line, and
the regression guarantee that the legacy blocking semantics (send /
sequential_broadcast — the Fig 4b baseline) are arithmetically unchanged
now that they share the isend completion path.
"""
import pytest

from repro.core import (Fabric, FLMessage, ObjectStore, VirtualPayload,
                        make_backend)
from repro.scenario import TopologySpec
from repro.core.netsim import MB, NCAL

NBYTES = 50 * MB


@pytest.fixture
def deployment():
    env = TopologySpec.preset("geo_distributed", num_clients=7).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return env, fabric, store


def _msg(dst, nbytes=NBYTES, tag="m"):
    return FLMessage("model_sync", "server", dst,
                     payload=VirtualPayload(nbytes, tag=tag))


def _legacy_send_times(be, dst, nbytes, now):
    """The pre-isend blocking formula, written out by hand."""
    ser_t = be.serializer.ser_time(nbytes)
    region = be._link_region(dst)
    start = now + ser_t
    arrive = (start + be._overhead(region) + region.latency
              + nbytes / region.conn_cap(be.policy.conns_per_transfer))
    return start, arrive


@pytest.mark.parametrize("backend", ["grpc", "mpi_generic", "mpi_mem_buff",
                                     "torch_rpc"])
def test_send_preserves_legacy_blocking_arithmetic(backend, deployment):
    env, fabric, store = deployment
    be = make_backend(backend, env, fabric, "server", store=store)
    start, arrive = be.send(_msg("client3"), 7.0)
    exp_start, exp_arrive = _legacy_send_times(be, "client3", NBYTES, 7.0)
    assert start == pytest.approx(exp_start, rel=1e-12)
    assert arrive == pytest.approx(exp_arrive, rel=1e-12)


def test_sequential_broadcast_chains_on_completion(deployment):
    """Fig 4b baseline: send i+1 is issued only when send i has arrived."""
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store)
    msgs = [_msg(c.host_id, tag=f"s{i}") for i, c in enumerate(env.clients)]
    done, arrives = be.sequential_broadcast(msgs, 0.0)
    t = 0.0
    for m in msgs:
        _, t = _legacy_send_times(be, m.receiver, NBYTES, t)
    assert done == pytest.approx(t, rel=1e-12)
    assert arrives == sorted(arrives)  # strictly chained
    assert done == arrives[-1]


def test_grpc_s3_send_preserves_legacy_path(deployment):
    env, fabric, store = deployment
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    msg = _msg("client3")
    h = be.isend(msg, 0.0)
    # sender-side completion = serialize + multipart PUT
    src = env.host("server")
    ser_t = be.serializer.ser_time(NBYTES)
    assert h.start == pytest.approx(
        ser_t + store.put_time(NBYTES, src, be.parts), rel=1e-12)
    # receiver availability = metadata hop + multipart GET after the PUT
    region = be._link_region("client3")
    dst = env.host("client3")
    exp_arrive = (h.start + be._meta_duration(region)
                  + store.get_time(NBYTES, dst, be.parts))
    assert h.arrive == pytest.approx(exp_arrive, rel=1e-12)
    assert h.inbox_t < h.arrive  # metadata lands before the payload GET
    s2 = make_backend("grpc+s3", env, fabric, "server", store=store)
    start, arrive = s2.send(_msg("client3", tag="again"), 0.0)
    assert (start, arrive) == (pytest.approx(h.start), pytest.approx(h.arrive))


def test_isend_queues_on_serializer_busy_line(deployment):
    """Overlapping isends on a copy serializer (grpc: ser_parallel=False)
    serialize one after another; zero-copy backends start in parallel."""
    env, fabric, store = deployment
    grpc = make_backend("grpc", env, fabric, "server", store=store)
    ser_t = grpc.serializer.ser_time(NBYTES)
    h1 = grpc.isend(_msg("client1", tag="a"), 0.0)
    h2 = grpc.isend(_msg("client2", tag="b"), 0.0)
    assert h1.start == pytest.approx(ser_t, rel=1e-12)
    assert h2.start == pytest.approx(2 * ser_t, rel=1e-12)  # queued

    rpc = make_backend("torch_rpc", env, fabric, "server", store=store)
    r1 = rpc.isend(_msg("client1", tag="c"), 0.0)
    r2 = rpc.isend(_msg("client2", tag="d"), 0.0)
    assert r1.start == pytest.approx(r2.start, rel=1e-12)  # parallel ser


def test_isend_handle_done_and_next_arrival(deployment):
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store)
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    h = be.isend(_msg("client2"), 0.0)
    assert not h.done(h.arrive / 2) and h.done(h.arrive)
    assert cl.next_arrival() == pytest.approx(h.inbox_t)
    assert cl.next_arrival(after=h.inbox_t) is None  # strictly-after peek
    got = cl.recv(h.arrive + 1.0)
    assert len(got) == 1
    assert cl.next_arrival() is None  # drained


def test_auto_backend_isend_routes_and_peeks(deployment):
    env, fabric, store = deployment
    be = make_backend("auto", env, fabric, "server", store=store)
    cl = make_backend("auto", env, fabric, "client1", store=store)
    h_small = be.isend(_msg("client1", nbytes=1 * MB, tag="sm"), 0.0)
    h_large = be.isend(_msg("client1", nbytes=200 * MB, tag="lg"), 0.0)
    assert h_large.inbox_t < h_large.arrive  # rode S3: meta then GET
    assert h_small.inbox_t == h_small.arrive  # rode plain gRPC
    assert cl.next_arrival() == pytest.approx(
        min(h_small.inbox_t, h_large.inbox_t))
