"""ChannelStack: default-stack equivalence, compression stages with error
feedback, chunked pipelining, provenance-driven decode, and the
MemoryMeter time-sorted peak."""
import numpy as np
import pytest

from repro.compression.stages import QsgdCodec, TopkCodec, make_codec
from repro.core import (Fabric, FLMessage, MemoryMeter, ObjectStore,
                        TensorPayload, VirtualPayload, make_backend)
from repro.scenario import TopologySpec
from repro.core.channel import (ChunkStage, CompressStage, SerializeStage,
                                make_channel)
from repro.core.netsim import MB, NCAL
from repro.core.serialization import SERIALIZERS, checksum


@pytest.fixture
def tree(rng):
    return {"w": rng.normal(size=(64, 32)).astype(np.float32),
            "b": rng.normal(size=(32,)).astype(np.float32)}


@pytest.fixture
def deployment():
    env = TopologySpec.preset("geo_distributed", num_clients=7).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return env, fabric, store


# ---------------------------------------------------------------------------
# default [SerializeStage] stack == pre-stack serializer behaviour
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["generic", "protobuf", "membuff",
                                  "tensor_rpc"])
def test_default_stack_matches_bare_serializer(name, tree):
    ser = SERIALIZERS[name]
    ch = make_channel(name)
    assert [type(s) for s in ch.stages] == [SerializeStage]
    payload = TensorPayload(tree)
    enc = ch.encode(payload)
    ref = ser.serialize(payload)
    assert enc.wire.nbytes == ref.nbytes
    assert checksum(enc.wire) == checksum(ref)
    assert enc.cost_s == pytest.approx(ser.ser_time(ref.nbytes))
    assert enc.extra_alloc == 0 and enc.chunks is None
    out, dec_s = ch.decode(enc.wire)
    assert dec_s == pytest.approx(ser.deser_time(ref.nbytes))
    np.testing.assert_array_equal(np.asarray(out.tree["w"]), tree["w"])


def test_wire_provenance_recorded(tree):
    ch = make_channel("generic", compression="qsgd", chunk_bytes=1024)
    enc = ch.encode(TensorPayload(tree))
    kinds = [i.get("stage", "compress") for i in enc.wire.stages]
    assert kinds == ["compress", "serialize", "chunk"]
    assert ch.signature() == "qsgd(b256)|generic|chunk(0.000976562MB)"


def test_legacy_bare_wire_decodes_codec_aware(tree):
    """A wire with no stage provenance (hand-built / pre-stack) decodes
    with the codec that produced it, not the receiver's serializer."""
    wire = SERIALIZERS["membuff"].serialize(TensorPayload(tree))
    assert wire.stages == []
    receiver = make_channel("generic")  # different serializer family
    out, _ = receiver.decode(wire)
    np.testing.assert_array_equal(np.asarray(out.tree["w"]), tree["w"])


# ---------------------------------------------------------------------------
# compression stages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,codec_cls", [("qsgd", QsgdCodec),
                                            ("topk:0.25", TopkCodec)])
@pytest.mark.parametrize("serializer", ["generic", "membuff"])
def test_compressed_roundtrip(spec, codec_cls, serializer, tree):
    ch = make_channel(serializer, compression=spec)
    payload = TensorPayload(tree)
    enc = ch.encode(payload, peer="server")
    assert enc.wire.nbytes < 0.6 * payload.nbytes  # genuinely smaller
    assert enc.extra_alloc > 0  # the compressed buffer is charged
    out, dec_s = ch.decode(enc.wire)
    assert dec_s > 0
    assert isinstance(out, TensorPayload)
    # block-quantisation tolerance: a few steps of the per-block max
    tol = (np.max(np.abs(tree["w"])) / 127.0) * 2 if spec == "qsgd" else None
    if spec == "qsgd":
        np.testing.assert_allclose(np.asarray(out.tree["w"]), tree["w"],
                                   atol=tol)
    else:  # top-k: kept coordinates exact, dropped ones zero
        got = np.asarray(out.tree["w"])
        mask = got != 0
        np.testing.assert_allclose(got[mask], tree["w"][mask], atol=1e-6)


def test_virtual_payload_compression_invertible():
    ch = make_channel("generic", compression="qsgd")
    enc = ch.encode(VirtualPayload(100 * MB, tag="model:v3"), peer="x")
    assert enc.wire.nbytes < 30 * MB  # ~4x
    out, _ = ch.decode(enc.wire)
    assert isinstance(out, VirtualPayload)
    assert out.size == 100 * MB and out.tag == "model:v3"


def test_error_feedback_state_is_per_peer(tree):
    ch = make_channel("generic", compression="qsgd")
    stage = next(s for s in ch.stages if isinstance(s, CompressStage))
    ch.encode(TensorPayload(tree), peer="a")
    ch.encode(TensorPayload(tree), peer="b")
    assert set(stage._state) == {"a", "b"}
    # the residual is the quantisation error: bounded by the block step
    err = np.asarray(stage._state["a"].error)
    step = max(np.abs(tree[k]).max() for k in tree) / 127.0
    assert np.max(np.abs(err)) <= 2 * step


def test_error_feedback_carries_residual_across_sends(tree):
    """Second send re-injects the first send's quantisation error: the
    mean decoded value over two sends is closer to the truth than one
    EF-less quantisation."""
    payload = TensorPayload(tree)
    ch_ef = make_channel("generic", compression="qsgd")
    outs = []
    for _ in range(2):
        enc = ch_ef.encode(payload, peer="server")
        outs.append(np.asarray(ch_ef.decode(enc.wire)[0].tree["w"]))
    mean_ef = (outs[0] + outs[1]) / 2

    ch_raw = make_channel("generic", compression="qsgd",
                          error_feedback=False)
    raw = np.asarray(
        ch_raw.decode(ch_raw.encode(payload, peer="server").wire)[0].tree["w"])
    assert np.abs(mean_ef - tree["w"]).mean() < \
        np.abs(raw - tree["w"]).mean() + 1e-9


# ---------------------------------------------------------------------------
# chunked pipelining
# ---------------------------------------------------------------------------

def test_chunk_stage_splits_and_small_wires_pass_through():
    st = ChunkStage(4 * MB)
    assert st.split(3 * MB) is None
    sizes = st.split(10 * MB)
    assert sum(sizes) == 10 * MB and max(sizes) == 4 * MB


def test_chunked_isend_delivers_once_and_faster(deployment):
    env, fabric, store = deployment
    whole = make_backend("grpc", env, fabric, "server", store=store)
    h0 = whole.isend(FLMessage("m", "server", "client2",
                               payload=VirtualPayload(64 * MB)), 0.0)
    fabric.endpoints["client2"].inbox.clear()

    chunked = make_backend("grpc", env, fabric, "server", store=store,
                           chunk_mb=8)
    h1 = chunked.isend(FLMessage("m", "server", "client2",
                                 payload=VirtualPayload(64 * MB)), 0.0)
    # pipelining overlaps the serializer with the network: strictly earlier
    assert h1.arrive < h0.arrive
    assert fabric.stats["chunks"] == 8
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    # chunk-granular inbox: nothing pops until the *last* chunk landed
    assert cl.recv(h1.arrive - 1e-6) == []
    assert cl.next_arrival() == pytest.approx(h1.arrive)
    got = cl.recv(h1.arrive + 1.0)
    assert len(got) == 1
    assert got[0][0].payload.nbytes == 64 * MB


def test_chunked_retransmit_of_same_message_does_not_wedge(deployment):
    """Chunk groups key on the transfer, not the msg_id: re-sending the
    same message (retransmit semantics) yields two complete deliveries
    instead of one wedged 2n-chunk group."""
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store,
                      chunk_mb=8)
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    msg = FLMessage("m", "server", "client2",
                    payload=VirtualPayload(32 * MB))
    h1 = be.isend(msg, 0.0)
    h2 = be.isend(msg, h1.arrive)  # same msg_id rides again
    got = cl.recv(h2.arrive + 1.0)
    assert len(got) == 2
    assert cl.next_arrival() is None  # nothing left half-assembled


def test_duplicate_chunks_never_double_deliver(deployment):
    """An adversarial duplicate of in-flight chunks (a retransmit that
    crossed the original on the wire) must not complete the transfer
    twice or early, and must not linger half-assembled."""
    import dataclasses as dc
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=8)
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    h = be.isend(FLMessage("m", "server", "client2",
                           payload=VirtualPayload(32 * MB)), 0.0)
    inbox = fabric.endpoints["client2"].inbox
    dups = [dc.replace(d, arrive_time=d.arrive_time + 0.5)
            for d in inbox if d.chunk is not None][:2]
    inbox.extend(dups)
    got = cl.recv(h.arrive + 10.0)
    assert len(got) == 1
    # dedupe keeps the earliest copy: the duplicate (+0.5s) neither
    # delays completion nor re-triggers it
    assert got[0][1] < h.arrive + 0.5
    assert cl.next_arrival() is None  # duplicates fully drained


def test_late_retransmit_of_completed_transfer_is_dropped(deployment):
    """A chunk replayed after its transfer already delivered (superseded
    transfer id) must be discarded, not start a phantom group."""
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=8)
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    msg = FLMessage("m", "server", "client2",
                    payload=VirtualPayload(32 * MB))
    h = be.isend(msg, 0.0)
    inbox = fabric.endpoints["client2"].inbox
    first = next(d for d in inbox if d.chunk is not None)
    n_total, xid = first.chunk[1], first.chunk[2]
    assert len(cl.recv(h.arrive + 1.0)) == 1  # transfer completes
    # adversary replays one chunk of the completed transfer, much later
    from repro.core.transport import Delivery
    inbox.append(Delivery(msg, None, h.arrive + 5.0, chunk=(0, n_total, xid)))
    assert cl.next_arrival() is None  # not a pending message
    assert cl.recv(h.arrive + 100.0) == []  # and never delivered


def test_interleaved_transfers_from_two_senders_reassemble_independently(
        deployment):
    env, fabric, store = deployment
    s1 = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=8)
    s2 = make_backend("grpc", env, fabric, "client1", store=store, chunk_mb=8)
    cl = make_backend("grpc", env, fabric, "client2", store=store)
    h1 = s1.isend(FLMessage("m", "server", "client2",
                            payload=VirtualPayload(32 * MB)), 0.0)
    h2 = s2.isend(FLMessage("m", "client1", "client2",
                            payload=VirtualPayload(24 * MB)), 0.0)
    # chunks of both transfers interleave in one inbox; nothing pops
    # until a transfer is *fully* delivered
    first_done = min(h1.arrive, h2.arrive)
    early = cl.recv(first_done - 1e-6)
    assert early == []
    got = cl.recv(max(h1.arrive, h2.arrive) + 1.0)
    assert sorted(g[0].payload.nbytes for g in got) == [24 * MB, 32 * MB]
    assert cl.next_arrival() is None


def test_unchunked_backend_has_no_chunk_deliveries(deployment):
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store)
    be.isend(FLMessage("m", "server", "client1",
                       payload=VirtualPayload(64 * MB)), 0.0)
    assert fabric.stats["chunks"] == 0


# ---------------------------------------------------------------------------
# compression over real backends (end to end)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["grpc", "mpi_mem_buff", "grpc+s3"])
def test_compressed_send_roundtrips_over_backend(backend, deployment,
                                                 tree):
    env, fabric, store = deployment
    be = make_backend(backend, env, fabric, "server", store=store,
                      compression="qsgd")
    # the receiver is configured *without* compression: decode follows
    # the wire's recorded stages, not the receiver's own stack
    cl = make_backend(backend, env, fabric, "client2", store=store)
    h = be.isend(FLMessage("model_sync", "server", "client2",
                           payload=TensorPayload(tree)), 0.0)
    assert h.nbytes < 0.6 * TensorPayload(tree).nbytes
    got = cl.recv(h.arrive + 100)
    assert len(got) == 1
    out = got[0][0].payload
    tol = np.max(np.abs(tree["w"])) / 127.0 * 2
    np.testing.assert_allclose(np.asarray(out.tree["w"]), tree["w"],
                               atol=tol)
    fabric.endpoints["client2"].inbox.clear()


def test_s3_compressed_repeat_send_hits_cache_stateless(deployment, tree):
    """Content addressing requires encode to be a pure function of the
    payload: grpc+s3 runs its CompressStage without error feedback, so a
    cache hit serves exactly the wire a re-encode would have produced
    (no silently-frozen residual)."""
    env, fabric, store = deployment
    be = make_backend("grpc+s3", env, fabric, "server", store=store,
                      compression="qsgd")
    p = TensorPayload(tree)
    h1 = be.isend(FLMessage("m", "server", "client1", payload=p), 0.0)
    be.isend(FLMessage("m", "server", "client2", payload=p), h1.arrive)
    assert store.stats["puts"] == 1 and store.stats["cache_hits"] == 1
    stage = next(s for s in be.channel.stages
                 if isinstance(s, CompressStage))
    assert stage._state == {}  # stateless stream on the s3 path


def test_compression_speeds_up_wan_send(deployment):
    env, fabric, store = deployment
    plain = make_backend("grpc", env, fabric, "server", store=store)
    comp = make_backend("grpc", env, fabric, "server", store=store,
                        compression="qsgd")
    msg = lambda tag: FLMessage("m", "server", "client5",
                                payload=VirtualPayload(200 * MB, tag=tag))
    t_plain = plain.isend(msg("a"), 0.0).arrive
    t_comp = comp.isend(msg("b"), 0.0).arrive
    assert t_comp < 0.5 * t_plain  # 4x fewer bytes through ser + WAN


# ---------------------------------------------------------------------------
# MemoryMeter: time-sorted peak (regression for out-of-order events)
# ---------------------------------------------------------------------------

def test_memory_meter_peak_uses_event_timeline():
    m = MemoryMeter()
    # call order: alloc A, alloc B, free A, free B — but the *timeline*
    # says A lives [0, 2] and B lives [5, 7]: they never overlap
    m.alloc(100, 0.0)
    m.alloc(50, 5.0)
    m.free(100, 2.0)
    m.free(50, 7.0)
    assert m.peak == 100  # call-order running max would claim 150


def test_memory_meter_detects_true_overlap_despite_call_order():
    m = MemoryMeter()
    # call order interleaves alloc/free pairs, but both live over [0, 10]
    m.alloc(100, 0.0)
    m.free(100, 10.0)
    m.alloc(50, 1.0)
    m.free(50, 9.0)
    assert m.peak == 150  # call-order running max would claim 100


def test_memory_meter_reset_and_current():
    m = MemoryMeter()
    m.alloc(10, 1.0)
    assert m.current == 10 and m.peak == 10
    m.free(10, 2.0)
    assert m.current == 0
    m.reset()
    assert m.peak == 0 and m.events == []
