"""Parallel engine: conditional (per-value) sub-axes, multiprocess-safe
RunStore appends, and --workers N == serial bit-for-bit."""
import json
import multiprocessing as mp
import os

import pytest

from repro.sweep import (Axis, CellResult, Engine, RunStore, Study, Sweep,
                         SweepError)


# ---------------------------------------------------------------------------
# conditional axes: per-value sub-grids
# ---------------------------------------------------------------------------

def _chunked_backend_axis():
    """The fig8 shape: chunking only exists on the grpc branch."""
    return Axis("channel.backend", values=("grpc", "grpc+s3"),
                sub={"grpc": (Axis("params.chunk_mb", values=(4.0, 8.0)),),
                     "grpc+s3": (Axis("params.chunk_mb", values=(0.0,)),)})


def test_conditional_axis_nests_under_parent_value():
    sw = Sweep(name="c", axes=(
        _chunked_backend_axis(),
        Axis("faults.link_loss", values=(0.0, 0.01))))
    cells = sw.expand()
    triples = [(c.overrides["channel.backend"], c.params["chunk_mb"],
                c.overrides["faults.link_loss"]) for c in cells]
    # branch cells stay contiguous; later axes cross inside each branch
    assert triples == [("grpc", 4.0, 0.0), ("grpc", 4.0, 0.01),
                       ("grpc", 8.0, 0.0), ("grpc", 8.0, 0.01),
                       ("grpc+s3", 0.0, 0.0), ("grpc+s3", 0.0, 0.01)]


def test_conditional_axis_roundtrip_through_json():
    sw = Sweep(name="c", axes=(
        _chunked_backend_axis(),
        Axis("faults.link_loss", lo=0.0, hi=0.1, steps=3)))
    assert Sweep.from_dict(json.loads(json.dumps(sw.to_dict()))) == sw


def test_conditional_axis_rejected_in_random_search():
    sw = Sweep(name="c", samples=4, seed=1,
               axes=(_chunked_backend_axis(),))
    with pytest.raises(SweepError, match="grid"):
        sw.expand()


def test_conditional_axis_branch_scoped_duplicate_rule():
    # the same field on two *different* branches is fine (that's the
    # whole point) ...
    Sweep(name="ok", axes=(_chunked_backend_axis(),)).check()
    # ... but a duplicate within one branch is still a conflict
    with pytest.raises(SweepError, match="duplicate"):
        Sweep(name="dup", axes=(
            Axis("channel.backend", values=("grpc",),
                 sub={"grpc": (Axis("params.x", values=(1,)),
                               Axis("params.x", values=(2,)))}),)).check()
    # and a sub-axis contradicting an enclosing axis is too
    with pytest.raises(SweepError, match="duplicate"):
        Sweep(name="shadow", axes=(
            Axis("faults.link_loss", values=(0.0,)),
            Axis("channel.backend", values=("grpc",),
                 sub={"grpc": (Axis("faults.link_loss",
                                    values=(0.1,)),)}),)).check()


def test_conditional_axis_sub_key_must_name_a_value():
    with pytest.raises(SweepError, match="no axis value"):
        Sweep(name="k", axes=(
            Axis("channel.backend", values=("grpc",),
                 sub={"tcp": (Axis("params.x", values=(1,)),)}),)).check()


def test_conditional_axis_from_dict_rejects_non_list_sub():
    with pytest.raises(SweepError, match=r"sub\['grpc'\]"):
        Sweep.from_dict({"name": "x", "axes": [
            {"field": "channel.backend", "values": ["grpc"],
             "sub": {"grpc": {"field": "params.x", "values": [1]}}}]})


def test_fig8_fedbuff_chunking_is_spec_not_code():
    """The backend-coupled chunk_mb lives in the fig8 *sweep spec* (a
    conditional axis), not in an if-branch inside its cell runner."""
    from benchmarks.fig8_faults_wan import STUDY
    axes = [ax for sw in STUDY.sweeps(True) for ax in sw.axes]
    cond = [ax for ax in axes if ax.sub]
    assert cond, "fig8 lost its conditional chunking axis"
    ax = cond[0]
    assert ax.field == "channel.backend"
    chunks = {k: sub[0].values[0] for k, sub in ax.sub.items()}
    assert chunks["grpc"] > 0.0 and chunks["grpc+s3"] == 0.0


# ---------------------------------------------------------------------------
# RunStore: concurrent appends from real processes
# ---------------------------------------------------------------------------

def _append_burst(path, wid, n):
    store = RunStore(path)
    for i in range(n):
        store.put(CellResult.from_metrics(
            "stress", f"stress/w{wid}/{i}", f"{wid:02d}{i:04d}".ljust(24, "f"),
            {}, {"w": wid, "i": i},
            {"sim_time_s": float(i), "blob": "x" * 256}))


def test_runstore_concurrent_appends_never_interleave(tmp_path):
    """4 writer processes x 25 records into ONE store file: every line
    must parse, every record must survive."""
    path = str(tmp_path / "stress.jsonl")
    ctx = mp.get_context("spawn")
    procs = [ctx.Process(target=_append_burst, args=(path, w, 25))
             for w in range(4)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
        assert p.exitcode == 0
    with open(path) as f:
        lines = f.read().splitlines()
    assert len(lines) == 100
    recs = [CellResult.from_dict(json.loads(line)) for line in lines]
    assert len({r.fingerprint for r in recs}) == 100
    assert len(RunStore(path)) == 100


# ---------------------------------------------------------------------------
# --workers N == serial, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_workers_store_bit_identical_to_serial(tmp_path):
    """The acceptance bar: the fig4a quick grid run with workers=4
    produces a byte-identical run store to the serial run."""
    from benchmarks.fig4a_p2p_latency import STUDY
    cells = [c for sw in STUDY.sweeps(True) for c in sw.expand()]
    eng_a, eng_b = Engine(str(tmp_path / "a")), Engine(str(tmp_path / "b"))
    res_a = eng_a.run_cells(STUDY, cells, verbose=False)
    res_b = eng_b.run_cells(STUDY, cells, verbose=False, workers=4)
    assert res_a == res_b  # same records, same order
    with open(eng_a.store_path(STUDY.name), "rb") as f:
        blob_a = f.read()
    with open(eng_b.store_path(STUDY.name), "rb") as f:
        blob_b = f.read()
    assert blob_a == blob_b and len(blob_a) > 0


def test_workers_flag_plumbed_through_registry():
    from benchmarks.registry import discover
    entries = {e.name: e for e in discover()}
    assert entries["fig4a"].accepts_workers
    assert entries["fig8"].accepts_workers
    # legacy non-sweep modules must not be handed a workers kwarg
    assert not entries["kernels"].accepts_workers
