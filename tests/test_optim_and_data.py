"""Optimizer + schedule + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.data import make_silo_datasets, synthetic_lm_batch
from repro.optim import adamw_init, adamw_update, cosine_warmup, sgd_init, \
    sgd_update
from repro.optim.optimizers import clip_by_global_norm


def _quad_problem():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    return params, loss


def test_adamw_converges_on_quadratic():
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0.0)
    params, loss = _quad_problem()
    state = adamw_init(params, cfg)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, 0.1, cfg)
    assert float(loss(params)) < 1e-3


def test_sgd_momentum_converges():
    cfg = TrainConfig(grad_clip=0.0)
    params, loss = _quad_problem()
    state = sgd_init(params, cfg)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = sgd_update(g, state, params, 0.05, cfg)
    assert float(loss(params)) < 1e-3


def test_bf16_moments_track_f32():
    cfg32 = TrainConfig(moment_dtype="float32", grad_clip=0.0)
    cfg16 = TrainConfig(moment_dtype="bfloat16", grad_clip=0.0)
    params, loss = _quad_problem()
    s32, s16 = adamw_init(params, cfg32), adamw_init(params, cfg16)
    p32 = p16 = params
    for _ in range(50):
        p32, s32, _ = adamw_update(jax.grad(loss)(p32), s32, p32, 0.05, cfg32)
        p16, s16, _ = adamw_update(jax.grad(loss)(p16), s16, p16, 0.05, cfg16)
    assert s16.m["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(p16["w"]), np.asarray(p32["w"]),
                               atol=0.05)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    cn = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert cn == pytest.approx(1.0, rel=1e-3)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-4)


def test_cosine_warmup_shape():
    lrs = [float(cosine_warmup(jnp.asarray(s), base_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warming up
    assert max(lrs) == pytest.approx(1.0, rel=1e-2)
    assert lrs[-1] < 0.2  # decayed
    assert lrs[-1] >= 0.099  # min_ratio floor


def test_synthetic_lm_learnable_structure(rng):
    b = synthetic_lm_batch(rng, 4, 32, 128)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)
    # targets are the shifted stream
    assert np.all(b["targets"][:, :-1] == b["tokens"][:, 1:])


def test_silo_datasets_non_iid():
    silos = make_silo_datasets(4, kind="image", examples_per_silo=256,
                               num_classes=8, alpha=0.1, seed=0)
    dists = []
    for s in silos:
        hist = np.bincount(s.labels, minlength=8) / len(s.labels)
        dists.append(hist)
    # Dirichlet(0.1) skew: silos should differ strongly
    d01 = np.abs(dists[0] - dists[1]).sum()
    assert d01 > 0.3
    batch = next(silos[0].batches(16))
    assert batch["images"].shape[0] == 16
