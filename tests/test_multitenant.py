"""Multi-tenant fabric: per-job namespaces and stats decomposition,
single-tenant bit-identity through the tenancy machinery, the _EdgePipe
admission policies, aggregate-link solver parity, control-message byte
accounting, the JSONL blackout trace front end, and MultiScenario."""
import json

import pytest

from repro.configs.paper_tiers import TIERS
from repro.core.message import FLMessage, VirtualPayload
from repro.core.netsim import MB, NCAL, Host, Transfer, scalar_transfers, \
    simulate_transfers
from repro.core.transport import CTRL_BYTES, Fabric, FabricSpec, _EdgePipe
from repro.scenario import (ChannelSpec, EdgeSpec, FaultSpec, FleetSpec,
                            JobSpec, MultiScenario, Scenario, ScenarioError,
                            StrategySpec, TopologySpec, load_blackouts_file)
from repro.scenario.spec import BlackoutSpec
from repro.sweep.runners import run_multi, run_scenario, wire_stats


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------

def _tight_topo(bw_mb=8.0, n=4):
    edges = tuple(EdgeSpec(src="server", dst=f"client{i}", bw_single_mb=bw_mb,
                           bw_multi_mb=bw_mb, latency_ms=40.0)
                  for i in range(n))
    return TopologySpec(kind="geo_distributed", num_clients=n, edges=edges)


def _job_scenario(name, seed, *, mode="fedbuff", tier="small", rounds=3,
                  topo=None, backend="grpc"):
    return Scenario(
        name=name, seed=seed,
        topology=topo or TopologySpec.preset("geo_distributed",
                                             num_clients=4),
        fleet=FleetSpec(tier=tier),
        channel=ChannelSpec(backend=backend),
        strategy=StrategySpec(mode=mode, rounds=rounds, buffer_k=2,
                              quorum_fraction=1.0))


def _mspec(jobs, policy="fifo", shared=True, name="mt"):
    return MultiScenario(name=name,
                         fabric=FabricSpec(policy=policy,
                                           shared_links=shared),
                         jobs=tuple(jobs))


# ---------------------------------------------------------------------------
# per-job stats namespaces
# ---------------------------------------------------------------------------

def test_per_job_stats_sum_to_globals():
    jobs = (JobSpec("a", _job_scenario("a", 0), rounds=3),
            JobSpec("b", _job_scenario("b", 1, mode="semisync"), rounds=3,
                    start_s=11.0))
    res = run_multi(_mspec(jobs))
    for key in ("bytes_on_wire", "retransmits", "transfers_failed"):
        per_job = sum(res["jobs"][n][key] for n in ("a", "b"))
        assert per_job == pytest.approx(res[key]), (
            f"{key}: per-job views {per_job} != global {res[key]}")
    assert res["jobs"]["a"]["bytes_on_wire"] > 0
    assert res["jobs"]["b"]["bytes_on_wire"] > 0


def test_job_namespace_isolation():
    env = TopologySpec.preset("geo_proximal", num_clients=2).build()
    fabric = Fabric(env)
    a, b = fabric.job("a"), fabric.job("b")
    fabric.register("server", job="a")
    fabric.register("server", job="b")
    # transfer ids allocate independently per namespace
    assert fabric.next_transfer_id("a") == fabric.next_transfer_id("b")
    msg = FLMessage(msg_type="control", sender="client0", receiver="server")
    fabric.deliver(msg, None, 0.0, 1.0, job="a")
    assert len(fabric._ep("server", "a").inbox) == 1
    assert len(fabric._ep("server", "b").inbox) == 0
    assert fabric.stats_for("a")["bytes"] == CTRL_BYTES
    assert fabric.stats_for("b")["bytes"] == 0
    assert fabric.stats["bytes"] == CTRL_BYTES
    assert a.name == "a" and b.name == "b"


def test_job_registration_idempotent_and_name_checked():
    env = TopologySpec.preset("geo_proximal", num_clients=2).build()
    fabric = Fabric(env)
    assert fabric.job("a") is fabric.job("a")  # register-or-fetch
    with pytest.raises(ValueError):
        fabric.job("a::b")  # '::' is the namespace separator


# ---------------------------------------------------------------------------
# control-message byte accounting (the deliver-vs-concurrent regression)
# ---------------------------------------------------------------------------

def test_control_messages_charge_ctrl_bytes_on_every_path():
    env = TopologySpec.preset("geo_proximal", num_clients=2).build()
    msg = FLMessage(msg_type="control", sender="server", receiver="client0")

    fab_a = Fabric(env)
    fab_a.register("client0")
    fab_a.deliver(msg, None, 0.0, 1.0)

    fab_b = Fabric(env)
    fab_b.register("client0")
    fab_b.deliver_concurrent([(msg, None, 0.0, 1)])

    # historical bug: deliver() charged 0 for wire=None while
    # deliver_concurrent charged CTRL_BYTES — the two paths must agree
    assert fab_a.stats["bytes"] == CTRL_BYTES
    assert fab_a.stats["bytes"] == fab_b.stats["bytes"]
    assert fab_a.stats["messages"] == fab_b.stats["messages"] == 1


# ---------------------------------------------------------------------------
# _EdgePipe admission policies
# ---------------------------------------------------------------------------

C = 100.0 * MB


def test_fifo_pipe_serializes_contending_tenants():
    pipe = _EdgePipe(C, "fifo")
    # tenant a holds the whole pipe for [0, 10)
    fin_a = pipe.transmit(0.0, 10 * C, C, 0, "a")
    assert fin_a == pytest.approx(10.0)
    # tenant b departing mid-way drains only after a's reservation
    fin_b = pipe.transmit(4.0, 2 * C, C, 0, "b")
    assert fin_b == pytest.approx(12.0)


def test_fifo_partial_residual_is_shared():
    pipe = _EdgePipe(C, "fifo")
    pipe.reserve(0.0, 10.0, 0.25 * C, 0, "a")
    assert pipe.available(5.0, 0, "b") == pytest.approx(0.75 * C)
    fin = pipe.transmit(0.0, 7.5 * C, C, 0, "b")
    assert fin == pytest.approx(10.0)


def test_priority_sees_through_lower_priority_reservations():
    pipe = _EdgePipe(C, "priority")
    pipe.reserve(0.0, 10.0, C, 0, "bg")  # low-prio tenant saturates
    # a priority-1 job contends only with >= its own priority: full rate
    # (the documented no-revocation overcommit approximation)
    assert pipe.available(5.0, 1, "fg") == pytest.approx(C)
    assert pipe.transmit(0.0, 5 * C, C, 1, "fg") == pytest.approx(5.0)
    # equal-priority traffic still queues fifo-style
    assert pipe.available(5.0, 0, "other") == pytest.approx(0.0)


def test_fair_share_guarantees_capacity_over_k():
    pipe = _EdgePipe(C, "fair-share")
    pipe.reserve(0.0, 10.0, C, 0, "a")  # one tenant holding everything
    # a second job is guaranteed C/2 even with zero fifo residual
    assert pipe.available(5.0, 0, "b") == pytest.approx(C / 2)
    # three distinct other tenants -> C/4 guarantee
    pipe.reserve(0.0, 10.0, 0.1 * C, 0, "c")
    pipe.reserve(0.0, 10.0, 0.1 * C, 0, "d")
    assert pipe.available(5.0, 0, "b") == pytest.approx(C / 4)
    # the holder itself is not double-guaranteed: work-conserving residual
    assert pipe.available(5.0, 0, "a") == pytest.approx(0.0)


def test_drain_rate_is_queueing_equivalent():
    pipe = _EdgePipe(C, "fifo")
    pipe.reserve(0.0, 6.0, C, 0, "a")
    nbytes = 4 * C
    rate = pipe.drain_rate(2.0, nbytes, C, 0, "b")
    fin = pipe.transmit(2.0, nbytes, C, 0, "b")
    # the average rate must reproduce the walked finish time exactly:
    # depart + nbytes/rate == walk(depart, nbytes)
    assert 2.0 + nbytes / rate == pytest.approx(fin)
    assert fin == pytest.approx(10.0)  # 4s queue + 4s drain
    # and a request for zero bytes degrades to the want rate
    assert pipe.drain_rate(2.0, 0.0, C, 0, "b") == C


# ---------------------------------------------------------------------------
# aggregate-link solver parity (scalar vs vectorized)
# ---------------------------------------------------------------------------

def _edge_batch(n):
    hub = Host("server", NCAL, NCAL.bw_multi, NCAL.bw_multi)
    out = []
    for i in range(n):
        cl = Host(f"client{i % 8}", NCAL, NCAL.bw_multi, NCAL.bw_multi)
        out.append(Transfer(start=0.1 * (i % 5), src=cl, dst=hub,
                            nbytes=(1 + i % 7) * MB, conns=1,
                            link_region=NCAL,
                            edge_key=("e", i % 3),
                            edge_cap=25.0 * MB))
    return out


def test_edge_pool_scalar_vs_vectorized_parity():
    batch_a = _edge_batch(96)  # >= SIM_VECTORIZE_MIN -> numpy solver
    simulate_transfers(batch_a)
    batch_b = _edge_batch(96)
    with scalar_transfers():
        simulate_transfers(batch_b)
    for a, b in zip(batch_a, batch_b):
        assert a.finish == pytest.approx(b.finish, rel=1e-9), (
            f"edge-pool divergence on {a.tag or a.nbytes}")


def test_edge_pool_caps_aggregate_rate():
    # 4 concurrent flows on one 10 MB/s edge pool: 40 MB total drains in
    # >= 4s no matter how fat the hosts are
    hub = Host("server", NCAL, NCAL.bw_multi, NCAL.bw_multi)
    cl = Host("client0", NCAL, NCAL.bw_multi, NCAL.bw_multi)
    ts = [Transfer(start=0.0, src=cl, dst=hub, nbytes=10 * MB, conns=1,
                   link_region=NCAL, edge_key=("up",), edge_cap=10.0 * MB)
          for _ in range(4)]
    simulate_transfers(ts)
    assert max(t.finish for t in ts) >= 4.0
    # without the shared pool the same flows finish far faster
    ts2 = [Transfer(start=0.0, src=cl, dst=hub, nbytes=10 * MB, conns=1,
                    link_region=NCAL) for _ in range(4)]
    simulate_transfers(ts2)
    assert max(t.finish for t in ts2) < 1.0


# ---------------------------------------------------------------------------
# single-tenant bit-identity (solo vs 1-job multi)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fedbuff", "semisync"])
def test_single_job_multi_matches_solo(mode):
    sc = _job_scenario("ident", 0, mode=mode, rounds=3)
    solo = run_scenario(sc)
    res = run_multi(_mspec(
        (JobSpec("ident", sc, rounds=3),), shared=False))
    job = res["jobs"]["ident"]
    assert job["round_s"] == solo["round_s"]
    assert job["sim_time_s"] == solo["sim_time_s"]
    assert job["n_rounds"] == solo["n_rounds"]
    assert job["bytes_on_wire"] == solo["bytes_on_wire"]
    assert job["mean_staleness"] == solo["mean_staleness"]
    # the global view of a one-job world IS the job's view
    assert res["bytes_on_wire"] == job["bytes_on_wire"]


def test_shared_links_off_is_inert_even_multi_job():
    """Two tenants with shared_links=False interleave on the clock but
    never contend: each matches its solo run exactly (fig2/5/6-style
    traces stay bit-identical through the tenancy layers)."""
    a = _job_scenario("a", 0, rounds=3, topo=_tight_topo())
    b = _job_scenario("b", 1, mode="semisync", rounds=3, topo=_tight_topo())
    res = run_multi(_mspec((JobSpec("a", a, rounds=3),
                            JobSpec("b", b, rounds=3, start_s=5.0)),
                           shared=False))
    # job a starts at t=0: bit-identical. job b is offset by 5s, so its
    # absolute event times shift and fp associativity allows 1-ulp drift.
    assert res["jobs"]["a"]["round_s"] == run_scenario(a)["round_s"]
    assert res["jobs"]["b"]["round_s"] == pytest.approx(
        run_scenario(b)["round_s"], rel=1e-12)


def test_shared_links_contention_and_priority_shield():
    """On thin shared uplinks an offset tenant pair contends under fifo;
    priority admission restores the foreground's solo round time."""
    def job(name, seed):
        sc = Scenario(
            name=name, seed=seed, topology=_tight_topo(),
            fleet=FleetSpec(tier="big"),
            channel=ChannelSpec(backend="grpc"),
            faults=FaultSpec(availability_trace="auto:400/40",
                             trace_horizon_s=2000.0),
            strategy=StrategySpec(mode="fedbuff", rounds=5, buffer_k=2))
        return sc

    fg_solo = run_scenario(job("fg", 0))["round_s"]
    jobs = (JobSpec("fg", job("fg", 0), priority=1, start_s=13.0, rounds=5),
            JobSpec("bg", job("bg", 1), rounds=5))
    fifo = run_multi(_mspec(jobs, policy="fifo"))
    prio = run_multi(_mspec(jobs, policy="priority"))
    assert fifo["jobs"]["fg"]["round_s"] > fg_solo  # fifo makes fg pay
    assert prio["jobs"]["fg"]["round_s"] == pytest.approx(fg_solo)


# ---------------------------------------------------------------------------
# JSONL blackout traces
# ---------------------------------------------------------------------------

def test_blackouts_file_roundtrip(tmp_path):
    windows = (BlackoutSpec(src="client1", dst="server", t0=10.0, t1=20.0),
               BlackoutSpec(src="client2", dst="*", t0=30.0, t1=40.0,
                            symmetric=False))
    p = tmp_path / "outages.jsonl"
    p.write_text("# replay trace\n\n" + "\n".join(
        json.dumps({"src": w.src, "dst": w.dst, "t0": w.t0, "t1": w.t1,
                    "symmetric": w.symmetric}) for w in windows) + "\n")
    assert load_blackouts_file(str(p)) == windows
    # FaultSpec appends file windows after the inline ones
    inline = BlackoutSpec(src="client0", t0=1.0, t1=2.0)
    fs = FaultSpec(blackouts=(inline,), blackouts_file=str(p))
    assert fs.all_blackouts() == (inline,) + windows


def test_blackouts_file_malformed_line_is_loud(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"src": "client0", "t0": 0, "t1": 5}\nnot json\n')
    with pytest.raises(ScenarioError, match=r"bad\.jsonl:2"):
        load_blackouts_file(str(p))
    p.write_text('{"src": "client0", "oops": 1}\n')
    with pytest.raises(ScenarioError, match="oops"):
        load_blackouts_file(str(p))
    with pytest.raises(ScenarioError, match="cannot read"):
        load_blackouts_file(str(tmp_path / "missing.jsonl"))


def test_blackouts_file_resolves_relative_to_spec(tmp_path):
    (tmp_path / "outages.jsonl").write_text(
        '{"src": "client0", "dst": "server", "t0": 5.0, "t1": 9.0}\n')
    sc = Scenario(name="bo",
                  faults=FaultSpec(blackouts_file="outages.jsonl"))
    spec_path = tmp_path / "scenario.json"
    spec_path.write_text(sc.to_json())
    loaded = Scenario.load(str(spec_path))
    assert loaded.faults.blackouts_file == str(tmp_path / "outages.jsonl")
    loaded.validate()
    assert loaded.faults.all_blackouts()[0].t1 == 9.0


def test_blackouts_file_validated_with_scenario(tmp_path):
    p = tmp_path / "outages.jsonl"
    p.write_text('{"src": "client99", "dst": "server", "t0": 0, "t1": 5}\n')
    sc = Scenario(name="bo", faults=FaultSpec(blackouts_file=str(p)))
    with pytest.raises(ScenarioError, match="client99"):
        sc.validate()


# ---------------------------------------------------------------------------
# MultiScenario spec
# ---------------------------------------------------------------------------

def test_multiscenario_roundtrip():
    ms = _mspec((JobSpec("a", _job_scenario("a", 0), priority=1,
                         start_s=13.0, rounds=4),
                 JobSpec("b", _job_scenario("b", 1, mode="semisync"))),
                policy="priority")
    assert MultiScenario.from_json(ms.to_json()) == ms
    assert MultiScenario.from_dict(ms.to_dict()) == ms


def test_multiscenario_load_anchors_blackout_files(tmp_path):
    (tmp_path / "outages.jsonl").write_text(
        '{"src": "client0", "t0": 0, "t1": 1}\n')
    sc = _job_scenario("a", 0)
    sc = Scenario(**{**sc.to_dict(),
                     "topology": sc.topology, "fleet": sc.fleet,
                     "channel": sc.channel, "strategy": sc.strategy,
                     "faults": FaultSpec(blackouts_file="outages.jsonl")})
    ms = _mspec((JobSpec("a", sc, rounds=3),))
    p = tmp_path / "multi.json"
    p.write_text(ms.to_json())
    loaded = MultiScenario.load(str(p))
    assert loaded.jobs[0].scenario.faults.blackouts_file == \
        str(tmp_path / "outages.jsonl")
    loaded.validate()


@pytest.mark.parametrize("mutate,msg", [
    (lambda ms: _mspec(()), ">= 1 job"),
    (lambda ms: _mspec((ms.jobs[0], ms.jobs[0])), "duplicate"),
    (lambda ms: _mspec((JobSpec("x::y", ms.jobs[0].scenario, rounds=3),)),
     "::"),
])
def test_multiscenario_validation_errors(mutate, msg):
    ms = _mspec((JobSpec("a", _job_scenario("a", 0), rounds=3),))
    with pytest.raises(ScenarioError, match=msg):
        mutate(ms).validate()


def test_multiscenario_rejects_sync_and_mismatched_topologies():
    sync_sc = _job_scenario("a", 0, mode="sync")
    with pytest.raises(ScenarioError, match="mode"):
        _mspec((JobSpec("a", sync_sc, rounds=3),)).validate()
    a = _job_scenario("a", 0)
    b = _job_scenario("b", 1, topo=_tight_topo())
    with pytest.raises(ScenarioError, match="topology"):
        _mspec((JobSpec("a", a, rounds=3),
                JobSpec("b", b, rounds=3))).validate()


def test_multiscenario_requires_a_cap():
    sc = Scenario(name="nocap",
                  topology=TopologySpec.preset("geo_proximal",
                                               num_clients=2),
                  strategy=StrategySpec(mode="fedbuff", rounds=0,
                                        buffer_k=1))
    with pytest.raises(ScenarioError, match="cap|rounds"):
        _mspec((JobSpec("a", sc),)).validate()


def test_fleet_train_s_override():
    sc = _job_scenario("t", 0)
    fast = Scenario(**{**sc.to_dict(), "topology": sc.topology,
                       "fleet": FleetSpec(tier="small", train_s=0.5),
                       "channel": sc.channel, "faults": sc.faults,
                       "strategy": sc.strategy})
    assert run_scenario(fast)["round_s"] < run_scenario(sc)["round_s"]
    with pytest.raises(ScenarioError, match="train_s"):
        Scenario(**{**sc.to_dict(), "topology": sc.topology,
                    "fleet": FleetSpec(tier="small", train_s=-1.0),
                    "channel": sc.channel, "faults": sc.faults,
                    "strategy": sc.strategy}).validate()


# ---------------------------------------------------------------------------
# run_multi end to end
# ---------------------------------------------------------------------------

def test_run_multi_smoke_reports_every_job():
    jobs = (JobSpec("a", _job_scenario("a", 0), rounds=2),
            JobSpec("b", _job_scenario("b", 1), rounds=2, start_s=3.0))
    res = run_multi(_mspec(jobs, policy="fair-share"))
    assert set(res["jobs"]) == {"a", "b"}
    assert res["policy"] == "fair-share" and res["shared_links"] is True
    for name in ("a", "b"):
        job = res["jobs"][name]
        assert job["n_rounds"] == 2
        assert job["round_s"] > 0
        assert job["n_client_updates"] >= 2


def test_wire_stats_job_view(tmp_path):
    jobs = (JobSpec("a", _job_scenario("a", 0), rounds=2),)
    res = run_multi(_mspec(jobs))
    assert res["jobs"]["a"]["bytes_on_wire"] == res["bytes_on_wire"]
