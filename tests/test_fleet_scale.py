"""Fleet-scale engine: the PR-7 equivalence contracts.

Every scale-path optimisation here is gated on producing the *same
simulation* as the paper-scale reference it replaces:

* calendar event queue == heapq trace, bit for bit;
* cohort sampling with K = N == the full-fleet run, bit for bit;
* streaming hub accumulator == dense aggregation within float tolerance
  (and trace-identical on virtual payloads);
* nested relay tree with depth=1 == the single-tier hier event set,
  depth=2 == the same numerics;
* vectorised fluid solver == the scalar reference solver;
* the linear-scan baseline switches (fig11) == the indexed fast paths;
* AUTO fused broadcast / fused topk batch == the per-message wire bytes.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Fabric, ObjectStore, TensorPayload, VirtualPayload,
                        make_backend)
from repro.core.netsim import (NCAL, Transfer, linear_host_lookup,
                               scalar_transfers, simulate_transfers)
from repro.core.transport import linear_inbox
from repro.fl import (FedBuffStrategy, FLClient, FLScheduler,
                      HierarchicalStrategy)
from repro.fl.scheduler import EventLoop
from repro.scenario import TopologySpec

from test_scheduler import _deployment, _init_params


def _virtual_sched(n=14, *, queue="heap", cohort_k=0, streaming=False,
                   buffer_k=3, max_agg=5, env_name="geo_distributed"):
    sb, clients = _deployment("grpc+s3", env_name, n, live=False,
                              straggle={f"client{n-1}": 3.0})
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=buffer_k,
                                        staleness_exponent=0.5),
                        local_steps=1, event_queue=queue,
                        cohort_k=cohort_k, streaming_hub=streaming)
    sched.run(VirtualPayload(32 << 20, tag="scale"),
              max_aggregations=max_agg)
    return sched


# ---------------------------------------------------------------------------
# calendar queue == heapq
# ---------------------------------------------------------------------------

def test_calendar_queue_trace_identical_at_paper_scale():
    heap = _virtual_sched(14, queue="heap")
    cal = _virtual_sched(14, queue="calendar")
    assert cal.loop.trace == heap.loop.trace
    assert [(e.time, e.version, e.n_updates) for e in cal.agg_log] == \
           [(e.time, e.version, e.n_updates) for e in heap.agg_log]


def test_calendar_queue_random_insertion_property():
    rng = np.random.default_rng(7)
    times = rng.uniform(0.0, 50.0, size=400).round(3)

    def drive(queue):
        loop = EventLoop(queue=queue)
        seen = []

        def handler(now, delay=0.0):
            seen.append((now, delay))
            # re-entrant pushes, including into the past (clamped) and
            # into the current bucket — the calendar's hazard cases
            if len(seen) < len(times) + 120:
                loop.call_at(now + delay % 3.0, f"re{len(seen)}",
                             handler, delay=0.5)
                loop.call_at(now - 1.0, f"past{len(seen)}", handler)
        for i, t in enumerate(times):
            loop.call_at(float(t), f"e{i}", handler, delay=float(t))
        loop.run(until=60.0)
        return loop.trace

    assert drive("calendar") == drive("heap")


# ---------------------------------------------------------------------------
# cohort sampling
# ---------------------------------------------------------------------------

def test_cohort_k_equals_n_is_bit_identical():
    full = _virtual_sched(8, cohort_k=0)
    kn = _virtual_sched(8, cohort_k=8)
    assert kn.loop.trace == full.loop.trace
    assert kn.update_log == full.update_log


def test_cohort_subsample_limits_participation():
    sched = _virtual_sched(8, cohort_k=3, max_agg=4)
    assert sched.n_aggregations == 4
    assert int(sched._in_cohort.sum()) == 3
    # every update came from a sampled client, never the whole fleet at
    # once: in-flight dispatches are capped by the cohort size
    assert sched.n_updates_applied >= 4 * 3 - 3  # buffer_k=3 per round


def test_cohort_resample_is_seeded():
    a = _virtual_sched(8, cohort_k=3)
    b = _virtual_sched(8, cohort_k=3)
    assert a.loop.trace == b.loop.trace
    assert np.array_equal(a._in_cohort, b._in_cohort)


# ---------------------------------------------------------------------------
# streaming hub
# ---------------------------------------------------------------------------

def test_streaming_hub_matches_dense_numerics():
    n = 4
    sb, clients = _deployment("grpc", "lan", n, live=True)
    dense = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=n, staleness_exponent=0.5),
                        local_steps=2)
    dense.run(TensorPayload(_init_params()), max_aggregations=2)

    sb2, clients2 = _deployment("grpc", "lan", n, live=True)
    stream = FLScheduler(sb2, clients2,
                         FedBuffStrategy(buffer_k=n, staleness_exponent=0.5),
                         local_steps=2, streaming_hub=True)
    stream.run(TensorPayload(_init_params()), max_aggregations=2)
    for k in dense.global_params:
        np.testing.assert_allclose(np.asarray(stream.global_params[k]),
                                   np.asarray(dense.global_params[k]),
                                   atol=1e-5)


def test_streaming_hub_virtual_trace_identical_and_peak_lower():
    dense = _virtual_sched(14, streaming=False, buffer_k=14, max_agg=2)
    stream = _virtual_sched(14, streaming=True, buffer_k=14, max_agg=2)
    assert stream.loop.trace == dense.loop.trace
    # dense buffers one record per client at the hub; streaming folds
    # into one O(model) accumulator
    assert stream.backend.endpoint.memory.peak \
        < dense.backend.endpoint.memory.peak


# ---------------------------------------------------------------------------
# nested relay trees
# ---------------------------------------------------------------------------

def _hier_sched(n, depth, *, live, max_agg=2, local_steps=2):
    sb, clients = _deployment("grpc", "geo_distributed", n, live=live)
    sched = FLScheduler(
        sb, clients,
        HierarchicalStrategy(staleness_exponent=0.0, relay_depth=depth),
        local_steps=local_steps)
    payload = TensorPayload(_init_params()) if live \
        else VirtualPayload(32 << 20, tag="hier")
    sched.run(payload, max_aggregations=max_agg)
    return sched


def test_relay_depth1_keeps_single_tier_event_set():
    sched = _hier_sched(8, 1, live=False)
    names = {name for _, name in sched.loop.trace}
    assert any(n.startswith("hier-hub<") for n in names)
    assert not any(n.startswith("hier-tier<") for n in names)
    assert not any(n.startswith("hier-fold<") for n in names)


def test_relay_depth2_routes_through_tier_nodes():
    sched = _hier_sched(8, 2, live=False)
    names = {name for _, name in sched.loop.trace}
    assert any(n.startswith("hier-tier<") for n in names)
    assert sched.n_aggregations == 2


def test_relay_depth2_matches_depth1_numerics():
    d1 = _hier_sched(8, 1, live=True)
    d2 = _hier_sched(8, 2, live=True)
    for k in d1.global_params:
        np.testing.assert_allclose(np.asarray(d2.global_params[k]),
                                   np.asarray(d1.global_params[k]),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# vectorised fluid solver
# ---------------------------------------------------------------------------

def _clone(transfers):
    return [Transfer(start=t.start, src=t.src, dst=t.dst, nbytes=t.nbytes,
                     conns=t.conns, link_region=t.link_region, tag=t.tag)
            for t in transfers]


def _solve_both(transfers):
    vec = _clone(transfers)
    simulate_transfers(vec)  # >= SIM_VECTORIZE_MIN engages the NumPy path
    ref = _clone(transfers)
    with scalar_transfers():
        simulate_transfers(ref)
    return [t.finish for t in vec], [t.finish for t in ref]


def test_vectorized_solver_matches_scalar_fanout_and_mesh():
    env = TopologySpec.preset("geo_distributed", num_clients=80).build()
    # identical-start fan-out (the collapsed-flow fast path)
    fan = [Transfer(start=0.0, src=env.server, dst=c, nbytes=8 << 20,
                    conns=1,
                    link_region=env.link("server", c.host_id).region,
                    tag=f"f{i}")
           for i, c in enumerate(env.clients)]
    vec, ref = _solve_both(fan)
    np.testing.assert_allclose(vec, ref, rtol=1e-9)

    # staggered fan-in + cross-client mesh (no collapsing)
    rng = np.random.default_rng(3)
    mesh = [Transfer(start=float(rng.uniform(0, 2)), src=c,
                     dst=env.server, nbytes=int(rng.integers(1, 64)) << 20,
                     conns=1,
                     link_region=env.link(c.host_id, "server").region,
                     tag=f"m{i}")
            for i, c in enumerate(env.clients)]
    mesh += [Transfer(start=float(rng.uniform(0, 2)), src=env.clients[i],
                      dst=env.clients[i + 40], nbytes=4 << 20, conns=1,
                      link_region=env.link(env.clients[i].host_id,
                                           env.clients[i + 40].host_id
                                           ).region,
                      tag=f"x{i}")
             for i in range(12)]
    vec, ref = _solve_both(mesh)
    np.testing.assert_allclose(vec, ref, rtol=1e-9)


def test_linear_baseline_switches_are_result_identical():
    fast = _virtual_sched(14, queue="calendar", streaming=True)
    with contextlib.ExitStack() as stack:
        stack.enter_context(scalar_transfers())
        stack.enter_context(linear_inbox())
        stack.enter_context(linear_host_lookup())
        slow = _virtual_sched(14, queue="calendar", streaming=True)
    assert slow.loop.trace == fast.loop.trace
    assert slow.update_log == fast.update_log


# ---------------------------------------------------------------------------
# lazy rule-generated link maps
# ---------------------------------------------------------------------------

def test_rule_links_match_dense_build():
    for kind in ("lan", "geo_distributed", "multi_hub"):
        spec = TopologySpec(kind=kind,
                            num_clients=TopologySpec.LAZY_LINKS_MIN)
        lazy = spec.build()
        assert type(lazy.links).__name__ == "_RuleLinks"
        old = TopologySpec.LAZY_LINKS_MIN
        try:
            TopologySpec.LAZY_LINKS_MIN = 1 << 30
            dense = spec.build()
        finally:
            TopologySpec.LAZY_LINKS_MIN = old
        assert type(dense.links) is dict and dense.links
        for key, edge in dense.links.items():
            got = lazy.links.get(key)
            assert got is not None, (kind, key)
            assert (got.src, got.dst, got.lan_class,
                    got.region.name) == (edge.src, edge.dst,
                                         edge.lan_class, edge.region.name)
        assert lazy.links.get(("nope", "nope2")) is None


# ---------------------------------------------------------------------------
# AUTO fused broadcast
# ---------------------------------------------------------------------------

def _auto_deployment(compression):
    from repro.core.message import FLMessage
    env = TopologySpec.preset("geo_distributed", num_clients=6).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    be = make_backend("auto", env, fabric, "server", store=store,
                      compression=compression)
    # mixed wave: metadata-only + small tensors (grpc) + a large virtual
    # model (grpc+s3) — exercises every routing branch of the fused path
    params = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6)}
    msgs = []
    for i, c in enumerate(env.clients):
        if i % 3 == 0:
            payload = None
        elif i % 3 == 1:
            payload = TensorPayload(jax.tree.map(lambda a: a + i, params))
        else:
            payload = VirtualPayload(64 << 20, tag=f"big{i}")
        msgs.append(FLMessage("m", "server", c.host_id, payload=payload))
    return env, fabric, be, msgs


def _old_subset_broadcast(be, msgs, now):
    """The pre-fusion AUTO path: each routed subset encodes on its own
    backend (no shared ``encode_many`` dispatch)."""
    routed = {}
    for i, msg in enumerate(msgs):
        routed.setdefault(id(be._route(msg)), []).append(i)
    backends = {id(b): b for b in (be.grpc, be.membuff, be.s3)
                if b is not None}
    sender_done, arrives = now, [0.0] * len(msgs)
    for bid, idxs in routed.items():
        done, arr = backends[bid].broadcast([msgs[i] for i in idxs], now)
        sender_done = max(sender_done, done)
        for i, a in zip(idxs, arr):
            arrives[i] = a
    return sender_done, arrives


@pytest.mark.parametrize("compression", [None, "qsgd", "topk:0.25"])
def test_auto_fused_broadcast_bit_identical(compression):
    env, fabric, be, msgs = _auto_deployment(compression)
    done, arrives = be.broadcast(msgs, 1.0)

    env2, fabric2, be2, msgs2 = _auto_deployment(compression)
    done2, arrives2 = _old_subset_broadcast(be2, msgs2, 1.0)

    assert done == done2 and arrives == arrives2
    for c in env.clients:
        a = [(d.arrive_time, d.wire.nbytes if d.wire else None)
             for d in fabric.endpoints[c.host_id].inbox]
        b = [(d.arrive_time, d.wire.nbytes if d.wire else None)
             for d in fabric2.endpoints[c.host_id].inbox]
        assert a == b, c.host_id


# ---------------------------------------------------------------------------
# fused topk batch + streaming accumulate kernel
# ---------------------------------------------------------------------------

def test_topk_batch_matches_per_message_with_ties():
    from repro.compression.topk import (topk_compress,
                                        topk_compress_flat_batch)
    rng = np.random.default_rng(11)
    flats = [rng.normal(size=64).astype(np.float32) for _ in range(3)]
    # |value| ties, same sign and opposite sign, plus a short message
    flats.append(np.array([1.0, -1.0, 0.5, 0.5, 2.0, -2.0, 0.0, 0.25],
                          np.float32))
    states = [None] * len(flats)
    batch, bstates = topk_compress_flat_batch(flats, states, k_frac=0.25)
    for f, p in zip(flats, batch):
        single, _, _ = topk_compress({"x": jnp.asarray(f)}, 0.25)
        assert np.array_equal(np.asarray(p["idx"]),
                              np.asarray(single["idx"]))
        assert np.array_equal(np.asarray(p["vals"]),
                              np.asarray(single["vals"]))


def test_topk_error_feedback_transitions_match():
    from repro.compression.qsgd import QuantState
    from repro.compression.topk import (topk_compress,
                                        topk_compress_flat_batch)
    rng = np.random.default_rng(5)
    flats = [rng.normal(size=48).astype(np.float32) for _ in range(4)]
    states = [QuantState(error=np.zeros(48, np.float32))
              for _ in flats]
    for _ in range(2):  # two EF rounds: residuals feed the next pick
        batch, states = topk_compress_flat_batch(
            flats, states, k_frac=0.2)
    singles = [QuantState(error=np.zeros(48, np.float32))
               for _ in flats]
    payloads = []
    for _ in range(2):
        payloads = []
        for i, f in enumerate(flats):
            p, singles[i], _ = topk_compress({"x": jnp.asarray(f)}, 0.2,
                                             singles[i])
            payloads.append(p)
    for p, b, ss, bs in zip(payloads, batch, singles, states):
        assert np.array_equal(np.asarray(p["idx"]), np.asarray(b["idx"]))
        assert np.array_equal(np.asarray(p["vals"]), np.asarray(b["vals"]))
        np.testing.assert_allclose(np.asarray(ss.error),
                                   np.asarray(bs.error), atol=0)


def test_topk_codec_encode_batch_matches_per_message():
    from repro.compression.stages import TopkCodec
    rng = np.random.default_rng(9)
    trees = [{"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=8).astype(np.float32))}
             for _ in range(3)]
    payloads = [TensorPayload(t) for t in trees]
    payloads.append(VirtualPayload(1 << 20, tag="v"))

    batch = TopkCodec(0.25).encode_batch(payloads, [None] * len(payloads))
    per_msg = [TopkCodec(0.25).compress(p, None) for p in payloads]
    for (bp, _, bi), (sp, _, si) in zip(batch, per_msg):
        assert bi == si or (bi["codec"] == si["codec"]
                            and bi["orig_nbytes"] == si["orig_nbytes"])
        if hasattr(bp, "packed"):
            for k in bp.packed:
                assert np.array_equal(np.asarray(bp.packed[k]),
                                      np.asarray(sp.packed[k]))
        else:
            assert bp.nbytes == sp.nbytes


def test_fedavg_accumulate_kernel_matches_ref():
    from repro.kernels import ops
    from repro.kernels.ops import _jit_accumulate_ref
    rng = np.random.default_rng(2)
    acc = rng.normal(size=1000).astype(np.float32)
    x = rng.normal(size=1000).astype(np.float32)
    got = ops.fedavg_accumulate_flat(acc, x, 0.37, interpret=True)
    want = _jit_accumulate_ref(jnp.asarray(acc), jnp.asarray(x), 0.37)
    # 1-ulp FMA-contraction differences between the Pallas interpret
    # path and the compiled XLA reference are within the streaming-hub
    # float-tolerance contract
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# spec round-trip for the new knobs
# ---------------------------------------------------------------------------

def test_fl_config_round_trips_scale_knobs():
    from repro.configs.base import FLConfig
    cfg = FLConfig(num_clients=200, mode="fedbuff", cohort_k=50,
                   streaming_hub=True, relay_depth=3)
    sc = cfg.to_scenario()
    assert sc.fleet.cohort_k == 50
    assert sc.strategy.streaming_hub is True
    assert sc.topology.relay_depth == 3
    back = sc.fl_config()
    assert back.cohort_k == 50
    assert back.streaming_hub is True
    assert back.relay_depth == 3


def test_cohort_validation_rejects_bad_specs():
    from repro.scenario.spec import ScenarioError
    from repro.configs.base import FLConfig
    with pytest.raises(ScenarioError):
        FLConfig(num_clients=10, mode="fedbuff",
                 cohort_k=11).to_scenario().validate()
    with pytest.raises(ScenarioError):
        FLConfig(num_clients=10, mode="sync",
                 cohort_k=5).to_scenario().validate()
