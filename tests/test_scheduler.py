"""Event-driven FL runtime: determinism, sync-equivalence, topology.

Covers the scheduler invariants the async modes rely on:
* the event loop replays the exact same trace for the same deployment;
* FedBuff with buffer K = n_clients and staleness weight ≡ 1 produces the
  same global model as one synchronous FedAvg round;
* hierarchical (relay) aggregation is numerically flat FedAvg;
* semi-sync folds stragglers into later rounds instead of dropping them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Fabric, ObjectStore, TensorPayload, VirtualPayload,
                        make_backend)
from repro.scenario import TopologySpec
from repro.core.netsim import NCAL
from repro.data import make_silo_datasets
from repro.fl import (FedBuffStrategy, FLClient, FLScheduler, FLServer,
                      HierarchicalStrategy, SemiSyncStrategy)
from repro.fl.scheduler import EventLoop

N_FEATURES = 8 * 8 * 3
N_CLASSES = 4


def _linear_train_fn():
    @jax.jit
    def train_fn(params, batch):
        def loss_fn(p):
            x = batch["images"].reshape(batch["images"].shape[0], -1)
            logits = x @ p["w"] + p["b"]
            onehot = jax.nn.one_hot(batch["labels"], N_CLASSES)
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot,
                                     axis=-1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
        return new, loss
    return train_fn


def _init_params():
    return {"w": jnp.zeros((N_FEATURES, N_CLASSES), jnp.float32),
            "b": jnp.zeros((N_CLASSES,), jnp.float32)}


def _deployment(backend="grpc", env_name="lan", n=4, *, live=True, seed=0,
                sim_train_s=5.0, straggle=None):
    env = TopologySpec.preset(env_name, num_clients=n).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    silos = (make_silo_datasets(n, kind="image", examples_per_silo=24,
                                num_classes=N_CLASSES, image_size=8,
                                seed=seed) if live else None)
    clients = []
    for i, host in enumerate(env.clients):
        cb = make_backend(backend, env, fabric, host.host_id, store=store)
        if live:
            # sim_train_s keeps the simulated clock deterministic (jit
            # compile wall time must not reorder event-driven arrivals)
            c = FLClient(host.host_id, cb, dataset=silos[i],
                         train_fn=_linear_train_fn(), batch_size=8,
                         sim_train_s=sim_train_s, seed=seed + i)
        else:
            c = FLClient(host.host_id, cb, sim_train_s=sim_train_s)
        if straggle and host.host_id in straggle:
            c.straggle_factor = straggle[host.host_id]
        clients.append(c)
    sb = make_backend(backend, env, fabric, "server", store=store)
    return sb, clients


# ---------------------------------------------------------------------------
# event loop / determinism
# ---------------------------------------------------------------------------

def test_event_loop_orders_by_time_then_insertion():
    loop = EventLoop()
    seen = []
    loop.call_at(2.0, "b", lambda now: seen.append("b"))
    loop.call_at(1.0, "a", lambda now: seen.append("a"))
    loop.call_at(2.0, "c", lambda now: seen.append("c"))  # tie: after b
    loop.run()
    assert seen == ["a", "b", "c"]
    assert [name for _, name in loop.trace] == ["a", "b", "c"]


def test_event_loop_never_schedules_into_the_past():
    loop = EventLoop()
    times = []

    def late(now):
        loop.call_at(now - 5.0, "x", lambda t: times.append(t))

    loop.call_at(10.0, "late", late)
    loop.run()
    assert times == [10.0]  # clamped to the current clock


def _sim_run(max_agg=5):
    sb, clients = _deployment("grpc", "geo_distributed", 7, live=False,
                              straggle={"client6": 3.0})
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=3, staleness_exponent=0.5),
                        local_steps=1)
    sched.run(VirtualPayload(32 << 20, tag="det"), max_aggregations=max_agg)
    return sched


def test_event_ordering_is_deterministic_across_runs():
    a, b = _sim_run(), _sim_run()
    assert a.loop.trace == b.loop.trace
    assert [(e.time, e.version, e.n_updates) for e in a.agg_log] == \
           [(e.time, e.version, e.n_updates) for e in b.agg_log]
    assert a.update_log == b.update_log


# ---------------------------------------------------------------------------
# numerical equivalences
# ---------------------------------------------------------------------------

def test_fedbuff_full_buffer_equals_sync_fedavg():
    """K = n_clients + staleness weight ≡ 1 + server_lr 1 is sync FedAvg."""
    n = 4
    sb, clients = _deployment("grpc", "lan", n, live=True)
    server = FLServer(sb, clients, local_steps=2)
    server.run_round(TensorPayload(_init_params()))
    sync_params = server.global_params

    sb2, clients2 = _deployment("grpc", "lan", n, live=True)
    sched = FLScheduler(
        sb2, clients2, FedBuffStrategy(buffer_k=n, staleness_exponent=0.0),
        local_steps=2)
    sched.run(TensorPayload(_init_params()), max_aggregations=1)
    for k in sync_params:
        np.testing.assert_allclose(np.asarray(sched.global_params[k]),
                                   np.asarray(sync_params[k]), atol=1e-5)


def test_hierarchical_aggregation_matches_flat_fedavg():
    """Relay-local FedAvg + weighted hub FedAvg == flat FedAvg (8 clients
    round-robin over 7 regions: one region carries two silos)."""
    n = 8
    sb, clients = _deployment("grpc", "geo_distributed", n, live=True)
    server = FLServer(sb, clients, local_steps=2)
    server.run_round(TensorPayload(_init_params()))
    flat_params = server.global_params

    sb2, clients2 = _deployment("grpc", "geo_distributed", n, live=True)
    sched = FLScheduler(sb2, clients2,
                        HierarchicalStrategy(staleness_exponent=0.0),
                        local_steps=2)
    rep = sched.run(TensorPayload(_init_params()), max_aggregations=1)
    assert rep.n_client_updates == n  # every silo folded through its relay
    for k in flat_params:
        np.testing.assert_allclose(np.asarray(sched.global_params[k]),
                                   np.asarray(flat_params[k]), atol=1e-4)


# ---------------------------------------------------------------------------
# async semantics
# ---------------------------------------------------------------------------

def test_semisync_folds_stragglers_instead_of_dropping():
    straggler = "client3"
    sb, clients = _deployment("grpc", "geo_distributed", 4, live=False,
                              straggle={straggler: 3.0})
    sched = FLScheduler(
        sb, clients,
        SemiSyncStrategy(quorum_fraction=0.5, round_deadline_s=30.0,
                         staleness_exponent=0.25),
        local_steps=1)
    rep = sched.run(VirtualPayload(16 << 20, tag="semi"),
                    max_aggregations=6)
    stragler_arrivals = [s for (_, cid, s) in sched.update_log
                         if cid == straggler]
    assert stragler_arrivals, "straggler update never surfaced"
    assert max(stragler_arrivals) >= 1  # merged late, with staleness
    assert rep.n_discarded == 0  # folded into later rounds, never dropped
    assert rep.n_aggregations == 6


def test_fedbuff_staleness_discount_reduces_effective_weight():
    sb, clients = _deployment("grpc", "geo_distributed", 4, live=False,
                              straggle={"client2": 10.0})
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=2, staleness_exponent=0.5),
                        local_steps=1)
    rep = sched.run(VirtualPayload(16 << 20, tag="stale"),
                    max_aggregations=6)
    assert rep.mean_staleness > 0
    assert rep.effective_updates < rep.n_client_updates


def test_fedbuff_max_staleness_discards():
    sb, clients = _deployment("grpc", "geo_distributed", 4, live=False,
                              straggle={"client2": 50.0})
    sched = FLScheduler(
        sb, clients,
        FedBuffStrategy(buffer_k=2, staleness_exponent=0.5, max_staleness=1),
        local_steps=1)
    rep = sched.run(VirtualPayload(16 << 20, tag="cap"), max_aggregations=6)
    assert rep.n_discarded >= 1


def test_adaptive_staleness_off_matches_fixed_polynomial():
    """staleness_adaptive=False is the exact (1+s)^-a discount, even with
    a populated observation window."""
    from repro.fl.aggregator import staleness_weight
    s = FedBuffStrategy(buffer_k=2, staleness_exponent=0.5)
    for obs in [0, 1, 4, 9, 2]:
        s.observe(obs)
    for st in [0, 1, 3, 9]:
        assert s.staleness_weight(st) == staleness_weight(st, 0.5)


def test_adaptive_staleness_scales_exponent_by_percentile():
    from repro.fl.aggregator import staleness_weight
    s = FedBuffStrategy(buffer_k=2, staleness_exponent=0.5,
                        staleness_adaptive=True)
    for obs in [0, 1, 2, 3, 8, 9]:
        s.observe(obs)
    # staler than most observed -> rank ~1 -> exponent ~1.5a (harsher)
    assert s.staleness_weight(9) < staleness_weight(9, 0.5)
    # fresher than everything -> rank ~1/6 -> exponent < a (gentler)
    assert s.staleness_weight(0.5) > staleness_weight(0.5, 0.5)
    # the adaptive exponent stays in the a/2 .. 3a/2 band (weights shrink
    # as the exponent grows)
    assert staleness_weight(9, 0.25) >= s.staleness_weight(9) >= \
        staleness_weight(9, 0.75)
    s.observe(10)  # rank of 9 drops below 1.0: still inside the band
    assert staleness_weight(9, 0.25) >= s.staleness_weight(9) >= \
        staleness_weight(9, 0.75)


def test_adaptive_staleness_end_to_end_discounts_more():
    """With a heavy straggler, percentile-adaptive discounting weighs the
    stale tail harder than the fixed exponent run."""
    def run(adaptive):
        sb, clients = _deployment("grpc", "geo_distributed", 4, live=False,
                                  straggle={"client2": 10.0})
        sched = FLScheduler(
            sb, clients,
            FedBuffStrategy(buffer_k=2, staleness_exponent=0.5,
                            staleness_adaptive=adaptive),
            local_steps=1)
        return sched.run(VirtualPayload(16 << 20, tag="ad"),
                         max_aggregations=6)
    fixed, adaptive = run(False), run(True)
    assert fixed.n_client_updates == adaptive.n_client_updates
    assert adaptive.effective_updates != fixed.effective_updates


def test_hierarchical_qsgd_wan_hop_matches_flat_within_tolerance():
    """Compression on the relay WAN hop only: the hub merges dequantised
    partials, so multi-round hier+qsgd tracks flat FedAvg within the
    quantisation band (error feedback prevents drift accumulation)."""
    n = 8
    rounds = 2
    sb, clients = _deployment("grpc", "geo_distributed", n, live=True)
    server = FLServer(sb, clients, local_steps=2)
    params = _init_params()
    for _ in range(rounds):
        server.run_round(TensorPayload(params))
        params = server.global_params

    sb2, clients2 = _deployment("grpc", "geo_distributed", n, live=True)
    strat = HierarchicalStrategy(staleness_exponent=0.0,
                                 wan_compression="qsgd")
    sched = FLScheduler(sb2, clients2, strat, local_steps=2)
    sched.run(TensorPayload(_init_params()), max_aggregations=rounds)

    upd = max(float(np.max(np.abs(np.asarray(params[k])))) for k in params)
    tol = max(8.0 * upd / 127.0, 1e-4)
    for k in params:
        np.testing.assert_allclose(np.asarray(sched.global_params[k]),
                                   np.asarray(params[k]), atol=tol)
    # error-feedback residuals (on the relay backends' channels) stay in
    # the quantisation band
    states = strat.wan_ef_states()
    assert states, "relay channels carry no error-feedback state"
    for state in states:
        assert float(np.max(np.abs(np.asarray(state.error)))) <= tol


def test_async_run_requires_a_bound():
    sb, clients = _deployment("grpc", "lan", 2, live=False)
    sched = FLScheduler(sb, clients, FedBuffStrategy(buffer_k=2))
    with pytest.raises(ValueError):
        sched.run(VirtualPayload(1 << 20))


def test_run_async_entrypoint_reports_throughput():
    sb, clients = _deployment("grpc", "lan", 3, live=False)
    server = FLServer(sb, clients, local_steps=1)
    report, sched = server.run_async(
        VirtualPayload(8 << 20, tag="ep"),
        FedBuffStrategy(buffer_k=3, staleness_exponent=0.0),
        max_aggregations=2)
    assert report.n_aggregations == 2
    assert report.aggregations_per_hour > 0
    # span covers the final merge, which completes after the stop event
    assert report.sim_time >= sched.loop.now > 0
    assert report.sim_time == pytest.approx(sched.agg_log[-1].time)
