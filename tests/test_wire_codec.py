"""Wire-domain byte codec (zlib) + receiver-driven NACK timing."""
import numpy as np
import pytest

from repro.compression.stages import ZlibCodec, make_codec
from repro.core.channel import (WireCompressStage, make_channel)
from repro.core.message import TensorPayload, VirtualPayload
from repro.core.netsim import BAHRAIN, LAN_TCP, Link, LinkFaultModel


def _tree():
    return {"w": np.linspace(0., 1., 2048, dtype=np.float32).reshape(32, 64),
            "b": np.arange(32, dtype=np.float32)}


# ---------------------------------------------------------------------------
# codec parsing / placement
# ---------------------------------------------------------------------------

def test_make_codec_parses_zlib_levels():
    assert make_codec("zlib").level == 6
    assert make_codec("zlib:9").level == 9
    assert make_codec("zlib").domain == "wire"
    with pytest.raises(KeyError):
        make_codec("zlib:11")


def test_wire_stage_rejects_payload_codecs():
    with pytest.raises(ValueError, match="wire-domain"):
        WireCompressStage(make_codec("qsgd"))


def test_compression_flag_routes_byte_codec_to_wire_slot():
    """--compression zlib builds the same stack as wire_codec=zlib."""
    a = make_channel("protobuf", compression="zlib:6")
    b = make_channel("protobuf", wire_codec="zlib:6")
    assert a.signature() == b.signature()
    assert "zlib(l6)" in a.signature()
    with pytest.raises(ValueError, match="two wire codecs"):
        make_channel("protobuf", compression="zlib:6", wire_codec="zlib:9")


# ---------------------------------------------------------------------------
# lossless roundtrip + provenance decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("serializer", ["generic", "protobuf", "membuff"])
def test_zlib_roundtrip_is_exact_per_serializer(serializer):
    ch = make_channel(serializer, wire_codec="zlib")
    enc = ch.encode(TensorPayload(_tree()))
    assert enc.wire.nbytes < TensorPayload(_tree()).nbytes  # really smaller
    # a receiver with NO codec configured decodes by provenance
    plain = make_channel(serializer)
    payload, cost = plain.decode(enc.wire)
    for k, v in _tree().items():
        np.testing.assert_array_equal(np.asarray(payload.tree[k]), v)
    assert cost > 0


def test_zlib_virtual_wire_scales_and_restores():
    ch = make_channel("protobuf", wire_codec="zlib")
    enc = ch.encode(VirtualPayload(10 << 20, tag="v"))
    assert enc.wire.nbytes == int(round((10 << 20) * ZlibCodec.WIRE_RATIO))
    payload, _ = make_channel("protobuf").decode(enc.wire)
    assert payload.nbytes == 10 << 20 and payload.tag == "v"


def test_zlib_composes_with_qsgd_and_chunking():
    ch = make_channel("protobuf", compression="qsgd", wire_codec="zlib",
                      chunk_bytes=1 << 20)
    enc = ch.encode(VirtualPayload(8 << 20, tag="big"), peer="p")
    kinds = [i.get("stage", "compress") for i in enc.wire.stages]
    assert kinds == ["compress", "serialize", "wirecodec", "chunk"]
    assert enc.chunks and len(enc.chunks) >= 2
    payload, _ = make_channel("protobuf").decode(enc.wire)
    assert payload.nbytes == 8 << 20


def test_decode_time_matches_decode_cost():
    ch = make_channel("generic", wire_codec="zlib")
    enc = ch.encode(TensorPayload(_tree()))
    rx = make_channel("generic")
    _, cost = rx.decode(enc.wire)
    assert rx.decode_time(enc.wire) == pytest.approx(cost)


def test_default_stack_signature_unchanged():
    """No codec, no chunking -> the exact pre-stack channel identity."""
    assert make_channel("protobuf").signature() == "protobuf"


# ---------------------------------------------------------------------------
# receiver-driven NACK timing
# ---------------------------------------------------------------------------

def test_detect_delay_derives_from_the_graph_edge():
    fm = LinkFaultModel(chunk_loss_rate=0.5)
    wan = Link("a", "b", BAHRAIN)
    lan = Link("a", "b", LAN_TCP)
    # one RTT of *that edge*: gap noticed one-way late + NACK one-way back
    assert fm.detect_delay(wan) == pytest.approx(2 * BAHRAIN.latency)
    assert fm.detect_delay(lan) == pytest.approx(2 * LAN_TCP.latency)
    assert fm.detect_delay(wan) > 100 * fm.detect_delay(lan)
    slow = LinkFaultModel(chunk_loss_rate=0.5, nack_rtts=2.0)
    assert slow.detect_delay(wan) == pytest.approx(4 * BAHRAIN.latency)


# ---------------------------------------------------------------------------
# zstd slot: real binding when importable, graceful zlib byte fallback
# ---------------------------------------------------------------------------

def test_make_codec_parses_zstd_levels():
    assert make_codec("zstd").level == 3
    assert make_codec("zstd:19").level == 19
    assert make_codec("zstd").domain == "wire"
    with pytest.raises(KeyError):
        make_codec("zstd:20")


def test_zstd_fallback_records_actual_impl_in_provenance():
    """Whatever byte transform ran, the wire says so — a receiver
    inverts by provenance, never by its local codec configuration."""
    from repro.compression.stages import ZstdCodec, zstd_binding
    codec = make_codec("zstd")
    expect = "zstd" if zstd_binding() is not None else "zlib"
    assert codec.impl == expect
    ch = make_channel("protobuf", wire_codec="zstd")
    enc = ch.encode(TensorPayload(_tree()))
    steps = [s for s in enc.wire.stages if s["stage"] == "wirecodec"]
    assert steps and steps[0]["impl"] == expect
    # zstd-class *modelled* constants are fixed per codec name, not per
    # binding: cached sweep results can't depend on what's pip-installed
    assert ZstdCodec().enc_bw != ZlibCodec().enc_bw


def test_zstd_roundtrip_is_exact_even_without_binding():
    ch = make_channel("protobuf", wire_codec="zstd:5")
    enc = ch.encode(TensorPayload(_tree()))
    assert enc.wire.nbytes < TensorPayload(_tree()).nbytes
    plain = make_channel("protobuf")  # provenance-driven decode
    payload, _ = plain.decode(enc.wire)
    for k, v in _tree().items():
        np.testing.assert_array_equal(np.asarray(payload.tree[k]), v)


def test_zstd_real_binding_roundtrip():
    from repro.compression.stages import zstd_binding
    if zstd_binding() is None:
        pytest.skip("no zstd binding ('zstandard'/'zstd') importable in "
                    "this environment; byte path covered by the zlib "
                    "fallback tests above")
    compress, decompress = zstd_binding()
    raw = np.linspace(0., 1., 4096, dtype=np.float32).tobytes()
    assert decompress(compress(raw, 3)) == raw
    ch = make_channel("protobuf", wire_codec="zstd")
    enc = ch.encode(TensorPayload(_tree()))
    steps = [s for s in enc.wire.stages if s["stage"] == "wirecodec"]
    assert steps[0]["impl"] == "zstd"
