"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fedavg_reduce import COL_TILE, fedavg_reduce, fedavg_reduce_q8
from repro.kernels.quantize import ROW_TILE, dequantize_blocks, quantize_blocks


@pytest.mark.parametrize("rows,block", [(8, 128), (16, 256), (32, 512),
                                        (8, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(rows, block, dtype, rng):
    x = jnp.asarray(rng.normal(size=(rows, block)) * 3).astype(dtype)
    q, s = quantize_blocks(x, interpret=True)
    qr, sr = ref.quantize_blocks_ref(x)
    # interpret-mode vs jit f32 contraction order can flip exact .5 ties
    # for bf16 inputs: allow 1 quantisation level there, exact otherwise
    if dtype == jnp.bfloat16:
        assert np.max(np.abs(np.asarray(q, np.int32)
                             - np.asarray(qr, np.int32))) <= 1
    else:
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = dequantize_blocks(q, s, interpret=True)
    xdr = ref.dequantize_blocks_ref(q, sr)  # same q: dequant parity
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xdr), rtol=1e-6)


def test_quantize_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    q, s = quantize_blocks(x, interpret=True)
    xd = dequantize_blocks(q, s, interpret=True)
    # error per element bounded by scale/2 = amax/254
    amax = np.max(np.abs(np.asarray(x)), axis=1, keepdims=True)
    assert np.all(np.abs(np.asarray(xd - x)) <= amax / 254 + 1e-7)


def test_quantize_zero_block():
    x = jnp.zeros((8, 256), jnp.float32)
    q, s = quantize_blocks(x, interpret=True)
    assert np.all(np.asarray(q) == 0)
    xd = dequantize_blocks(q, s, interpret=True)
    assert np.all(np.asarray(xd) == 0)


@pytest.mark.parametrize("n,t", [(2, COL_TILE), (5, 2 * COL_TILE),
                                 (16, 4 * COL_TILE)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_matches_ref(n, t, dtype, rng):
    u = jnp.asarray(rng.normal(size=(n, t))).astype(dtype)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))
    out = fedavg_reduce(u, w, interpret=True)
    expect = ref.fedavg_reduce_ref(u, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("n,t,block", [(3, COL_TILE, 256), (7, 2 * COL_TILE, 512)])
def test_fedavg_q8_matches_ref(n, t, block, rng):
    qs, ss = [], []
    for i in range(n):
        x = jnp.asarray(rng.normal(size=(t,)).astype(np.float32))
        p = ops.quantize_flat(x, block=block, interpret=True)
        qs.append(p["q"])
        ss.append(p["scales"])
    q, s = jnp.stack(qs), jnp.stack(ss)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))
    out = fedavg_reduce_q8(q, s, w, block=block, interpret=True)
    expect = ref.fedavg_reduce_q8_ref(q, s, w, block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_pytree_aggregate_weighted_mean(rng):
    t1 = {"a": jnp.ones((37, 5)), "b": jnp.zeros((9,))}
    t2 = {"a": jnp.zeros((37, 5)), "b": jnp.ones((9,))}
    agg = ops.fedavg_aggregate([t1, t2], [3.0, 1.0])
    np.testing.assert_allclose(np.asarray(agg["a"]), 0.75, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(agg["b"]), 0.25, rtol=1e-5)


def test_flatten_roundtrip_mixed_dtypes(rng):
    tree = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)).astype(jnp.bfloat16)}
    flat, unflatten = ops.flatten_pytree(tree)
    rec = unflatten(flat)
    assert rec["b"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(rec["w"]), np.asarray(tree["w"]))
