"""Vertical / split FL subsystem (fl/vertical.py + the mode plumbing).

Covers: split == unsplit forward/backward parity across three zoo
families and two cut depths; per-direction error-feedback state on the
compressed activation path; chunk-loss retransmit completing every
batch; SplitSpec JSON round-trip; CLI override precedence for
--cut-layer; the loud unknown-mode errors; the weighted fair-share
admission formula; cross-job object-store dedup; and the benchmark
registry's loud discovery error.
"""
import json
import sys
import types

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.fl.vertical import SplitPlan, bottom_fraction, sim_activation_nbytes
from repro.models.transformer import TransformerLM
from repro.models.vision import (MobileNetConfig, MobileNetV3, ResNet,
                                 ResNetConfig)
from repro.scenario import (ChannelSpec, FaultSpec, FleetSpec, Scenario,
                            ScenarioError, SplitSpec, StrategySpec,
                            TopologySpec)

# ---------------------------------------------------------------------------
# split == unsplit parity, three zoo families x two cut depths
# ---------------------------------------------------------------------------

TOL = 1e-5


def _resnet():
    model = ResNet(ResNetConfig(name="r-test", widths=(8, 16),
                                blocks_per_stage=2, num_classes=5,
                                image_size=8))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3)),
             "labels": jnp.array([0, 3])}
    return model, batch


def _mobilenet():
    model = MobileNetV3(MobileNetConfig(
        name="m-test", blocks=((1, 8, 1, False), (4, 12, 2, True),
                               (3, 12, 1, False)),
        stem=8, head=24, classifier=16, num_classes=5, image_size=8))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 3)),
             "labels": jnp.array([1, 4])}
    return model, batch


def _transformer():
    model = TransformerLM(ModelConfig(
        name="t-test", family="dense", num_layers=4, d_model=16,
        num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=31,
        dtype="float32", param_dtype="float32"))
    tok = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, 31)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=1)}
    return model, batch


@pytest.mark.parametrize("family", ["resnet", "mobilenet", "transformer"])
@pytest.mark.parametrize("cut", [1, 2])
def test_split_parity_forward_backward(family, cut):
    model, batch = {"resnet": _resnet, "mobilenet": _mobilenet,
                    "transformer": _transformer}[family]()
    params = model.init(jax.random.PRNGKey(0))
    if family == "transformer":
        params, _axes = params  # TransformerLM.init returns (params, axes)
    plan = SplitPlan(model, cut_layer=cut)
    assert 1 <= cut <= plan.n_units - 1

    ref_loss, ref_g = jax.value_and_grad(
        lambda p: model.loss(p, batch)[0])(params)
    bottom, top = plan.split_params(params)
    split_loss, (g_b, g_t) = jax.value_and_grad(
        lambda b, t: plan.loss(b, t, batch)[0], argnums=(0, 1))(bottom, top)
    assert abs(float(ref_loss) - float(split_loss)) <= TOL
    merged_g = plan.merge_params(g_b, g_t)
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(merged_g)):
        assert float(jnp.max(jnp.abs(a - b))) <= TOL
    # the parameter split is an exact round trip
    re = plan.merge_params(bottom, top)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(re)):
        assert a is b or bool(jnp.all(a == b))


def test_split_plan_rejects_out_of_range_cut():
    model, _ = _resnet()
    with pytest.raises(ValueError, match="cut_layer"):
        SplitPlan(model, cut_layer=0)
    with pytest.raises(ValueError, match="cut_layer"):
        SplitPlan(model, cut_layer=99)


# ---------------------------------------------------------------------------
# live compressed activations: per-direction error-feedback state
# ---------------------------------------------------------------------------

def test_live_qsgd_activation_error_feedback_per_direction():
    from repro.core.message import VirtualPayload
    from repro.launch.fl_train import _vertical_strategy, build_deployment

    sc = Scenario(
        name="vert-ef",
        topology=TopologySpec(kind="lan", num_clients=2),
        fleet=FleetSpec(tier="small", local_steps=1),
        channel=ChannelSpec(backend="grpc"),
        strategy=StrategySpec(mode="vertical", rounds=1),
        split=SplitSpec(cut_layer=1, batches_per_round=2,
                        activation_codec="qsgd")).validate()
    fl_cfg = sc.fl_config()
    server, params, env, store = build_deployment(
        fl_cfg, tier=sc.fleet.tier, local_steps=sc.fleet.local_steps,
        scenario=sc)
    strategy = _vertical_strategy(fl_cfg, server, params, sc)
    report, sched = server.run_async(
        VirtualPayload(strategy.activation_nbytes, tag="vert-ef"),
        strategy, availability=None, cohort_k=0, cohort_seed=0,
        streaming_hub=False, max_aggregations=1)
    assert report.n_aggregations == 1

    # activations ride UP on each client's channel: one residual stream
    # keyed by the server peer
    for c in server.clients:
        state = c.backend.channel.compress_stage._state
        assert set(state) == {"server"}, (
            f"client {c.client_id} EF streams: {sorted(state)}")
    # activation gradients ride DOWN on the server's channel: one
    # residual stream per feature party
    down = sched.backend.channel.compress_stage._state
    assert set(down) == {c.client_id for c in server.clients}
    # a real quantization loop ran: every batch produced a live loss
    assert all(ev.loss is not None for ev in sched.agg_log)


# ---------------------------------------------------------------------------
# chunk loss on the activation path: retransmits, every batch completes
# ---------------------------------------------------------------------------

def test_chunk_loss_retransmit_completes_every_batch():
    from repro.sweep.runners import run_scenario

    n_rounds, n_clients, bpr = 2, 3, 4
    sc = Scenario(
        name="vert-loss",
        topology=TopologySpec(kind="geo_distributed",
                              num_clients=n_clients),
        fleet=FleetSpec(tier="small"),
        channel=ChannelSpec(backend="grpc", chunk_mb=0.05),
        faults=FaultSpec(link_loss=0.05),
        strategy=StrategySpec(mode="vertical", rounds=n_rounds),
        split=SplitSpec(cut_layer=1, batches_per_round=bpr))
    out = run_scenario(sc)
    assert out["n_rounds"] == n_rounds
    # lossy chunked activation wires actually retransmitted
    assert out["retransmits"] > 0
    # ... and every batch of every round still completed: nothing was
    # discarded, and each aggregation saw every party's full batch count
    assert out["n_discarded"] == 0
    for rep in out["round_reports"]:
        assert rep["n_updates"] == n_clients


# ---------------------------------------------------------------------------
# SplitSpec serialization + CLI plumbing
# ---------------------------------------------------------------------------

def test_split_spec_json_round_trip():
    sc = Scenario(name="vert-json",
                  strategy=StrategySpec(mode="vertical", rounds=4),
                  split=SplitSpec(cut_layer=3, batches_per_round=5,
                                  activation_codec="topk:0.1"))
    sc2 = Scenario.from_json(sc.to_json())
    assert sc2 == sc
    assert sc2.split == SplitSpec(cut_layer=3, batches_per_round=5,
                                  activation_codec="topk:0.1")
    # unknown split keys stay loud
    bad = json.loads(sc.to_json())
    bad["split"]["cut_depth"] = 1
    with pytest.raises(ScenarioError, match="cut_depth"):
        Scenario.from_json(json.dumps(bad))


def test_cli_cut_layer_override_precedence(tmp_path):
    from repro.launch.fl_train import _parser, resolve_scenario

    spec = tmp_path / "vert.json"
    sc = Scenario(name="vert-cli",
                  strategy=StrategySpec(mode="vertical"),
                  split=SplitSpec(cut_layer=2, batches_per_round=6,
                                  activation_codec="qsgd"))
    spec.write_text(sc.to_json())
    ap = _parser()
    # unset flag -> the loaded spec's value survives
    args = ap.parse_args(["--scenario", str(spec)])
    assert resolve_scenario(args, ap).split.cut_layer == 2
    # explicit flag wins over the loaded spec
    args = ap.parse_args(["--scenario", str(spec), "--cut-layer", "3",
                          "--batches-per-round", "2",
                          "--activation-codec", "none"])
    got = resolve_scenario(args, ap)
    assert got.split.cut_layer == 3
    assert got.split.batches_per_round == 2
    assert got.split.activation_codec == "none"


# ---------------------------------------------------------------------------
# unknown mode: the loud, path-carrying error
# ---------------------------------------------------------------------------

def test_unknown_mode_error_lists_valid_modes():
    from repro.fl import make_strategy
    from repro.scenario.spec import MODES

    sc = Scenario(name="bad-mode", strategy=StrategySpec(mode="warp"))
    with pytest.raises(ScenarioError) as ei:
        sc.validate()
    msg = str(ei.value)
    assert "strategy.mode: unknown mode 'warp'" in msg
    for m in MODES:
        assert m in msg

    cfg = Scenario(name="ok").fl_config()
    cfg = type(cfg)(**{**cfg.__dict__, "mode": "warp"})
    with pytest.raises(KeyError) as ei:
        make_strategy(cfg, 4)
    assert "unknown scheduler mode 'warp'" in str(ei.value)
    assert "'vertical'" in str(ei.value)


# ---------------------------------------------------------------------------
# admission-weighted fair share
# ---------------------------------------------------------------------------

def test_weighted_fair_share_grant_formula():
    from repro.core.transport import _EdgePipe

    cap = 8e6
    # unit weights: bit-identical to the historic cap / k grant
    pipe = _EdgePipe(cap, "fair-share")
    pipe.reserve(0.0, 10.0, cap, 0, "b")
    assert pipe.available(5.0, job="a") == cap / 2
    # 3:1 weights: the guaranteed slice scales to cap * w / sum(w)
    weights = {"a": 3.0, "b": 1.0}
    pipe = _EdgePipe(cap, "fair-share",
                     weight_of=lambda j: weights.get(j, 1.0))
    pipe.reserve(0.0, 10.0, cap, 0, "b")
    assert pipe.available(5.0, job="a") == cap * 3.0 / 4.0
    assert pipe.available(15.0, job="a") == cap  # alone -> full cap


def test_job_weight_validated_and_default_is_noop():
    from repro.core.netsim import NCAL
    from repro.core.transport import Fabric
    from repro.scenario import TopologySpec

    env = TopologySpec(kind="lan", num_clients=1).build()
    fabric = Fabric(env)
    with pytest.raises(ValueError, match="weight"):
        fabric.job("bad", weight=0.0)
    h = fabric.job("ok")
    assert h.weight == 1.0
    assert fabric._job_weight("ok") == 1.0
    assert fabric._job_weight("never-registered") == 1.0


def test_multiscenario_rejects_nonpositive_weight():
    from repro.scenario import FabricSpec, JobSpec, MultiScenario

    sc = Scenario(name="w", strategy=StrategySpec(mode="fedbuff", rounds=1))
    ms = MultiScenario(name="bad-w", fabric=FabricSpec(),
                       jobs=(JobSpec("a", sc, weight=-1.0),))
    with pytest.raises(ScenarioError, match="weight"):
        ms.validate()


# ---------------------------------------------------------------------------
# cross-job object-store dedup
# ---------------------------------------------------------------------------

def test_cross_job_store_dedup_counts_hits():
    from repro.core.backends import make_backend
    from repro.core.message import FLMessage, VirtualPayload
    from repro.core.netsim import NCAL
    from repro.core.objectstore import ObjectStore
    from repro.core.transport import Fabric

    env = TopologySpec(kind="geo_distributed", num_clients=2).build()
    fabric = Fabric(env)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    store = ObjectStore(NCAL)
    be = {name: make_backend("grpc+s3", env, fabric, "server", store=store,
                             job=fabric.job(name))
          for name in ("jobA", "jobB")}
    payload = VirtualPayload(50 << 20, tag="shared-base-model")

    def send(job, t):
        be[job].isend(FLMessage(msg_type="model", sender="server",
                                receiver=env.clients[0].host_id, round=0,
                                payload=payload), t)

    send("jobA", 0.0)   # fresh PUT
    send("jobB", 1.0)   # cross-tenant content hit
    send("jobB", 2.0)   # jobB's own per-instance cache, NOT cross-job
    assert store.stats["puts"] == 1
    assert store.stats["cache_hits"] == 2
    assert fabric.stats_for("jobB")["cross_job_hits"] == 1
    assert fabric.stats_for("jobA")["cross_job_hits"] == 0
    # the global view is the exact sum of the per-job views
    assert fabric.stats["cross_job_hits"] == sum(
        fabric.stats_for(j)["cross_job_hits"] for j in ("jobA", "jobB"))
    # ... and the stats surface the count under the CellResult name
    from repro.sweep.runners import wire_stats
    assert wire_stats(fabric, store, job="jobB")["n_cross_job_hits"] == 1.0


# ---------------------------------------------------------------------------
# sizing helpers + registry discovery stays loud
# ---------------------------------------------------------------------------

def test_sizing_helpers_monotone():
    assert 0.05 <= bottom_fraction(1, 6) < bottom_fraction(5, 6) <= 0.95
    a1 = sim_activation_nbytes(100 << 20, 32, 1)
    a3 = sim_activation_nbytes(100 << 20, 32, 3)
    assert a1 > a3 >= 1024  # deeper cuts ship smaller activations


def test_registry_discovery_error_stays_loud():
    from benchmarks import registry

    mod = types.ModuleType("benchmarks._fake_not_a_study")
    sys.modules["benchmarks._fake_not_a_study"] = mod
    try:
        with pytest.raises(RuntimeError, match="neither STUDY nor run"):
            registry._entry("_fake_not_a_study")
    finally:
        del sys.modules["benchmarks._fake_not_a_study"]


def test_fig13_registered_in_quick_gate():
    from benchmarks.fig13_vertical import STUDY

    assert STUDY.in_quick
    assert STUDY.out == "fig13_vertical.json"
