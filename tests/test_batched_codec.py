"""Batched codec API: kernel-impl parity, encode_many == per-item encode
(wire bytes / charges / error-feedback state), roofline character."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.stages import QsgdCodec
from repro.core.channel import encode_many, make_channel
from repro.core.message import TensorPayload
from repro.kernels import ops, ref
from repro.kernels.quantize import ROW_TILE


def _flats(rng, lengths=(100, 2048, 2048 * 3 + 17)):
    return [jnp.asarray(rng.normal(size=n).astype(np.float32) * 3)
            for n in lengths]


def _trees(rng, n=3):
    return [{"w": rng.normal(size=(16 + i, 64)).astype(np.float32),
             "b": rng.normal(size=16 + i).astype(np.float32)}
            for i in range(n)]


def _wire_bytes(wire):
    return b"".join(
        bytes(b) if isinstance(b, (bytes, bytearray))
        else np.asarray(b).tobytes() for b in (wire.buffers or []))


# ---------------------------------------------------------------------------
# kernel parity: Pallas interpreter vs jitted ref vs NumPy twin
# ---------------------------------------------------------------------------

def test_quantize_flat_batch_parity_three_impls(rng):
    """One fused dispatch == per-item quantize, across every impl; the
    wire-critical int8 values agree bit-for-bit across all three impls
    on f32 input. Scales may differ by 1 ULP between the NumPy twin and
    XLA-compiled paths (XLA rewrites the constant division ``amax/127``
    as a reciprocal multiply), so they are held to <=1 ULP cross-impl
    and exactly equal batched-vs-single within one impl."""
    flats = _flats(rng)
    block = 256
    by_impl = {}
    for interpret in (True, None):  # Pallas interpreter / CPU jitted ref
        batch = ops.quantize_flat_batch(flats, block=block,
                                        interpret=interpret)
        single = [ops.quantize_flat(x, block=block, interpret=interpret)
                  for x in flats]
        for pb, ps in zip(batch, single):
            np.testing.assert_array_equal(np.asarray(pb["q"]),
                                          np.asarray(ps["q"]))
            np.testing.assert_array_equal(np.asarray(pb["scales"]),
                                          np.asarray(ps["scales"]))
            assert pb["orig_len"] == ps["orig_len"]
        by_impl[interpret] = batch
    # the NumPy twin, fed the same per-item row-aligned padding
    mult = block * ROW_TILE
    for x, pk in zip(flats, by_impl[None]):
        xp = np.zeros(-(-x.size // mult) * mult, np.float32)
        xp[: x.size] = np.asarray(x)
        qn, sn = ref.quantize_blocks_np(xp.reshape(-1, block))
        np.testing.assert_array_equal(qn.reshape(-1), np.asarray(pk["q"]))
        np.testing.assert_array_almost_equal_nulp(
            sn.reshape(-1), np.asarray(pk["scales"]), nulp=1)
    # and the interpreter agrees with the jitted ref
    for pa, pb in zip(by_impl[True], by_impl[None]):
        np.testing.assert_array_equal(np.asarray(pa["q"]),
                                      np.asarray(pb["q"]))
        np.testing.assert_array_almost_equal_nulp(
            np.asarray(pa["scales"]), np.asarray(pb["scales"]), nulp=1)


def test_dequantize_flat_batch_roundtrip_and_mixed_blocks(rng):
    flats = _flats(rng)
    packed = ops.quantize_flat_batch(flats, block=256)
    outs = ops.dequantize_flat_batch(packed)
    for x, y in zip(flats, outs):
        assert np.asarray(y).shape == np.asarray(x).shape
        amax = np.max(np.abs(np.asarray(x)))
        assert np.max(np.abs(np.asarray(y) - np.asarray(x))) <= amax / 254 \
            + 1e-7
    # mixed block sizes fall back to the per-item path, same results
    mixed = [ops.quantize_flat(flats[0], block=128),
             ops.quantize_flat(flats[1], block=512)]
    a, b = ops.dequantize_flat_batch(mixed)
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(ops.dequantize_flat(mixed[0])))
    np.testing.assert_array_equal(
        np.asarray(b), np.asarray(ops.dequantize_flat(mixed[1])))


# ---------------------------------------------------------------------------
# codec + channel surface: fused == sequential, bit-for-bit
# ---------------------------------------------------------------------------

def test_qsgd_encode_batch_matches_compress_loop(rng):
    trees = _trees(rng)
    a, b = QsgdCodec(block=256), QsgdCodec(block=256)
    payloads = [TensorPayload(t) for t in trees]
    states = [a.init_state(p) for p in payloads]  # live EF residuals
    fused = a.encode_batch(payloads, states)
    seq = [b.compress(p, s) for p, s in zip(payloads, states)]
    for (pf, sf, inf_f), (ps, ss, inf_s) in zip(fused, seq):
        for k in ("q", "scales"):
            np.testing.assert_array_equal(np.asarray(pf.packed[k]),
                                          np.asarray(ps.packed[k]))
        assert inf_f == inf_s
        np.testing.assert_array_equal(np.asarray(sf.error),
                                      np.asarray(ss.error))


def test_encode_many_matches_per_item_encode(rng):
    """Fan-out round (distinct peers): fused wire bytes, provenance,
    charges and per-peer EF residuals all equal the sequential path."""
    trees = _trees(rng)
    fused_ch = make_channel("protobuf", compression="qsgd")
    seq_ch = make_channel("protobuf", compression="qsgd")
    peers = [f"c{i}" for i in range(len(trees))]
    for _round in range(2):  # second round exercises non-None EF state
        encs = encode_many([(fused_ch, TensorPayload(t), p)
                            for t, p in zip(trees, peers)])
        refs = [seq_ch.encode(TensorPayload(t), p)
                for t, p in zip(trees, peers)]
        for enc, exp in zip(encs, refs):
            assert _wire_bytes(enc.wire) == _wire_bytes(exp.wire)
            assert enc.wire.stages == exp.wire.stages
            assert enc.wire.nbytes == exp.wire.nbytes
            assert enc.cost_s == pytest.approx(exp.cost_s)
            assert [(n, a) for n, _, a in enc.charges] == \
                   [(n, a) for n, _, a in exp.charges]
    for p in peers:
        np.testing.assert_array_equal(
            np.asarray(fused_ch.compress_stage._state[p].error),
            np.asarray(seq_ch.compress_stage._state[p].error))


def test_encode_many_keeps_same_peer_stream_sequential(rng):
    """Two encodes to ONE peer chain through the same EF residual; fusing
    them would decouple the chain, so encode_many must not."""
    trees = _trees(rng, n=2)
    trees[1] = jax.tree.map(np.copy, trees[0])  # same shapes -> shared state
    fused_ch = make_channel("protobuf", compression="qsgd")
    seq_ch = make_channel("protobuf", compression="qsgd")
    encs = encode_many([(fused_ch, TensorPayload(t), "s3") for t in trees])
    refs = [seq_ch.encode(TensorPayload(t), "s3") for t in trees]
    for enc, exp in zip(encs, refs):
        assert _wire_bytes(enc.wire) == _wire_bytes(exp.wire)
    np.testing.assert_array_equal(
        np.asarray(fused_ch.compress_stage._state["s3"].error),
        np.asarray(seq_ch.compress_stage._state["s3"].error))


def test_channel_decode_batch_inverts_encode_batch(rng):
    ch = make_channel("protobuf", compression="qsgd", wire_codec="zlib")
    trees = _trees(rng)
    encs = ch.encode_batch([(TensorPayload(t), f"c{i}")
                            for i, t in enumerate(trees)])
    plain = make_channel("protobuf")  # decodes purely by provenance
    decoded = plain.decode_batch([e.wire for e in encs])
    for t, (payload, cost) in zip(trees, decoded):
        assert cost > 0
        for k in t:
            assert np.asarray(payload.tree[k]).shape == t[k].shape
    # batched decode == per-wire decode, element for element
    for enc, (payload, _) in zip(encs, decoded):
        single, _ = plain.decode(enc.wire)
        for k in payload.tree:
            np.testing.assert_array_equal(np.asarray(payload.tree[k]),
                                          np.asarray(single.tree[k]))


# ---------------------------------------------------------------------------
# roofline: the fused quantize stage is bandwidth-bound
# ---------------------------------------------------------------------------

def test_fused_quantize_stage_is_bandwidth_bound():
    from repro.roofline.hlo_cost import (arithmetic_intensity, entry_cost,
                                         is_bandwidth_bound)
    c = jax.jit(ref.quantize_blocks_ref).lower(
        jax.ShapeDtypeStruct((512, 256), jnp.float32)).compile()
    cost = entry_cost(c.as_text())
    ai = arithmetic_intensity(cost)
    assert np.isfinite(ai)
    assert is_bandwidth_bound(cost), (
        f"quantize stage should sit under the machine balance, got "
        f"intensity {ai:.1f} flops/byte")
