"""Checkpoint/restart: integrity, keep-k GC, async writes, reshard restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(v=1.0):
    return {"layer": {"w": jnp.full((8, 4), v), "b": jnp.zeros((4,))},
            "step_scale": jnp.asarray(0.5)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(2.0), meta={"note": "x"})
    restored, step, meta = load_checkpoint(d, _tree(0.0))
    assert step == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]), 2.0)


def test_checksum_detects_corruption(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    path = os.path.join(d, "step_000000001", "arrays.npz")
    data = dict(np.load(path))
    data["layer/w"] = data["layer/w"] + 1.0
    np.savez(path, **data)
    with pytest.raises(IOError):
        load_checkpoint(d, _tree())


def test_keep_last_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    from repro.checkpoint.ckpt import list_steps
    assert list_steps(str(tmp_path)) == [3, 4]
    restored, step, _ = mgr.restore(_tree())
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]), 4.0)


def test_async_write_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_writes=True)
    mgr.save(7, _tree(7.0))
    mgr.wait()
    restored, step, _ = mgr.restore(_tree())
    assert step == 7


def test_restore_with_new_sharding(tmp_path):
    """Elastic restart: restore onto an explicit (different) sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(3.0))
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"layer": {"w": NamedSharding(mesh, P("data")),
                    "b": NamedSharding(mesh, P())},
          "step_scale": NamedSharding(mesh, P())}
    restored, _, _ = load_checkpoint(d, _tree(), shardings=sh)
    assert restored["layer"]["w"].sharding == sh["layer"]["w"]
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]), 3.0)


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = {"layer": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))},
           "step_scale": jnp.asarray(0.0)}
    with pytest.raises(ValueError):
        load_checkpoint(d, bad)
