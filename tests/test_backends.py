"""Comm backend behaviour: the properties the paper measures."""
import numpy as np
import pytest

from repro.core import (Fabric, FLMessage, ObjectStore, TensorPayload,
                        VirtualPayload, make_backend)
from repro.scenario import TopologySpec
from repro.core.netsim import MB, NCAL

LARGE = int(1243.14 * MB)
SMALL = int(2.39 * MB)


@pytest.fixture
def deployment():
    env = TopologySpec.preset("geo_distributed", num_clients=7).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return env, fabric, store


def _broadcast(name, env, fabric, store, nbytes):
    be = make_backend(name, env, fabric, "server", store=store)
    msgs = [FLMessage("model_sync", "server", c.host_id,
                      payload=VirtualPayload(nbytes)) for c in env.clients]
    done, arrives = be.broadcast(msgs, 0.0)
    peak = be.endpoint.memory.peak
    for c in env.clients:
        fabric.endpoints[c.host_id].inbox.clear()
    be.endpoint.memory.reset()
    return max(arrives), peak


def test_grpc_s3_beats_grpc_for_large_broadcast(deployment):
    env, fabric, store = deployment
    t_grpc, _ = _broadcast("grpc", env, fabric, store, LARGE)
    t_s3, _ = _broadcast("grpc+s3", env, fabric, store, LARGE)
    assert t_s3 < t_grpc / 3  # paper: 3.5-3.8x end-to-end, >3x on transfer


def test_grpc_competitive_for_small(deployment):
    env, fabric, store = deployment
    t_grpc, _ = _broadcast("grpc", env, fabric, store, SMALL)
    t_s3, _ = _broadcast("grpc+s3", env, fabric, store, SMALL)
    # <10MB: the two-hop S3 path is not a large win (paper §VII guideline)
    assert t_grpc < 3 * t_s3


def test_sender_memory_constant_for_s3_linear_for_grpc(deployment):
    env, fabric, store = deployment
    _, peak_grpc = _broadcast("grpc", env, fabric, store, LARGE)
    _, peak_s3 = _broadcast("grpc+s3", env, fabric, store, LARGE)
    n = len(env.clients)
    assert peak_grpc > 0.9 * n * LARGE  # one buffered copy per receiver
    assert peak_s3 < 1.5 * LARGE  # single upload copy, O(1) in receivers


def test_membuff_zero_copy_memory(deployment):
    env, fabric, store = deployment
    _, peak = _broadcast("mpi_mem_buff", env, fabric, store, LARGE)
    assert peak < 0.1 * LARGE  # staging only, no payload copies


def test_rpc_multiconn_beats_single_conn_backends_on_wan(deployment):
    env, fabric, store = deployment
    t_rpc, _ = _broadcast("torch_rpc", env, fabric, store, LARGE)
    t_mpi, _ = _broadcast("mpi_mem_buff", env, fabric, store, LARGE)
    assert t_rpc < t_mpi  # paper §V: RPC wins geo-distributed


def test_p2p_roundtrip_delivers_identical_tree(deployment):
    env, fabric, store = deployment
    tree = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
            "b": np.ones(7, dtype=np.float32)}
    for name in ("grpc", "mpi_generic", "mpi_mem_buff", "torch_rpc",
                 "grpc+s3"):
        be = make_backend(name, env, fabric, "server", store=store)
        cl = make_backend(name, env, fabric, "client2", store=store)
        _, arrive = be.send(FLMessage("model_sync", "server", "client2",
                                      payload=TensorPayload(tree)), 0.0)
        got = cl.recv(arrive + 100)
        assert len(got) == 1, name
        msg, ready = got[0]
        assert ready >= 0
        np.testing.assert_array_equal(np.asarray(msg.payload.tree["w"]),
                                      tree["w"], err_msg=name)
        fabric.endpoints["client2"].inbox.clear()


def test_s3_key_cache_single_upload(deployment):
    env, fabric, store = deployment
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    payload = VirtualPayload(LARGE, tag="round1")
    for c in env.clients[:3]:
        be.send(FLMessage("model_sync", "server", c.host_id,
                          payload=payload), 0.0)
    assert store.stats["puts"] == 1  # cached key reused
    assert store.stats["cache_hits"] == 2


def test_s3_key_cache_across_rounds_one_upload_n_gets(deployment):
    """Re-broadcasting the *same* model across rounds hits the
    content-addressed cache: one upload total, one GET per delivery."""
    env, fabric, store = deployment
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    payload = VirtualPayload(LARGE, tag="modelA")
    n = len(env.clients)
    t = 0.0
    for r in range(3):
        msgs = [FLMessage("model_sync", "server", c.host_id, round=r,
                          payload=payload) for c in env.clients]
        t, _ = be.broadcast(msgs, t)
        for c in env.clients:
            fabric.endpoints[c.host_id].inbox.clear()
    assert store.stats["puts"] == 1
    assert store.stats["cache_hits"] == 2  # rounds 2 and 3
    assert store.stats["gets"] == 3 * n


def test_s3_key_cache_invalidates_on_payload_or_compression_change(
        deployment):
    env, fabric, store = deployment
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    be.send(FLMessage("m", "server", "client1",
                      payload=VirtualPayload(LARGE, tag="v1")), 0.0)
    assert store.stats["puts"] == 1
    # a *new* model (different fingerprint) re-uploads
    be.send(FLMessage("m", "server", "client1",
                      payload=VirtualPayload(LARGE, tag="v2")), 0.0)
    assert store.stats["puts"] == 2
    # same payload through a *compressing* stack is a different wire:
    # the cache keys on the post-compression wire, so it must re-upload
    be_q = make_backend("grpc+s3", env, fabric, "server", store=store,
                        compression="qsgd")
    be_q.send(FLMessage("m", "server", "client1",
                        payload=VirtualPayload(LARGE, tag="v2")), 0.0)
    assert store.stats["puts"] == 3
    assert len(store._objects) == 3  # three distinct content keys
    # and the compressed object is the smaller wire
    sizes = sorted(o.nbytes for o in store._objects.values())
    assert sizes[0] < 0.3 * LARGE


def test_s3_recv_decodes_with_producing_codec(deployment):
    """Satellite regression: a stored wire produced by a *different*
    serializer (AUTO routing / mixed fleets) must decode with its own
    codec, not the receiver's generic pickle deserializer."""
    env, fabric, store = deployment
    tree = {"w": np.arange(256, dtype=np.float32).reshape(16, 16)}
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    cl = make_backend("grpc+s3", env, fabric, "client2", store=store)
    msg = FLMessage("model_sync", "server", "client2",
                    payload=TensorPayload(tree))
    h = be.isend(msg, 0.0)
    # swap the stored wire for a membuff-coded one (what an AUTO-routed
    # zero-copy sender would have produced for the same model)
    from repro.core.serialization import SERIALIZERS
    key = list(store._objects)[0]
    alt = SERIALIZERS["membuff"].serialize(TensorPayload(tree))
    store.put(key, alt, alt.nbytes, 0.0)
    got = cl.recv(h.arrive + 100)
    assert len(got) == 1
    np.testing.assert_array_equal(np.asarray(got[0][0].payload.tree["w"]),
                                  tree["w"])


def test_s3_refetch_after_failure():
    env = TopologySpec.preset("geo_distributed", num_clients=7).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL, fail_rate=0.4, seed=3)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    be = make_backend("grpc+s3", env, fabric, "server", store=store)
    cl = make_backend("grpc+s3", env, fabric, "client3", store=store)
    _, arrive = be.send(FLMessage("model_sync", "server", "client3",
                                  payload=VirtualPayload(LARGE)), 0.0)
    key = list(store._objects)[0]
    obj, t_ready = cl.refetch(key, arrive)
    assert obj.nbytes == LARGE and t_ready > arrive
    # retries were charged, never fatal
    assert store.stats["retries"] >= 0


def test_auto_backend_routes_by_size(deployment):
    env, fabric, store = deployment
    auto = make_backend("auto", env, fabric, "server", store=store)
    auto.send(FLMessage("m", "server", "client0",
                        payload=VirtualPayload(SMALL)), 0.0)
    auto.send(FLMessage("m", "server", "client0",
                        payload=VirtualPayload(LARGE)), 0.0)
    assert auto.decisions[0][2] == "grpc"
    assert auto.decisions[1][2] == "grpc+s3"


def test_auto_routing_sees_post_compression_wire_size(deployment):
    """§VII's 10 MB threshold is wire bytes: a qsgd-compressed 32 MB
    update (~8.1 MB on the wire) rides plain gRPC, while the same
    payload uncompressed rides gRPC+S3."""
    env, fabric, store = deployment
    nbytes = 32 * MB
    plain = make_backend("auto", env, fabric, "server", store=store)
    plain.send(FLMessage("m", "server", "client0",
                         payload=VirtualPayload(nbytes, tag="u")), 0.0)
    assert plain.decisions[-1][2] == "grpc+s3"

    comp = make_backend("auto", env, fabric, "server", store=store,
                        compression="qsgd")
    comp.send(FLMessage("m", "server", "client0",
                        payload=VirtualPayload(nbytes, tag="c")), 0.0)
    kind, wire_est, backend = comp.decisions[-1]
    assert backend == "grpc"
    assert wire_est < 10 * MB  # the logged size is the wire estimate
    # resolve() (the planner hook) agrees with the routed send
    assert comp.resolve(FLMessage("m", "server", "client0",
                                  payload=VirtualPayload(nbytes))) is comp.grpc
    # p2p_time routes on the same estimate (charges the gRPC path)
    assert comp.p2p_time(nbytes, "client0") == \
        comp.grpc.p2p_time(nbytes, "client0")
    # far above the threshold even compressed: still grpc+s3
    comp.send(FLMessage("m", "server", "client0",
                        payload=VirtualPayload(LARGE, tag="big")), 0.0)
    assert comp.decisions[-1][2] == "grpc+s3"


def test_auto_broadcast_routes_per_message(deployment):
    """One small control record in a batch of large models must not drag
    the models onto gRPC (and vice versa): mixed-size broadcasts split,
    each subset keeping its backend's timing semantics."""
    env, fabric, store = deployment
    auto = make_backend("auto", env, fabric, "server", store=store)
    msgs = [FLMessage("ctl", "server", "client0",
                      payload=VirtualPayload(SMALL)),
            FLMessage("model_sync", "server", "client1",
                      payload=VirtualPayload(LARGE)),
            FLMessage("ctl", "server", "client2",
                      payload=VirtualPayload(SMALL)),
            FLMessage("model_sync", "server", "client3",
                      payload=VirtualPayload(LARGE))]
    done, arrives = auto.broadcast(msgs, 0.0)
    assert [d[2] for d in auto.decisions] == ["grpc", "grpc+s3", "grpc",
                                              "grpc+s3"]
    assert len(arrives) == 4 and all(a > 0 for a in arrives)
    # arrivals stay in input order: the small control messages land well
    # before the 1.2 GB models despite being interleaved in the batch
    assert max(arrives[0], arrives[2]) < min(arrives[1], arrives[3])
    # the s3 subset kept single-upload semantics (one PUT for two models)
    assert store.stats["puts"] == 1
    for c in env.clients:
        fabric.endpoints[c.host_id].inbox.clear()


def test_auto_sequential_broadcast_routes_per_message(deployment):
    env, fabric, store = deployment
    auto = make_backend("auto", env, fabric, "server", store=store)
    msgs = [FLMessage("m", "server", "client0",
                      payload=VirtualPayload(SMALL)),
            FLMessage("m", "server", "client1",
                      payload=VirtualPayload(LARGE))]
    t, arrives = auto.sequential_broadcast(msgs, 0.0)
    assert [d[2] for d in auto.decisions] == ["grpc", "grpc+s3"]
    # blocking chain: the second send is issued only after the first lands
    assert arrives[1] > arrives[0]
    assert t == arrives[-1]


def test_presigned_url_scoping():
    store = ObjectStore(NCAL)
    store.put("models/x", None, 100, 0.0)
    url = store.presign("models/x", "get", now=0.0, ttl=10.0)
    assert url.valid("models/x", "get", 5.0)
    assert not url.valid("models/x", "get", 11.0)  # expired
    assert not url.valid("models/y", "get", 5.0)  # wrong key
    assert not url.valid("models/x", "put", 5.0)  # wrong mode
