"""Sweep engine: axis expansion, seeded random search, fingerprint
caching/resume, CellResult round-trips, fig-module parity, and the sweep
CLI surface."""
import json
import os

import pytest

from repro.scenario import Scenario, StrategySpec, TopologySpec
from repro.sweep import (Axis, Cell, CellResult, Engine, RunStore, Study,
                         Sweep, SweepError, fingerprint, run_scenario)


# ---------------------------------------------------------------------------
# axis expansion
# ---------------------------------------------------------------------------

def test_grid_cross_product_counts_and_order():
    sw = Sweep(name="g", axes=(
        Axis("channel.backend", values=("grpc", "grpc+s3")),
        Axis("fleet.tier", values=("small", "big", "large")),
        Axis("params.n", values=(1, 2))))
    cells = sw.expand()
    assert len(cells) == 2 * 3 * 2
    # declaration order = nesting order (first axis outermost)
    assert [c.overrides["channel.backend"] for c in cells[:6]] == \
        ["grpc"] * 6
    assert cells[0].overrides["fleet.tier"] == "small"
    assert cells[0].params == {"n": 1}
    assert cells[1].params == {"n": 2}
    # scenario really carries the overrides
    assert cells[0].scenario.channel.backend == "grpc"
    assert cells[-1].scenario.fleet.tier == "large"


def test_grid_range_axis_linspace():
    sw = Sweep(name="g", axes=(
        Axis("faults.link_loss", lo=0.0, hi=0.2, steps=5),))
    vals = [c.overrides["faults.link_loss"] for c in sw.expand()]
    assert vals == pytest.approx([0.0, 0.05, 0.1, 0.15, 0.2])


def test_grid_range_axis_without_steps_rejected():
    with pytest.raises(SweepError, match="steps"):
        Sweep(name="g", axes=(Axis("faults.link_loss", lo=0, hi=1),)
              ).expand()


def test_random_search_deterministic_and_sized():
    sw = Sweep(name="r", samples=7, seed=13, axes=(
        Axis("faults.link_loss", lo=0.0, hi=0.3),
        Axis("channel.backend", values=("grpc", "grpc+s3", "auto"))))
    a = [(c.overrides["faults.link_loss"],
          c.overrides["channel.backend"]) for c in sw.expand()]
    b = [(c.overrides["faults.link_loss"],
          c.overrides["channel.backend"]) for c in sw.expand()]
    assert a == b and len(a) == 7
    assert all(0.0 <= l <= 0.3 for l, _ in a)
    # a different seed draws a different grid
    other = Sweep(name="r", samples=7, seed=14, axes=sw.axes).expand()
    assert a != [(c.overrides["faults.link_loss"],
                  c.overrides["channel.backend"]) for c in other]


def test_sweep_constants_merge_into_every_cell():
    sw = Sweep(name="c", axes=(Axis("params.x", values=(1, 2)),),
               params={"rounds": 3})
    for c in sw.expand():
        assert c.params["rounds"] == 3


def test_bad_axis_field_rejected_with_path():
    with pytest.raises(SweepError, match="channel.bakend"):
        Sweep(name="b", axes=(Axis("channel.bakend", values=("x",)),)
              ).expand()
    with pytest.raises(SweepError, match="params"):
        Sweep(name="b", axes=(Axis("nonsense", values=(1,)),)).expand()
    with pytest.raises(SweepError, match="None"):
        Sweep(name="b",
              axes=(Axis("channel.backend", values=(None,)),)).expand()
    with pytest.raises(SweepError, match="duplicate"):
        Sweep(name="b", axes=(Axis("params.x", values=(1,)),
                              Axis("params.x", values=(2,)))).expand()


# ---------------------------------------------------------------------------
# (de)serialisation round-trips
# ---------------------------------------------------------------------------

def test_sweep_roundtrip_through_json():
    sw = Sweep(name="rt",
               base=Scenario(name="rt",
                             topology=TopologySpec(num_clients=3),
                             strategy=StrategySpec(mode="fedbuff")),
               axes=(Axis("channel.backend", values=("grpc", "auto")),
                     Axis("faults.link_loss", lo=0.0, hi=0.1, steps=3),
                     Axis("params.k", values=(1, 2))),
               samples=0, seed=5, params={"rounds": 2})
    assert Sweep.from_dict(json.loads(json.dumps(sw.to_dict()))) == sw


def test_sweep_from_dict_rejects_unknown_keys():
    with pytest.raises(SweepError, match="axess"):
        Sweep.from_dict({"name": "x", "axess": []})
    with pytest.raises(SweepError, match=r"axes\[0\].*valuess"):
        Sweep.from_dict({"name": "x", "axes": [{"field": "f",
                                                "valuess": [1]}]})


def test_cellresult_roundtrip():
    r = CellResult(study="s", cell="s/a", fingerprint="f" * 24,
                   overrides={"channel.backend": "grpc"},
                   params={"loss": 0.1},
                   sim_time_s=1.5, bytes_on_wire=2e6, retransmits=3.0,
                   transfers_failed=0.0, n_rounds=4,
                   stage_charges={"server.communication": 1.0},
                   round_reports=[{"round": 0}],
                   metrics={"speedup": 2.0, "trace": [[0.0, "a"]]})
    assert CellResult.from_dict(json.loads(json.dumps(r.to_dict()))) == r
    with pytest.raises(ValueError, match="unknown"):
        CellResult.from_dict({**r.to_dict(), "bogus": 1})


def test_from_metrics_canonicalises_fresh_equal_cached():
    """A freshly-run cell must compare equal to its JSON-replayed self —
    the bit-for-bit trace comparisons in fig8 rely on this."""
    m = {"sim_time_s": 1.25, "trace": ((0.5, "ev"), (1.0, "ev2")),
         "n_rounds": 2}
    r = CellResult.from_metrics("s", "s/x", "f" * 24, {}, {}, m)
    replay = CellResult.from_dict(json.loads(json.dumps(r.to_dict())))
    assert replay == r
    assert r.metrics["trace"] == [[0.5, "ev"], [1.0, "ev2"]]


# ---------------------------------------------------------------------------
# engine: fingerprints, cache hits, resume
# ---------------------------------------------------------------------------

def _counting_study(sw, calls):
    def cell(c):
        calls.append(c.index)
        return {"sim_time_s": float(c.index), "v": c.index}
    return Study(name="t", sweeps=lambda quick: (sw,), cell=cell)


def test_engine_cache_rerun_touches_zero_cells(tmp_path):
    sw = Sweep(name="t", axes=(Axis("params.n", values=(1, 2, 3)),))
    calls = []
    eng = Engine(str(tmp_path))
    study = _counting_study(sw, calls)
    rows1 = eng.run_study(study, verbose=False)
    assert len(calls) == 3 and eng.last_stats.n_ran == 3
    rows2 = eng.run_study(study, verbose=False)
    assert len(calls) == 3, "re-run must touch zero completed cells"
    assert eng.last_stats.n_cached == 3 and eng.last_stats.n_ran == 0
    assert rows1 == rows2
    # fresh=True bypasses the store — including through the legacy
    # runner surface run.py --fresh uses (per-study, no rmtree)
    eng.runner(study)(verbose=False, fresh=True)
    assert len(calls) == 6


def test_engine_resumes_partial_store(tmp_path):
    """Only the missing cells of an interrupted grid run."""
    sw = Sweep(name="t", axes=(Axis("params.n", values=(1, 2, 3, 4)),))
    calls = []
    eng = Engine(str(tmp_path))
    study = _counting_study(sw, calls)
    results = eng.run_cells(study, sw.expand()[:2], verbose=False)
    assert len(calls) == 2
    eng.run_study(study, verbose=False)
    assert len(calls) == 4, "completed prefix must come from the store"
    assert eng.last_stats.n_cached == 2 and eng.last_stats.n_ran == 2
    assert all(isinstance(r, CellResult) for r in results)


def test_store_tolerates_truncated_tail(tmp_path):
    path = str(tmp_path / "s.jsonl")
    r = CellResult.from_metrics("s", "s/x", "f" * 24, {}, {}, {"v": 1})
    store = RunStore(path)
    store.put(r)
    with open(path, "a") as f:
        f.write('{"study": "s", "cell": tru')  # interrupted write
    store2 = RunStore(path)
    assert len(store2) == 1 and store2.get("f" * 24) == r


def test_fingerprint_depends_on_spec_params_and_version():
    cell = Sweep(name="t", axes=(Axis("params.n", values=(1,)),)
                 ).expand()[0]
    base = fingerprint("s", 1, cell)
    assert fingerprint("s", 1, cell) == base
    assert fingerprint("s", 2, cell) != base
    assert fingerprint("other", 1, cell) != base
    cell2 = Sweep(name="t", axes=(Axis("params.n", values=(2,)),)
                  ).expand()[0]
    assert fingerprint("s", 1, cell2) != base
    cell3 = Sweep(name="t", base=Scenario(seed=9),
                  axes=(Axis("params.n", values=(1,)),)).expand()[0]
    assert fingerprint("s", 1, cell3) != base


# ---------------------------------------------------------------------------
# fig-module parity: the refactored studies expand to the legacy grids
# ---------------------------------------------------------------------------

def test_fig4a_cells_match_prerefactor_grid():
    from benchmarks.fig4a_p2p_latency import STUDY
    cells = [c for sw in STUDY.sweeps(False) for c in sw.expand()]
    names = [STUDY.name_of(c) for c in cells]
    # the exact enumeration the hand-rolled loops produced
    expected = []
    for label, env, _dst in [("LAN", "lan", "client0"),
                             ("GeoProx", "geo_proximal", "client0"),
                             ("CA-VA", "geo_distributed", "client2"),
                             ("CA-HK", "geo_distributed", "client3")]:
        backends = ["mpi_generic", "mpi_mem_buff", "grpc", "torch_rpc"]
        if env != "lan":
            backends.append("grpc+s3")
        for tier in ("small", "medium", "big", "large"):
            for b in backends:
                expected.append(f"fig4a/{label}/{tier}/{b}")
    assert names == expected


def test_fig6_quick_cells_match_prerefactor_grid():
    from benchmarks.fig6_async_vs_sync import STUDY
    cells = [c for sw in STUDY.sweeps(True) for c in sw.expand()]
    names = [STUDY.name_of(c) for c in cells]
    expected = [f"fig6/{env}/big/{b}/{mode}"
                for env, backends in
                [("geo_distributed", ("grpc", "grpc+s3")),
                 ("lan", ("grpc",))]
                for b in backends
                for mode in ("sync", "fedbuff", "semisync", "hier")]
    # pre-refactor nesting was env -> tier -> backend -> mode; ours is
    # env -> tier -> backend -> mode too, so the sets AND order agree
    assert names == expected


def test_every_fig_study_is_registered_and_quick():
    from benchmarks.registry import discover
    entries = {e.name: e for e in discover()}
    for name in ("fig2", "fig4a", "fig4b", "fig4c", "fig5", "fig6",
                 "fig7", "fig8", "fig9", "fig10", "table1"):
        assert name in entries, f"{name} dropped from discovery"
        assert entries[name].in_quick
    assert not entries["kernels"].in_quick
    assert not entries["crosspod"].in_quick
    # sweep studies expose their Study object
    assert entries["fig10"].module.STUDY.out == "fig10_decision_guide.json"


# ---------------------------------------------------------------------------
# generic runner + sweep CLI
# ---------------------------------------------------------------------------

def _tiny_scenario(mode="sync"):
    return Scenario(name="tiny",
                    topology=TopologySpec(kind="lan", num_clients=2),
                    strategy=StrategySpec(mode=mode, rounds=1))


def test_run_scenario_sync_unified_metrics():
    m = run_scenario(_tiny_scenario())
    assert m["n_rounds"] == 1 and m["sim_time_s"] > 0
    assert m["bytes_on_wire"] > 0  # broadcast + upload legs counted
    assert "server.communication" in m["stage_charges"]
    assert m["round_reports"][0]["n_participants"] == 2


def test_run_scenario_event_driven():
    m = run_scenario(_tiny_scenario("fedbuff"), rounds=2)
    assert m["n_rounds"] == 2
    assert m["aggregations_per_hour"] > 0
    assert len(m["round_reports"]) == 2


def test_sweep_cli_runs_file_and_caches(tmp_path, capsys):
    from repro.sweep.__main__ import run_sweep_file
    sweep = Sweep(name="cli", base=_tiny_scenario(),
                  axes=(Axis("channel.backend",
                             values=("grpc", "mpi_mem_buff")),))
    path = tmp_path / "sweep.json"
    path.write_text(sweep.to_json())
    report = tmp_path / "report.json"
    results = run_sweep_file(str(path), out_dir=str(tmp_path / "out"),
                             report_path=str(report))
    assert len(results) == 2
    assert json.load(open(report))[0]["study"] == "cli"
    # second run replays from the store
    run_sweep_file(str(path), out_dir=str(tmp_path / "out"))
    out = capsys.readouterr().out
    assert "2 cached" in out


def test_fl_train_sweep_flag(tmp_path, capsys):
    from repro.launch.fl_train import main
    sweep = Sweep(name="flcli", base=_tiny_scenario(),
                  axes=(Axis("params.n", values=(1,)),))
    path = tmp_path / "sweep.json"
    path.write_text(sweep.to_json())
    assert main(["--sweep", str(path),
                 "--sweep-out-dir", str(tmp_path / "out")]) == 0
    assert "flcli" in capsys.readouterr().out
    assert (tmp_path / "out" / "runstore" / "flcli.jsonl").exists()
