"""Sharding rules + hypothesis property tests on MeshPlan invariants."""
import jax
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs.base import (MULTI_POD_MESH, SINGLE_POD_MESH, SMOKE_MESH,
                                MeshConfig)
from repro.sharding.rules import MeshPlan

LOGICAL = ["layers", "vocab", "embed", "heads", "kv_heads", "mlp", "expert",
           "expert_in", "batch", "seq", "seq_kv", "ssm_inner", "norm", None]


def test_basic_resolution():
    plan = MeshPlan(SINGLE_POD_MESH)
    assert plan.spec(("vocab", "embed")) == P("model", "data")
    assert plan.spec(("embed", "heads")) == P("data", "model")
    assert plan.spec(("norm",)) == P()
    assert plan.spec(("layers", "embed", "mlp")) == P(None, "data", "model")


def test_duplicate_axis_dropped():
    plan = MeshPlan(SINGLE_POD_MESH)
    # expert and mlp both map to 'model': second use must be dropped
    spec = plan.spec(("expert", "expert_in", "mlp"))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat += list(s) if isinstance(s, tuple) else [s]
    assert len(flat) == len(set(flat))
    assert spec[0] == "model"


def test_divisibility_fallback():
    plan = MeshPlan(MULTI_POD_MESH)
    # batch=1 cannot shard over (pod, data): falls back to unsharded
    assert plan.spec(("batch",), (1,)) == P()
    # batch=128 over pod*data=32 works
    assert plan.spec(("batch",), (128,)) == P(("pod", "data"))
    # batch=16 shards over pod(2) then data(16) fails -> partial (pod only)
    assert plan.spec(("batch",), (2,)) == P(("pod",))


@given(axes=st.lists(st.sampled_from(LOGICAL), min_size=0, max_size=5),
       mesh_cfg=st.sampled_from([SINGLE_POD_MESH, MULTI_POD_MESH, SMOKE_MESH]))
@settings(max_examples=200, deadline=None)
def test_no_mesh_axis_reused(axes, mesh_cfg):
    """PartitionSpec invariant: each mesh axis appears at most once."""
    plan = MeshPlan(mesh_cfg)
    spec = plan.spec(tuple(axes))
    flat = []
    for s in spec:
        if s is None:
            continue
        flat += list(s) if isinstance(s, tuple) else [s]
    assert len(flat) == len(set(flat))
    for a in flat:
        assert a in mesh_cfg.axis_names


@given(axes=st.lists(st.sampled_from(LOGICAL), min_size=1, max_size=4),
       dims=st.lists(st.sampled_from([1, 2, 3, 16, 32, 256, 4096]),
                     min_size=1, max_size=4))
@settings(max_examples=200, deadline=None)
def test_shape_aware_spec_always_divisible(axes, dims):
    n = min(len(axes), len(dims))
    axes, dims = tuple(axes[:n]), tuple(dims[:n])
    plan = MeshPlan(MULTI_POD_MESH)
    spec = plan.spec(axes, dims)
    for dim, s in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        if s is None:
            continue
        parts = s if isinstance(s, tuple) else (s,)
        total = 1
        for p in parts:
            total *= MULTI_POD_MESH.axis_size(p)
        assert dim % total == 0, (axes, dims, spec)


def test_tree_specs_match_structure():
    plan = MeshPlan(SINGLE_POD_MESH)
    axes_tree = {"a": ("embed", "heads"), "b": {"c": ("norm",), "d": None}}
    specs = plan.tree_specs(axes_tree)
    assert specs["a"] == P("data", "model")
    assert specs["b"]["c"] == P()
    assert specs["b"]["d"] == P()
