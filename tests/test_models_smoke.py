"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes + finite values; decode parity with full forward
for recurrent models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_ORDER, get_config, smoke_config
from repro.configs.shapes import SHAPES, applicability
from repro.models import build_model, param_count


pytestmark = pytest.mark.slow  # minutes-long; PR CI runs -m 'not slow'


def _batch(cfg, rng, b=2, s=16):
    batch = {}
    if cfg.external_embeddings:
        batch["embeds"] = jax.random.normal(rng, (b, s, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    batch["targets"] = jax.random.randint(jax.random.fold_in(rng, 7),
                                          (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params, axes = model.init(rng)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gnorm = np.sqrt(sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_ORDER
                                  if get_config(a).causal])
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.key(1)
    params, _ = model.init(rng)
    b = 2
    cache = model.init_cache(b, 32)
    batch = {"tokens": jnp.zeros((b, 1), jnp.int32), "pos": jnp.int32(0)}
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-1.2b"])
def test_recurrent_decode_matches_parallel_forward(arch):
    """Chunkwise-parallel training form == recurrent decode form."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.key(2)
    params, _ = model.init(rng)
    b, s = 1, 8
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    full_logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    outs = []
    for pos in range(s):
        logits, cache = step(params, cache,
                             {"tokens": tokens[:, pos:pos + 1],
                              "pos": jnp.int32(pos)})
        outs.append(logits.reshape(b, -1))
    dec = np.stack([np.asarray(o, dtype=np.float32) for o in outs], axis=1)
    ref = np.asarray(full_logits, dtype=np.float32)
    np.testing.assert_allclose(dec, ref, rtol=0.15, atol=0.15)  # bf16 noise


def test_causal_attention_is_causal():
    cfg = smoke_config("qwen3-8b")
    model = build_model(cfg)
    rng = jax.random.key(3)
    params, _ = model.init(rng)
    t1 = jax.random.randint(rng, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[:, -1].set((t1[:, -1] + 5) % cfg.vocab_size)
    l1, _ = jax.jit(model.forward)(params, {"tokens": t1})
    l2, _ = jax.jit(model.forward)(params, {"tokens": t2})
    # changing the last token must not change logits at earlier positions
    np.testing.assert_allclose(np.asarray(l1[:, :-1], dtype=np.float32),
                               np.asarray(l2[:, :-1], dtype=np.float32),
                               rtol=1e-2, atol=1e-2)


def test_block_causal_matches_full_mask():
    import dataclasses
    cfg = smoke_config("qwen3-8b")
    m1 = build_model(dataclasses.replace(cfg, block_causal=True))
    m2 = build_model(dataclasses.replace(cfg, block_causal=False))
    rng = jax.random.key(4)
    params, _ = m1.init(rng)
    tokens = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    l1, _ = jax.jit(m1.forward)(params, {"tokens": tokens})
    l2, _ = jax.jit(m2.forward)(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_full_config_param_counts():
    """Analytic sanity for the full (assigned) configs via eval_shape."""
    expect = {  # billions, loose bands around the advertised sizes
        "qwen3-8b": (7, 10), "deepseek-67b": (60, 72),
        "granite-3-8b": (7, 10), "stablelm-12b": (11, 13.5),
        "llama4-maverick-400b-a17b": (380, 420),
        "granite-moe-1b-a400m": (0.8, 1.6), "hubert-xlarge": (0.8, 1.4),
        "llama-3.2-vision-11b": (9, 12),
        "xlstm-1.3b": (1.0, 2.1), "zamba2-1.2b": (0.9, 1.8),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_applicability_matrix_counts():
    runnable = skipped = 0
    for arch in ARCH_ORDER:
        for s in SHAPES.values():
            ok, reason = applicability(get_config(arch), s)
            runnable += ok
            skipped += not ok
            if not ok:
                assert reason
    assert runnable + skipped == 40
    assert skipped == 9  # documented in DESIGN.md
