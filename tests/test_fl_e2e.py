"""End-to-end FL: live training rounds over real backends, quorum /
straggler / fault handling, aggregation correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import TensorPayload
from repro.fl.fault import FaultPlan, apply_stragglers
from repro.launch.fl_train import build_deployment


pytestmark = pytest.mark.slow  # minutes-long; PR CI runs -m 'not slow'


def run_rounds(backend, environment, rounds=2, **kw):
    fl_cfg = FLConfig(backend=backend, environment=environment,
                      rounds=rounds, **{k: v for k, v in kw.items()
                                        if k in FLConfig.__dataclass_fields__})
    server, params, env, store = build_deployment(
        fl_cfg, local_steps=kw.get("local_steps", 2))
    reports = []
    for r in range(rounds):
        rep = server.run_round(TensorPayload(params),
                               dropped=kw.get("dropped", set()) if r == 0 else set())
        if server.global_params is not None:
            params = server.global_params
        reports.append(rep)
    return reports, server, store


@pytest.mark.parametrize("backend", ["grpc", "grpc+s3", "torch_rpc",
                                     "mpi_mem_buff", "auto"])
def test_round_completes_and_loss_improves(backend):
    reports, server, _ = run_rounds(backend, "geo_distributed", rounds=3)
    losses = [r.losses for r in reports]
    assert all(l is not None for l in losses)
    assert losses[-1] < losses[0]  # learning across rounds
    assert all(r.n_participants == 7 for r in reports)
    assert all(r.round_time > 0 for r in reports)


def test_lan_uses_no_object_store():
    reports, server, store = run_rounds("auto", "lan", rounds=1)
    assert store.stats["puts"] == 0  # auto never routes to S3 on LAN
    assert reports[0].n_participants == 7


def test_quorum_proceeds_with_dropped_clients():
    reports, server, _ = run_rounds("grpc+s3", "geo_distributed", rounds=1,
                                    quorum_fraction=0.5,
                                    dropped={"client0", "client1"})
    rep = reports[0]
    assert not rep.aborted
    assert rep.n_participants >= 4  # 5 alive, quorum of 4 counted
    assert rep.n_dropped >= 2


def test_mpi_aborts_on_dropout_but_grpc_does_not():
    rep_mpi, _, _ = run_rounds("mpi_generic", "geo_distributed", rounds=1,
                               quorum_fraction=0.5, dropped={"client0"})
    rep_grpc, _, _ = run_rounds("grpc+s3", "geo_distributed", rounds=1,
                                quorum_fraction=0.5, dropped={"client0"})
    assert rep_mpi[0].aborted  # static world, no fault isolation (§II-C)
    assert not rep_grpc[0].aborted


def test_straggler_deadline_drops_slow_client():
    fl_cfg = FLConfig(backend="grpc+s3", environment="geo_distributed",
                      quorum_fraction=0.7)
    server, params, env, store = build_deployment(fl_cfg, local_steps=2)
    plan = FaultPlan(straggler_rate=0.99, straggler_factor=50.0, seed=2)
    _, stragglers = plan.for_round(0, [c.client_id for c in server.clients])
    apply_stragglers(server.clients, stragglers, 50.0)
    rep = server.run_round(TensorPayload(params))
    assert rep.n_participants >= 4  # quorum met without the stragglers
    assert rep.n_participants < 7 or not stragglers


def test_aggregation_is_weighted_average():
    from repro.fl.aggregator import fedavg
    t1 = {"w": jnp.full((8, 8), 2.0)}
    t2 = {"w": jnp.full((8, 8), 6.0)}
    agg, secs = fedavg([t1, t2], [1, 3])
    np.testing.assert_allclose(np.asarray(agg["w"]), 5.0, rtol=1e-6)
    assert secs >= 0


def test_report_states_cover_paper_fig5():
    reports, _, _ = run_rounds("grpc", "geo_distributed", rounds=1)
    srv, cl = reports[0].server, reports[0].clients
    for k in ("communication", "migration", "serialization", "waiting",
              "aggregation"):
        assert k in srv and srv[k] >= 0
    for k in ("communication", "migration", "serialization", "waiting",
              "training"):
        assert k in cl and cl[k] >= 0
    assert cl["training"] > 0
