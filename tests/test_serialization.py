"""Serializer behaviour: roundtrip fidelity, copy-vs-view semantics,
calibrated cost ordering."""
import jax
import numpy as np
import pytest

from repro.core.message import PackedPayload, TensorPayload, VirtualPayload
from repro.core.serialization import SERIALIZERS, checksum


@pytest.fixture
def tree(rng):
    return {"w": rng.normal(size=(32, 16)).astype(np.float32),
            "b": rng.normal(size=(16,)).astype(np.float32)}


@pytest.mark.parametrize("name", ["generic", "protobuf", "membuff",
                                  "tensor_rpc"])
def test_roundtrip(name, tree):
    s = SERIALIZERS[name]
    wire = s.serialize(TensorPayload(tree))
    assert wire.nbytes > 0
    out = s.deserialize(wire)
    np.testing.assert_array_equal(np.asarray(out.tree["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(out.tree["b"]), tree["b"])


def test_membuff_is_zero_copy(tree):
    s = SERIALIZERS["membuff"]
    wire = s.serialize(TensorPayload(tree))
    assert not wire.copied
    # buffers share memory with the source arrays (leaves flatten in
    # key-sorted order: "b" then "w")
    srcs = [tree["b"], tree["w"]]
    for buf, src in zip(wire.buffers, srcs):
        assert buf.__array_interface__["data"][0] == \
            src.__array_interface__["data"][0]


def test_generic_copies(tree):
    wire = SERIALIZERS["generic"].serialize(TensorPayload(tree))
    assert wire.copied and isinstance(wire.buffers[0], bytes)


def test_cost_ordering_matches_paper():
    """Paper §V: protobuf (gRPC) slowest, generic middle, buffers ~free."""
    n = 256 * 2 ** 20
    t = {name: SERIALIZERS[name].ser_time(n) for name in SERIALIZERS}
    assert t["protobuf"] > t["generic"] > t["tensor_rpc"] >= t["membuff"]
    assert t["membuff"] == 0.0


def test_grpc_lan_serialization_fraction():
    """Reproduce the '86% of gRPC LAN latency is serialization' claim."""
    from repro.core.netsim import LAN_TCP
    s = SERIALIZERS["protobuf"]
    nbytes = int(253.19 * 2 ** 20)  # Big tier
    ser = s.ser_time(nbytes) + s.deser_time(nbytes)
    total = ser + LAN_TCP.latency + nbytes / LAN_TCP.bw_single
    assert 0.80 <= ser / total <= 0.92


def test_virtual_payload_passthrough():
    s = SERIALIZERS["generic"]
    wire = s.serialize(VirtualPayload(12345, tag="x"))
    assert wire.nbytes == 12345
    out = s.deserialize(wire)
    assert isinstance(out, VirtualPayload) and out.size == 12345


def test_packed_payload_roundtrip(rng):
    from repro.kernels import ops
    tree = {"w": np.asarray(rng.normal(size=(128,)).astype(np.float32))}
    packed, _ = ops.quantize_pytree(tree)
    p = PackedPayload(jax.tree.map(np.asarray, packed))
    for name in ("generic", "membuff"):
        wire = SERIALIZERS[name].serialize(p)
        out = SERIALIZERS[name].deserialize(wire)
        np.testing.assert_array_equal(np.asarray(out.packed["q"]),
                                      np.asarray(packed["q"]))


def test_checksum_stable(tree):
    s = SERIALIZERS["membuff"]
    w1 = s.serialize(TensorPayload(tree))
    w2 = s.serialize(TensorPayload(tree))
    assert checksum(w1) == checksum(w2) != 0
