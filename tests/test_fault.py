"""Fault & churn subsystem: independent FaultPlan draws, availability
traces, the deterministic LinkFaultModel, chunk retransmit recovery, and
the event-driven scheduler under churn (mid-round departures, semisync
live quorum, hier relay quorum + fold-in on rejoin)."""
import math

import numpy as np
import pytest

from repro.core import (Fabric, FLMessage, ObjectStore, VirtualPayload,
                        make_backend)
from repro.scenario import TopologySpec
from repro.core.netsim import MB, NCAL, LinkFaultModel
from repro.fl import FedBuffStrategy, HierarchicalStrategy, SemiSyncStrategy
from repro.fl.fault import (AvailabilityTrace, FaultPlan, make_availability)
from repro.fl.scheduler import FLScheduler


# ---------------------------------------------------------------------------
# FaultPlan: independent split-stream draws (regression for the elif bug)
# ---------------------------------------------------------------------------

def test_fault_plan_marginal_rates_match_knobs():
    """The straggler rate must be its knob, not (1-drop)*straggler: the
    old coupled elif draw gave 0.28 effective for (0.3, 0.4)."""
    plan = FaultPlan(drop_rate=0.3, straggler_rate=0.4, seed=7)
    ids = [f"c{i}" for i in range(40)]
    n = drops = strags = both = 0
    for r in range(400):
        d, s = plan.for_round(r, ids)
        drops += len(d)
        strags += len(s)
        both += len(d & s)
        n += len(ids)
    assert abs(drops / n - 0.3) < 0.02
    assert abs(strags / n - 0.4) < 0.02  # coupled draw would give ~0.28
    # independence: joint rate is the product of the marginals
    assert abs(both / n - 0.3 * 0.4) < 0.02


def test_fault_plan_deterministic_and_seed_sensitive():
    ids = [f"c{i}" for i in range(10)]
    a = FaultPlan(drop_rate=0.5, straggler_rate=0.5, seed=3)
    b = FaultPlan(drop_rate=0.5, straggler_rate=0.5, seed=3)
    assert a.for_round(5, ids) == b.for_round(5, ids)
    c = FaultPlan(drop_rate=0.5, straggler_rate=0.5, seed=4)
    assert any(a.for_round(r, ids) != c.for_round(r, ids) for r in range(5))


def test_fault_plan_client_can_be_both_dropped_and_straggler():
    plan = FaultPlan(drop_rate=0.9, straggler_rate=0.9, seed=0)
    ids = [f"c{i}" for i in range(30)]
    d, s = plan.for_round(0, ids)
    assert d & s  # independent draws overlap at these rates


# ---------------------------------------------------------------------------
# AvailabilityTrace
# ---------------------------------------------------------------------------

def test_availability_trace_parse_and_is_up():
    tr = AvailabilityTrace.parse(
        "client0:leave@120,join@400; client3:leave@50")
    assert len(tr) == 3
    assert tr.is_up("client0", 0.0)
    assert not tr.is_up("client0", 200.0)
    assert tr.is_up("client0", 401.0)
    assert not tr.is_up("client3", 1e9)
    assert tr.is_up("client1", 50.0)  # untouched clients stay up


def test_availability_trace_parse_rejects_garbage():
    with pytest.raises(ValueError):
        AvailabilityTrace.parse("client0")
    with pytest.raises(ValueError):
        AvailabilityTrace.parse("client0:crash@5")


def test_availability_trace_generate_is_deterministic_and_split_stream():
    ids = [f"client{i}" for i in range(5)]
    a = AvailabilityTrace.generate(ids, 3600, mean_up_s=600, mean_down_s=200,
                                   seed=1)
    b = AvailabilityTrace.generate(ids, 3600, mean_up_s=600, mean_down_s=200,
                                   seed=1)
    assert a.events == b.events
    assert a.events  # something happens over a 6x-mean-up horizon
    # alternation per client: leave, join, leave, ...
    for cid in ids:
        kinds = [e.kind for e in a.for_client(cid)]
        assert kinds == (["leave", "join"] * len(kinds))[:len(kinds)]
    # id-keyed streams: adding a client does not reshuffle existing
    # traces, even one that sorts into the middle of the fleet
    # ("client12" sorts between client1 and client2)
    c = AvailabilityTrace.generate(ids + ["client12"], 3600, mean_up_s=600,
                                   mean_down_s=200, seed=1)
    for cid in ids:
        assert c.for_client(cid) == a.for_client(cid)


def test_make_availability_adapter():
    assert make_availability("", ["a"], 100.0) is None
    tr = make_availability("auto:50/20", ["a", "b"], 500.0, seed=2)
    assert isinstance(tr, AvailabilityTrace) and len(tr) > 0
    tr2 = make_availability("a:leave@5", ["a"], 100.0)
    assert not tr2.is_up("a", 6.0)


# ---------------------------------------------------------------------------
# LinkFaultModel
# ---------------------------------------------------------------------------

def test_link_fault_model_deterministic_counter_based():
    fm = LinkFaultModel(chunk_loss_rate=0.3, seed=5)
    draws = [fm.attempts("a", "b", xid, c) for xid in range(20)
             for c in range(4)]
    fm2 = LinkFaultModel(chunk_loss_rate=0.3, seed=5)
    assert draws == [fm2.attempts("a", "b", xid, c) for xid in range(20)
                     for c in range(4)]
    assert any(d > 1 for d in draws) and any(d == 1 for d in draws)
    assert LinkFaultModel(chunk_loss_rate=0.0).attempts("a", "b", 0, 0) == 1


def test_link_fault_model_bounded_retries_and_forced_mode():
    fm = LinkFaultModel(chunk_loss_rate=0.999, max_retries=3, seed=1)
    draws = [fm.attempts("a", "b", x, 0) for x in range(50)]
    assert None in draws  # cap exhausted -> transfer failed
    forced = [fm.attempts("a", "b", x, 0, forced=True) for x in range(50)]
    assert all(f is not None and f <= fm.max_retries + 1 for f in forced)


def test_link_fault_model_blackout_delays_departures():
    fm = LinkFaultModel(blackouts={"hk": [(10.0, 20.0)], "sv": [(19.0, 25.0)]})
    assert fm.delay(("sv", "hk"), 5.0) == 5.0
    # cascading windows on both ends: 12 -> 20 (hk) -> 25 (sv)
    assert fm.delay(("sv", "hk"), 12.0) == 25.0
    assert fm.delay(("sv", "hk"), 30.0) == 30.0


# ---------------------------------------------------------------------------
# per-edge blackout windows (FaultSpec.blackouts -> edge_blackouts)
# ---------------------------------------------------------------------------

def test_edge_blackout_is_directional_and_cascades_with_host_windows():
    fm = LinkFaultModel(blackouts={"sv": [(19.0, 25.0)]},
                        edge_blackouts={("sv", "hk"): [(10.0, 20.0)]})
    # only the named directed edge is dark
    assert fm.delay(("sv", "hk"), 12.0) == 25.0  # 12 -> 20 (edge) -> 25
    assert fm.delay(("hk", "sv"), 12.0) == 12.0  # reverse edge unaffected
    assert fm.delay(("sv", "other"), 12.0) == 12.0


def test_fault_spec_blackouts_reach_the_fault_model():
    from repro.scenario import BlackoutSpec, FaultSpec, Scenario, \
        build_runtime
    rt = build_runtime(Scenario(name="bo", faults=FaultSpec(blackouts=(
        BlackoutSpec("server", "client0", 10.0, 20.0),
        BlackoutSpec("client1", "*", 5.0, 6.0),
        BlackoutSpec("server", "client2", 1.0, 2.0, symmetric=False)))))
    fm = rt.fabric.fault_model
    assert fm is not None and fm.chunk_loss_rate == 0.0
    assert fm.delay(("server", "client0"), 12.0) == 20.0
    assert fm.delay(("client0", "server"), 12.0) == 20.0  # symmetric pair
    assert fm.delay(("client1", "server"), 5.5) == 6.0    # per-host form
    assert fm.delay(("server", "client2"), 1.5) == 2.0
    assert fm.delay(("client2", "server"), 1.5) == 1.5    # one-way


def test_edge_blackout_shifts_a_real_send_past_the_window():
    from repro.scenario import BlackoutSpec, ChannelSpec, FaultSpec, \
        Scenario, build_runtime
    clean = build_runtime(Scenario(name="c",
                                   channel=ChannelSpec(backend="grpc")))
    dark = build_runtime(Scenario(
        name="d", channel=ChannelSpec(backend="grpc"),
        faults=FaultSpec(blackouts=(
            BlackoutSpec("server", "client0", 0.0, 50.0),))))
    msg = FLMessage("m", "server", "client0",
                    payload=VirtualPayload(4 * MB, tag="x"))
    import dataclasses
    t_clean = clean.make_backend("server").isend(msg, 0.0).arrive
    t_dark = dark.make_backend("server").isend(
        dataclasses.replace(msg), 0.0).arrive
    # the departure (post-serialization) shifts past the window; the
    # remaining wire time is what a clean send pays after its encode
    assert t_clean < 50.0 < t_dark < 50.0 + t_clean


def test_sync_round_honours_client_to_server_blackout():
    """The sync server's gather phase must hold client uploads while
    their edge to the hub is dark (it used to bypass the fault model)."""
    from repro.fl.client import FLClient
    from repro.fl.server import FLServer
    from repro.scenario import BlackoutSpec, ChannelSpec, FaultSpec, \
        Scenario, TopologySpec, build_runtime

    def round_time(backend, faults):
        rt = build_runtime(Scenario(
            name="sbo", channel=ChannelSpec(backend=backend),
            topology=TopologySpec(num_clients=3), faults=faults))
        clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                            sim_train_s=10.0) for h in rt.env.clients]
        server = FLServer(rt.make_backend("server"), clients,
                          local_steps=1, live=False)
        return server.run_round(VirtualPayload(8 * MB, tag="r")).round_time

    dark = FaultSpec(blackouts=(
        BlackoutSpec("client0", "server", 0.0, 500.0, symmetric=False),))
    for backend in ("grpc", "grpc+s3"):
        clean_t = round_time(backend, FaultSpec())
        dark_t = round_time(backend, dark)
        assert clean_t < 500.0 < dark_t, \
            f"{backend}: upload blackout ignored ({dark_t} vs {clean_t})"
        # zero-width windows stay bit-for-bit no-ops on the sync path too
        noop = FaultSpec(blackouts=(
            BlackoutSpec("client0", "server", 10.0, 10.0),))
        assert round_time(backend, noop) == clean_t


def test_zero_width_blackout_window_is_bit_for_bit_noop():
    """A FaultSpec whose only content is a zero-width window installs a
    fault model, but every trace and timestamp must equal the fault-free
    run exactly (the regression the ISSUE demands)."""
    from repro.configs.paper_tiers import TIERS
    from repro.fl.client import FLClient
    from repro.scenario import BlackoutSpec, FaultSpec, Scenario, \
        TopologySpec, build_runtime

    def trace(faults):
        rt = build_runtime(Scenario(
            name="z", topology=TopologySpec(num_clients=6),
            faults=faults))
        clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                            sim_train_s=30.0) for h in rt.env.clients]
        sched = FLScheduler(rt.make_backend("server"), clients,
                            FedBuffStrategy(buffer_k=3,
                                            staleness_exponent=0.5),
                            local_steps=1)
        rep = sched.run(VirtualPayload(32 * MB, tag="t"),
                        max_aggregations=4)
        return tuple(sched.loop.trace), rep.sim_time

    clean = trace(FaultSpec())
    zero = trace(FaultSpec(blackouts=(
        BlackoutSpec("server", "client0", 10.0, 10.0),)))
    assert clean == zero


# ---------------------------------------------------------------------------
# chunk retransmit over a real backend
# ---------------------------------------------------------------------------

@pytest.fixture
def deployment():
    env = TopologySpec.preset("geo_distributed", num_clients=7).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return env, fabric, store


def test_chunk_loss_recovers_via_retransmit_exactly_once(deployment):
    env, fabric, store = deployment
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.25, seed=3)
    be = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=4)
    cl = make_backend("grpc", env, fabric, "client3", store=store)
    h = be.isend(FLMessage("m", "server", "client3",
                           payload=VirtualPayload(64 * MB)), 0.0)
    assert not h.failed and math.isfinite(h.arrive)
    assert fabric.stats["retransmits"] > 0  # faults actually fired
    got = cl.recv(h.arrive + 1.0)
    assert len(got) == 1 and got[0][0].payload.nbytes == 64 * MB
    assert cl.next_arrival() is None  # fully reassembled, nothing wedged


def test_chunk_loss_makes_transfer_slower_not_wedged(deployment):
    env, fabric, store = deployment
    clean = make_backend("grpc", env, fabric, "server", store=store,
                         chunk_mb=4)
    h0 = clean.isend(FLMessage("m", "server", "client3",
                               payload=VirtualPayload(64 * MB)), 0.0)
    fabric.endpoints["client3"].inbox.clear()
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.25, seed=3)
    lossy = make_backend("grpc", env, fabric, "server", store=store,
                         chunk_mb=4)
    h1 = lossy.isend(FLMessage("m", "server", "client3",
                               payload=VirtualPayload(64 * MB)), 0.0)
    assert h1.arrive > h0.arrive  # retransmits cost time...
    assert h1.arrive < 3 * h0.arrive  # ...but bounded


def test_exhausted_retries_fail_the_send_handle(deployment):
    env, fabric, store = deployment
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.9999, max_retries=2,
                                        seed=0)
    be = make_backend("grpc", env, fabric, "server", store=store)
    h = be.isend(FLMessage("m", "server", "client1",
                           payload=VirtualPayload(8 * MB)), 0.0)
    assert h.failed and math.isinf(h.arrive)
    assert fabric.stats["transfers_failed"] >= 1
    cl = make_backend("grpc", env, fabric, "client1", store=store)
    assert cl.recv(1e9) == []  # nothing was delivered
    assert cl.next_arrival() is None


def test_zero_rate_fault_model_is_bit_for_bit_noop(deployment):
    env, fabric, store = deployment
    be = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=8)
    msg = lambda: FLMessage("m", "server", "client2",
                            payload=VirtualPayload(64 * MB))
    h0 = be.isend(msg(), 0.0)
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.0, seed=9)
    be2 = make_backend("grpc", env, fabric, "server", store=store, chunk_mb=8)
    h1 = be2.isend(msg(), 0.0)
    assert h1.arrive == h0.arrive and h1.start == h0.start


# ---------------------------------------------------------------------------
# scheduler under churn
# ---------------------------------------------------------------------------

def _deployment(backend="grpc", n=4, env_name="geo_distributed"):
    env = TopologySpec.preset(env_name, num_clients=n).build()
    fabric = Fabric(env)
    store = ObjectStore(NCAL)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    from repro.fl import FLClient
    clients = [FLClient(h.host_id,
                        make_backend(backend, env, fabric, h.host_id,
                                     store=store), sim_train_s=5.0)
               for h in env.clients]
    sb = make_backend(backend, env, fabric, "server", store=store)
    return sb, clients, store


def test_fedbuff_discards_midround_departure_and_rejoins():
    sb, clients, _ = _deployment(n=4)
    # client1 leaves while its first update is in flight (train ends ~5s
    # after model arrival), rejoins later, leaves again at the horizon
    trace = AvailabilityTrace.parse("client1:leave@5.5,join@15")
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=2, staleness_exponent=0.5),
                        availability=trace)
    rep = sched.run(VirtualPayload(4 * MB, tag="churn"), max_aggregations=8)
    assert rep.n_departures == 1 and rep.n_rejoins == 1
    assert rep.n_discarded >= 1  # the in-flight update was not counted
    assert rep.n_aggregations == 8  # the fleet kept making progress
    # while down, client1 contributed nothing
    down = [cid for (t, cid, _) in sched.update_log if 6 < t < 15]
    assert "client1" not in down


def test_quick_leave_rejoin_blip_does_not_duplicate_pipeline():
    """A leave/rejoin blip while the model is still in flight must not
    leave the client with two permanent dispatch->train->upload loops:
    the pre-leave model is dropped on arrival (stale generation), the
    rejoin dispatch owns the pipeline."""
    def run(trace):
        sb, clients, _ = _deployment(n=2)
        sched = FLScheduler(
            sb, clients, FedBuffStrategy(buffer_k=1, staleness_exponent=0.0),
            availability=trace)
        # 200 MB over the WAN: the model is in flight well past the blip
        sched.run(VirtualPayload(200 * MB, tag="blip"), max_aggregations=12)
        counts = {}
        for (_, cid, _) in sched.update_log:
            counts[cid] = counts.get(cid, 0) + 1
        return counts
    base = run(None)
    blip = run(AvailabilityTrace.parse("client0:leave@0.5,join@0.9"))
    # one pipeline only: the blip must not let client0 out-report its own
    # churn-free baseline (a duplicated loop roughly doubles its count)
    assert blip.get("client0", 0) <= base.get("client0", 0)
    assert blip.get("client1", 0) >= base.get("client1", 0) - 1


def test_rejoin_over_s3_is_a_late_refetch_not_a_reupload():
    sb, clients, store = _deployment(backend="grpc+s3", n=3)
    trace = AvailabilityTrace.parse("client1:leave@2,join@10")
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=2, staleness_exponent=0.0),
                        availability=trace)
    rep = sched.run(VirtualPayload(16 * MB, tag="s3churn"),
                    max_aggregations=4)
    assert rep.n_late_refetches >= 1
    assert rep.n_rejoins == 1


def test_semisync_quorum_shrinks_when_clients_leave():
    sb, clients, _ = _deployment(n=4)
    # two clients leave before anyone reports: quorum 1.0 over 4 would
    # stall forever; over the live fleet the round closes with 2
    trace = AvailabilityTrace.parse("client2:leave@1;client3:leave@1")
    sched = FLScheduler(sb, clients,
                        SemiSyncStrategy(quorum_fraction=1.0),
                        availability=trace)
    rep = sched.run(VirtualPayload(4 * MB, tag="semi"), max_aggregations=3)
    assert rep.n_aggregations == 3
    assert all(e.n_updates <= 2 for e in sched.agg_log)


def test_hier_skips_below_quorum_region_and_folds_in_on_rejoin():
    # 8 clients over 7 regions: region ncal holds client0 AND client7
    sb, clients, _ = _deployment(n=8)
    strat = HierarchicalStrategy(region_quorum=0.5)
    # both ncal members leave mid-round-2 (region churns to 0/2 live,
    # below any quorum); client0 rejoins a couple of rounds later
    trace = AvailabilityTrace.parse(
        "client0:leave@7,join@13;client7:leave@7")
    sched = FLScheduler(sb, clients, strat, availability=trace,
                        local_steps=1)
    rep = sched.run(VirtualPayload(4 * MB, tag="hier"), max_aggregations=5)
    assert rep.n_aggregations == 5
    assert strat.rounds_with_skips >= 1  # ncal skipped while below quorum
    # per-round relay partials: 7 regions full, 6 while ncal is churned
    # out (mid-round departure then begin-of-round skip), back to 7 once
    # client0 rejoins (folded in with 1 of 2 members live)
    regions_per_round = [e.n_updates for e in sched.agg_log]
    assert regions_per_round[0] == 7
    assert 6 in regions_per_round
    assert regions_per_round[-1] == 7
    # client updates: 8 (full) + 6 + 6 + 7 + 7 (one ncal member back)
    assert rep.n_client_updates == 34


def test_hier_relay_migrates_to_live_member_under_churn():
    """The relay is elected per round among *live* members: with a
    region's first member down, the surviving member's host carries the
    fan-out, the LAN legs and the WAN partial (a departed host must not
    keep transmitting the region's traffic)."""
    sb, clients, _ = _deployment(n=8)
    strat = HierarchicalStrategy(region_quorum=0.5)
    # ncal = {client0, client7}; client0 leaves at t=0 and never returns
    trace = AvailabilityTrace.parse("client0:leave@0")
    sched = FLScheduler(sb, clients, strat, availability=trace,
                        local_steps=1)
    rep = sched.run(VirtualPayload(4 * MB, tag="mig"), max_aggregations=3)
    assert rep.n_aggregations == 3
    assert strat._relay_host["ncal"] == "client7"
    assert "client7" in strat._relay_be  # the live member's channel


def test_hier_full_quorum_no_churn_unchanged():
    """The quorum machinery must be a pure no-op without churn: same
    aggregation count and per-round participation as the fleet size."""
    sb, clients, _ = _deployment(n=8)
    strat = HierarchicalStrategy(region_quorum=1.0)
    sched = FLScheduler(sb, clients, strat, local_steps=1)
    rep = sched.run(VirtualPayload(4 * MB, tag="noc"), max_aggregations=2)
    assert rep.n_aggregations == 2
    assert rep.n_client_updates == 16  # 8 clients x 2 rounds
    assert strat.rounds_with_skips == 0


def test_scheduler_survives_failed_transfers_via_redispatch():
    sb, clients, _ = _deployment(n=3)
    fabric = sb.fabric
    # high loss + tiny retry budget: some sends fail outright; the
    # scheduler's backoff redispatch must still finish the run
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.45, max_retries=1,
                                        seed=11)
    sched = FLScheduler(sb, clients,
                        FedBuffStrategy(buffer_k=2, staleness_exponent=0.0),
                        redispatch_backoff_s=5.0)
    rep = sched.run(VirtualPayload(8 * MB, tag="lossy"), max_aggregations=4)
    assert rep.n_aggregations == 4
    assert rep.n_transfer_failures > 0  # failures happened AND were healed


def test_availability_trace_runs_are_deterministic():
    def once():
        sb, clients, _ = _deployment(n=4)
        trace = AvailabilityTrace.generate(
            [c.client_id for c in clients], 200.0, mean_up_s=30,
            mean_down_s=10, seed=4)
        sched = FLScheduler(sb, clients,
                            FedBuffStrategy(buffer_k=2,
                                            staleness_exponent=0.5),
                            availability=trace)
        sched.run(VirtualPayload(4 * MB, tag="det"), max_aggregations=6)
        return sched
    a, b = once(), once()
    assert a.loop.trace == b.loop.trace
    assert a.update_log == b.update_log
    assert (a.departures, a.rejoins) == (b.departures, b.rejoins)
