"""QSGD / top-k compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.compression import (QuantState, qsgd_compress, qsgd_decompress,
                               qsgd_init, topk_compress, topk_decompress)
from repro.compression.qsgd import packed_nbytes


def _tree(rng, n=300):
    return {"w": jnp.asarray(rng.normal(size=(n,)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(17,)).astype(np.float32))}


def test_qsgd_roundtrip_error_small(rng):
    tree = _tree(rng)
    packed, _, unflatten = qsgd_compress(tree)
    rec = qsgd_decompress(packed, unflatten)
    for k in tree:
        err = np.max(np.abs(np.asarray(rec[k] - tree[k])))
        assert err < 0.05 * float(jnp.max(jnp.abs(tree[k])))


def test_qsgd_wire_reduction(rng):
    tree = _tree(rng, n=65536)  # large enough to amortise tile padding
    packed, _, _ = qsgd_compress(tree, block=256)
    raw = sum(np.asarray(v).nbytes for v in jax.tree.leaves(tree))
    assert packed_nbytes(packed) < 0.30 * raw  # ~4x reduction


def test_qsgd_error_feedback_reduces_bias(rng):
    """With error feedback, the *accumulated* compressed stream converges
    to the accumulated true stream (compression is asymptotically unbiased)."""
    state = qsgd_init(_tree(rng))
    true_sum = None
    sent_sum = None
    for i in range(20):
        tree = _tree(np.random.default_rng(i))
        packed, state, unflatten = qsgd_compress(tree, state)
        rec = qsgd_decompress(packed, unflatten)
        true_sum = rec if true_sum is None else true_sum
        if i == 0:
            true_acc = jax.tree.map(lambda x: x, tree)
            sent_acc = jax.tree.map(lambda x: x, rec)
        else:
            true_acc = jax.tree.map(jnp.add, true_acc, tree)
            sent_acc = jax.tree.map(jnp.add, sent_acc, rec)
    resid = np.max(np.abs(np.asarray(sent_acc["w"] - true_acc["w"])))
    # residual stays bounded by one quantisation step (does not accumulate)
    assert resid < 0.1


@given(frac=st.floats(0.01, 0.5))
@settings(max_examples=10, deadline=None)
def test_topk_keeps_largest(frac):
    rng = np.random.default_rng(1)
    tree = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    payload, _, unflatten = topk_compress(tree, frac)
    rec = topk_decompress(payload, unflatten)
    w, r = np.asarray(tree["w"]), np.asarray(rec["w"])
    kept = np.nonzero(r)[0]
    assert len(kept) <= max(1, int(0.5 + 273 * frac)) + 1
    if len(kept):
        thresh = np.min(np.abs(w[kept]))
        dropped = np.setdiff1d(np.arange(256), kept)
        assert np.all(np.abs(w[dropped]) <= thresh + 1e-6)


def test_topk_error_feedback_eventually_sends_everything():
    """One real update followed by zero updates: error feedback must drain
    every component over subsequent rounds (nothing is lost forever)."""
    first = {"w": jnp.asarray(np.array([10.0, 1.0, 0.1, 0.01], np.float32))}
    zeros = {"w": jnp.zeros(4)}
    state = QuantState(error=jnp.zeros(4))
    total = jnp.zeros(4)
    payload, state, unflatten = topk_compress(first, 0.25, state)
    total = total + topk_decompress(payload, unflatten)["w"]
    for _ in range(6):
        payload, state, unflatten = topk_compress(zeros, 0.25, state)
        total = total + topk_decompress(payload, unflatten)["w"]
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(first["w"]), rtol=1e-6)
