"""Network model invariants (hypothesis property tests + Table I checks)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.netsim import (BAHRAIN, GEO_REGIONS, HONGKONG, MB, NCAL,
                               Host, Region, Transfer, geo_distributed_env,
                               lan_env, simulate_transfers,
                               transfer_time)


def test_table1_values_loaded():
    assert NCAL.bw_single == 592 * MB and NCAL.bw_multi == 2946 * MB
    assert BAHRAIN.latency == pytest.approx(111e-3)
    assert len(GEO_REGIONS) == 7


def test_conn_cap_monotone_saturates():
    caps = [BAHRAIN.conn_cap(n) for n in (1, 2, 16, 64, 1000)]
    assert all(b >= a for a, b in zip(caps, caps[1:]))
    assert caps[-1] == BAHRAIN.bw_multi  # saturates at multi-conn bw


@given(nbytes=st.integers(1, 10 ** 10), conns=st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_single_transfer_time_positive_and_bounded(nbytes, conns):
    t = transfer_time(nbytes, HONGKONG, conns)
    assert t >= HONGKONG.latency
    # cannot beat the multi-connection cap
    assert t >= HONGKONG.latency + nbytes / HONGKONG.bw_multi - 1e-9


@given(n=st.integers(1, 8), nbytes=st.integers(10 ** 6, 10 ** 9))
@settings(max_examples=30, deadline=None)
def test_concurrent_never_faster_than_uncontended(n, nbytes):
    env = geo_distributed_env()
    server = env.server
    dst = env.clients[6]  # bahrain
    transfers = [Transfer(start=0.0, src=server, dst=dst, nbytes=nbytes,
                          conns=1) for _ in range(n)]
    simulate_transfers(transfers)
    uncontended = transfer_time(nbytes, dst.region, 1)
    for t in transfers:
        assert t.finish >= uncontended - 1e-6
    # conservation: aggregate throughput <= host uplink
    total_bytes = n * nbytes
    span = max(t.finish for t in transfers) - dst.region.latency
    assert total_bytes / span <= server.uplink * 1.01


def test_concurrent_beats_sequential_over_wan():
    env = geo_distributed_env()
    dst = env.clients[6]
    n, nbytes = 8, 100 * MB
    conc = [Transfer(start=0.0, src=env.server, dst=dst, nbytes=nbytes)
            for _ in range(n)]
    simulate_transfers(conc)
    t_conc = max(t.finish for t in conc)
    t_seq = n * transfer_time(nbytes, dst.region, 1)
    # paper Fig 4b: concurrency mitigates WAN latency/bw underutilisation
    assert t_conc < t_seq


def test_fluid_staggered_starts():
    env = geo_distributed_env()
    dst = env.clients[1]
    a = Transfer(start=0.0, src=env.server, dst=dst, nbytes=50 * MB)
    b = Transfer(start=100.0, src=env.server, dst=dst, nbytes=50 * MB)
    simulate_transfers([a, b])
    assert a.finish < 100.0  # finished before b starts
    assert b.finish == pytest.approx(100.0 + transfer_time(50 * MB, dst.region, 1),
                                     rel=1e-3)


def test_environments():
    from repro.scenario import TopologySpec
    for name in ("lan", "geo_proximal", "geo_distributed"):
        env = TopologySpec.preset(name, num_clients=7).build()
        assert len(env.clients) == 7
    assert lan_env().trusted and not geo_distributed_env().trusted
