"""HLO cost-walk correctness: trip counts, dots, collectives, DUS bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import (collective_effective_bytes, entry_cost,
                                     parse_replica_groups)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 32), jnp.float32))
    cost = entry_cost(c.as_text())
    expect = 2 * 64 * 128 * 32
    assert cost.flops == pytest.approx(expect, rel=0.3)


def test_scan_trip_count_multiplies():
    def step(x, w):
        return jnp.tanh(x @ w), None

    def g(x, ws):
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    costs = {}
    for n in (2, 8):
        c = _compile(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((n, 64, 64), jnp.float32))
        costs[n] = entry_cost(c.as_text()).flops
    assert costs[8] / costs[2] == pytest.approx(4.0, rel=0.1)


def test_nested_scan_trip_counts():
    def inner(x, w):
        return x * w, None

    def outer(x, ws):
        def body(x, w_outer):
            y, _ = jax.lax.scan(inner, x, ws)
            return y + w_outer, None
        z, _ = jax.lax.scan(body, x, jnp.ones((5,)))
        return z.sum()

    c = _compile(lambda x, ws: outer(x, ws),
                 jax.ShapeDtypeStruct((128,), jnp.float32),
                 jax.ShapeDtypeStruct((3, 128), jnp.float32))
    cost = entry_cost(c.as_text())
    # 5 outer x (3 inner muls of 128) + 5 adds of 128 ~ 5*3*128 + 5*128
    assert cost.flops >= 5 * 3 * 128


def test_replica_group_parsing():
    size, groups = parse_replica_groups("replica_groups={{0,1},{2,3}}")
    assert size == 2 and groups == [[0, 1], [2, 3]]
    size, groups = parse_replica_groups(
        "replica_groups=[4,2]<=[2,4]T(1,0), attr=1")
    assert size == 2
    assert sorted(groups[0]) == [0, 4]


def test_collective_formulas():
    # ring all-reduce: 2(n-1)/n
    assert collective_effective_bytes("all-reduce", 1000, 1000, 4) == \
        pytest.approx(1500)
    assert collective_effective_bytes("all-gather", 1600, 400, 4) == \
        pytest.approx(1200)
    assert collective_effective_bytes("reduce-scatter", 400, 1600, 4) == \
        pytest.approx(1200)
    assert collective_effective_bytes("all-reduce", 1000, 1000, 1) == 0.0


def test_dus_counts_slice_not_buffer():
    def f(buf, x):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(b, x, i * 4, 0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return out.sum()

    c = _compile(f, jax.ShapeDtypeStruct((4096, 256), jnp.float32),
                 jax.ShapeDtypeStruct((4, 256), jnp.float32))
    cost = entry_cost(c.as_text())
    buf_bytes = 4096 * 256 * 4
    # 64 iterations touching a 4x256 slice each must NOT count 64 full buffers
    assert cost.hbm_bytes < 10 * buf_bytes
