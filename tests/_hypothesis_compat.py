"""Fallback shims so the property tests degrade gracefully when
``hypothesis`` is not installed (the seed image ships without it).

With hypothesis present, this module re-exports the real ``given`` /
``settings`` / ``strategies``. Without it, ``given`` runs the test body
over a deterministic seeded sample of each strategy (``max_examples``
draws, honouring ``@settings``) — weaker than real shrinking/coverage,
but the invariants still execute and the module collects cleanly.

Usage in test modules::

    from tests._hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd: random.Random):
            return self._draw(rnd)

    class _StrategyModule:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: elements[r.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.example(r) for _ in range(n)]
            return _Strategy(draw)

    st = _StrategyModule()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
                 **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategy_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rnd = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = {k: s.example(rnd)
                             for k, s in strategy_kw.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest resolves fixtures from the signature: hide the
            # strategy-provided parameters (and the __wrapped__ chain
            # functools.wraps leaves behind, which pytest would follow)
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategy_kw]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco
