#!/usr/bin/env python
"""Lint: all wire-stat mutations must flow through Fabric.account.

The multi-tenant fabric keeps one global ledger plus a per-job view and
guarantees the views sum to the global exactly. That invariant lives in
ONE method — ``Fabric.account`` — so any code that writes
``fabric.stats[...] += ...`` (or pokes a ``stats_for(...)`` /
``job_stats[...]`` view) directly will silently desynchronise the
per-job decomposition. This script fails CI on any such write outside
the Fabric class in src/repro/core/transport.py.

Usage: python scripts/check_stats_discipline.py [root ...]
Exits 1 and prints file:line for every violation.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_ROOTS = ("src", "benchmarks", "examples", "scripts")
ALLOWED = REPO / "src" / "repro" / "core" / "transport.py"
STAT_NAMES = {"stats", "job_stats"}


def _is_stats_store(node: ast.expr) -> bool:
    """True for stats writes through a *foreign* object:
    ``<x>.stats[...]``, ``<x>.job_stats[...]``,
    ``<x>.stats_for(...)[...]``. A class mutating its own ledger
    (``self.stats[...]``) is its own business; reaching into another
    object's ledger bypasses that object's accounting invariants."""
    if not isinstance(node, ast.Subscript):
        return False
    base = node.value
    if isinstance(base, ast.Attribute) and base.attr in STAT_NAMES:
        owner = base.value
        return not (isinstance(owner, ast.Name) and owner.id == "self")
    if (isinstance(base, ast.Call)
            and isinstance(base.func, ast.Attribute)
            and base.func.attr == "stats_for"):
        owner = base.func.value
        return not (isinstance(owner, ast.Name) and owner.id == "self")
    return False


def _violations(path: Path) -> list[int]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:  # a broken file is its own CI failure
        print(f"{path}: unparseable ({exc})", file=sys.stderr)
        return [exc.lineno or 0]
    lines = []
    for node in ast.walk(tree):
        targets: list[ast.expr] = []
        if isinstance(node, ast.AugAssign):
            targets = [node.target]
        elif isinstance(node, ast.Assign):
            targets = list(node.targets)
        for tgt in targets:
            if _is_stats_store(tgt):
                lines.append(node.lineno)
    return lines


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [REPO / r for r in DEFAULT_ROOTS]
    bad = []
    for root in roots:
        if not root.exists():
            continue
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if f.resolve() == ALLOWED:
                continue  # Fabric.account and friends live here
            for ln in _violations(f):
                try:
                    rel = f.relative_to(REPO)
                except ValueError:
                    rel = f
                bad.append(f"{rel}:{ln}")
    if bad:
        print("stats-discipline violations (mutate wire stats only via "
              "Fabric.account):", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 1
    print("stats discipline OK: no direct stats mutations outside "
          "Fabric.account")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
