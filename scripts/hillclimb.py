import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: the three selected cells, hypothesis -> change ->
re-lower -> validate. Every variant is persisted under artifacts/hillclimb/.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  A. granite-moe-1b-a400m x train_4k  — worst roofline fraction (1.5%)
  B. deepseek-67b        x train_4k  — most collective-bound (72s ICI term)
  C. qwen3-8b x train_4k FL round @2x16x16 — the paper's technique
     (cross-silo sync at pod scale): f32 vs int8 delta exchange, local-K.

Usage: PYTHONPATH=src python scripts/hillclimb.py [A|B|C|all]
"""
import dataclasses
import json
import sys

import jax

from repro.configs.base import (MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig)
from repro.launch.dryrun import run_cell

OUT = "artifacts/hillclimb"


def report(rec, label):
    if rec["status"] != "ok":
        print(f"  {label}: {rec['status']} {rec.get('error','')[:200]}")
        return
    rl = rec["roofline"]
    print(f"  {label:34s} compute={rl['t_compute']*1e3:9.1f}ms "
          f"memory={rl['t_memory']*1e3:7.1f}ms "
          f"ici={rl['t_collective']*1e3:9.1f}ms "
          f"dcn={rl['t_dcn']*1e3:8.1f}ms -> {rl['dominant']}-bound "
          f"frac={rl['roofline_fraction']*100:5.2f}% "
          f"useful={rl['useful_flops_ratio']*100:5.1f}%")


def mesh_cfg(shape, axes=("data", "model"), **kw):
    return MeshConfig(shape=shape, axis_names=axes, **kw)


def cell_A():
    print("== Cell A: granite-moe-1b-a400m x train_4k (worst fraction) ==")
    arch, shape = "granite-moe-1b-a400m", "train_4k"
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   tag_suffix="__base", verbose=False)
    report(rec, "baseline 16x16 TP16")
    # H1: TP=16 on d_ff=512 experts is pure overhead for a 1.3B model;
    # 256-way FSDP (model axis width 1) removes activation all-reduces
    # and EP resharding entirely. Predict collective 3.5s -> ~0.2s.
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((256, 1)), mesh_label="pod256x1",
                   tag_suffix="__fsdp256", train_kw=dict(microbatches=1),
                   verbose=False)
    report(rec, "H1 remap 256x1 pure FSDP")
    # H2: intermediate 64x4 (keeps some TP for activation memory headroom)
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((64, 4)), mesh_label="pod64x4",
                   tag_suffix="__fsdp64tp4", train_kw=dict(microbatches=1),
                   verbose=False)
    report(rec, "H2 remap 64x4")
    # H3: on the best mesh, bigger dispatch groups cut router/dispatch
    # matmul flops per token (group 2048 -> 512: dispatch cost ~ g*k*cf*d)
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((256, 1)), mesh_label="pod256x1",
                   tag_suffix="__fsdp256_group512",
                   overrides=dict(moe_group_size=512),
                   train_kw=dict(microbatches=1), verbose=False)
    report(rec, "H3 256x1 + dispatch group 512")
    # H4: capacity factor 1.25 -> 1.0 (drop tokens instead of padding)
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((256, 1)), mesh_label="pod256x1",
                   tag_suffix="__fsdp256_group512_cap1",
                   overrides=dict(moe_group_size=512, capacity_factor=1.0),
                   train_kw=dict(microbatches=1), verbose=False)
    report(rec, "H4 + capacity 1.0")


def cell_B():
    print("== Cell B: deepseek-67b x train_4k (most collective-bound) ==")
    arch, shape = "deepseek-67b", "train_4k"
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   tag_suffix="__base", verbose=False)
    report(rec, "baseline 16x16 TP16 mb8")
    # H1: TP16 activation all-reduces dominate (95L x ~4 AR x act bytes).
    # Remap to FSDP64 x TP4: AR group 16->4 shrinks ring factor and the
    # per-device activation slab 4x. Predict ici 72s -> ~15-20s.
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((64, 4)), mesh_label="pod64x4",
                   tag_suffix="__fsdp64tp4", train_kw=dict(microbatches=4),
                   verbose=False)
    report(rec, "H1 remap 64x4 mb4")
    # H2: push further: FSDP128 x TP2
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((128, 2)), mesh_label="pod128x2",
                   tag_suffix="__fsdp128tp2", train_kw=dict(microbatches=2),
                   verbose=False)
    report(rec, "H2 remap 128x2 mb2")
    # H3: pure FSDP 256 (param all-gathers replace activation ARs; for 67B
    # params the AG traffic ~3x param bytes may exceed H2's activation cost)
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((256, 1)), mesh_label="pod256x1",
                   tag_suffix="__fsdp256", train_kw=dict(microbatches=2),
                   verbose=False)
    report(rec, "H3 remap 256x1 pure FSDP mb2")
    # H3 REFUTED as run: mb2 makes the per-microbatch batch (128) indivisible
    # by 256 -> the batch spec falls back to replication and every chip
    # recomputes the full batch. H3' fixes the microbatching.
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((256, 1)), mesh_label="pod256x1",
                   tag_suffix="__fsdp256_mb1", train_kw=dict(microbatches=1),
                   verbose=False)
    report(rec, "H3' remap 256x1 pure FSDP mb1")
    # H4: 128x2 with mb1 (fewer passes -> fewer param re-gathers)
    rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                   mesh_cfg=mesh_cfg((128, 2)), mesh_label="pod128x2",
                   tag_suffix="__fsdp128tp2_mb1", train_kw=dict(microbatches=1),
                   verbose=False)
    report(rec, "H4 remap 128x2 mb1")


def cell_C():
    print("== Cell C: qwen3-8b FL round @2x16x16 (paper technique) ==")
    arch, shape = "qwen3-8b", "train_4k"
    # baseline: fully synchronous two-pod training (per-step DCN all-reduce)
    rec = run_cell(arch, shape, multi_pod=True, out_dir=OUT,
                   tag_suffix="__sync_base", verbose=False)
    report(rec, "baseline sync 2x16x16")
    # H1: the paper's round structure at pod scale — K=2 local steps then
    # f32 delta exchange (DCN bytes /K, paid as one fused sync)
    rec = run_cell(arch, shape, multi_pod=True, fl=True, out_dir=OUT,
                   fl_compress="none", tag_suffix="__fl_f32", verbose=False)
    report(rec, "H1 FL round K=2, f32 deltas")
    # H2: + int8 quantised deltas (QSGD kernel semantics, int8 all-gather
    # + local reduce): DCN bytes /4 vs f32
    rec = run_cell(arch, shape, multi_pod=True, fl=True, out_dir=OUT,
                   fl_compress="int8", tag_suffix="__fl_int8", verbose=False)
    report(rec, "H2 FL round K=2, int8 deltas")
    # H3: amortise further: K=8 local steps per exchange
    rec = run_cell(arch, shape, multi_pod=True, fl=True, out_dir=OUT,
                   fl_compress="int8", fl_local_steps=8,
                   tag_suffix="__fl_int8_k8", verbose=False)
    report(rec, "H3 FL round K=8, int8 deltas")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("A", "all"):
        cell_A()
        jax.clear_caches()
    if which in ("B", "all"):
        cell_B()
        jax.clear_caches()
    if which in ("C", "all"):
        cell_C()
