"""Render the §Dry-run / §Roofline tables for EXPERIMENTS.md from
artifacts/dryrun/*.json."""
import glob
import json
import sys


def load(mesh):
    rows = {}
    for p in sorted(glob.glob(f"artifacts/dryrun/{mesh}/*.json")):
        r = json.load(open(p))
        rows[(r["arch"], r["shape"], r.get("fl", False))] = r
    return rows


def dryrun_table(mesh):
    rows = load(mesh)
    out = [f"| arch | shape | status | kind | args GiB/dev | temp GiB/dev | compile s |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape, fl), r in sorted(rows.items()):
        if fl:
            continue
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | SKIP | — | — | — | — |")
            continue
        ma = r["memory_analysis"]
        out.append(
            f"| {arch} | {shape} | ok | {r['kind']} | "
            f"{ma['argument_bytes'] / 2**30:.2f} | "
            f"{ma['temp_bytes'] / 2**30:.2f} | {r['compile_s']} |")
    return "\n".join(out)


def roofline_table(mesh):
    rows = load(mesh)
    out = ["| arch | shape | compute s | memory s | ICI s | DCN s | bound | "
           "MODEL/HLO flops | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    LEVERS = {
        "collective": "shrink TP group / sequence-parallel the activation "
                      "all-reduces",
        "memory": "decode is cache-bandwidth-bound: quantise KV cache to int8",
        "compute": "raise MXU utilisation (larger per-chip tiles)",
        "dcn": "local-step + int8 delta sync over the pod axis (cell C)",
    }
    for (arch, shape, fl), r in sorted(rows.items()):
        if fl or r["status"] != "ok":
            continue
        rl = r["roofline"]
        out.append(
            f"| {arch} | {shape}{' (fl)' if fl else ''} | "
            f"{rl['t_compute']:.3f} | {rl['t_memory']:.3f} | "
            f"{rl['t_collective']:.3f} | {rl['t_dcn']:.3f} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']*100:.0f}% | "
            f"{rl['roofline_fraction']*100:.2f}% | "
            f"{LEVERS.get(rl['dominant'], '')} |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    which = sys.argv[2] if len(sys.argv) > 2 else "both"
    if which in ("dryrun", "both"):
        print(dryrun_table(mesh))
        print()
    if which in ("roofline", "both"):
        print(roofline_table(mesh))
