"""Dev-only quick check of every family's fwd/bwd/decode on tiny configs."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_ORDER, smoke_config
from repro.models import build_model


def check(name):
    cfg = smoke_config(name)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params, axes = model.init(rng)
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    b, s = 2, 16
    batch = {}
    if cfg.external_embeddings:
        batch["embeds"] = jax.random.normal(rng, (b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    batch["targets"] = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), (name, "grad nan")

    out = [f"{name}: params={n:,} loss={float(loss):.3f} gnorm={float(gnorm):.3f}"]
    if cfg.causal:
        cache = model.init_cache(b, 32)
        db = {"tokens": batch.get("tokens", jnp.zeros((b, s), jnp.int32))[:, :1],
              "pos": jnp.int32(0)}
        logits, cache = jax.jit(model.decode_step)(params, cache, db)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), (name, "decode nan")
        out.append("decode ok")
    print(" | ".join(out))


if __name__ == "__main__":
    names = sys.argv[1:] or ARCH_ORDER
    for nm in names:
        check(nm)
