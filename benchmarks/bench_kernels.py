"""Pallas kernel microbenchmarks (interpret-mode CPU walltime is NOT TPU
perf — the derived column reports bytes handled per call, the roofline
relevant quantity)."""
from __future__ import annotations

BENCH_NAME = "kernels"
BENCH_ORDER = 200
BENCH_IN_QUICK = False  # JAX-heavy; skipped by the CI smoke

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.fedavg_reduce import fedavg_reduce, fedavg_reduce_q8
from repro.kernels.quantize import dequantize_blocks, quantize_blocks


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.normal(size=(512, 256)).astype(np.float32))
    t = _time(lambda a: quantize_blocks(a, interpret=True), x)
    rows.append({"name": "kernel/quantize_512x256", "us_per_call": t * 1e6,
                 "derived": f"{x.nbytes / t / 1e6:.0f}MB/s-interp"})
    q, s = quantize_blocks(x, interpret=True)
    t = _time(lambda a, b: dequantize_blocks(a, b, interpret=True), q, s)
    rows.append({"name": "kernel/dequantize_512x256", "us_per_call": t * 1e6,
                 "derived": f"{x.nbytes / t / 1e6:.0f}MB/s-interp"})
    u = jnp.asarray(rng.normal(size=(8, 8192)).astype(np.float32))
    w = jnp.ones((8,), jnp.float32)
    t = _time(lambda a, b: fedavg_reduce(a, b, interpret=True), u, w)
    rows.append({"name": "kernel/fedavg_8x8192", "us_per_call": t * 1e6,
                 "derived": f"{u.nbytes / t / 1e6:.0f}MB/s-interp"})
    qs = [ops.quantize_flat(u[i], block=256) for i in range(8)]
    qq = jnp.stack([p["q"] for p in qs])
    ss = jnp.stack([p["scales"] for p in qs])
    t = _time(lambda a, b, c: fedavg_reduce_q8(a, b, c, block=256,
                                               interpret=True), qq, ss, w)
    rows.append({"name": "kernel/fedavg_q8_8x8192", "us_per_call": t * 1e6,
                 "derived": f"{qq.nbytes / t / 1e6:.0f}MB/s-interp"})
    if verbose:
        print("\n== Pallas kernels (interpret mode) ==")
        for r in rows:
            print(f"{r['name']:28s} {r['us_per_call']:10.0f}us  {r['derived']}")
    return rows


if __name__ == "__main__":
    run()
