"""Table I: single- vs multi-connection bandwidth and latency per region
(model check: the netsim reproduces its own calibration measurements via
actual simulated transfers, not just constants)."""
from __future__ import annotations

BENCH_NAME = "table1"
BENCH_ORDER = 10

from repro.core.netsim import (GEO_REGIONS, MB, Host, Transfer,
                               simulate_transfers)


def run(verbose=True):
    rows = []
    nbytes = 512 * MB
    if verbose:
        print("\n== Table I: EC2 link characterization (hub = N.California) ==")
        print(f"{'region':12s} {'single MB/s':>12s} {'multi MB/s':>12s} "
              f"{'latency ms':>11s}")
    for r in GEO_REGIONS:
        src = Host("server", r, r.bw_multi, r.bw_multi)
        dst = Host("client", r, r.bw_multi, r.bw_multi)
        t1 = Transfer(start=0.0, src=src, dst=dst, nbytes=nbytes, conns=1,
                      link_region=r)
        tn = Transfer(start=0.0, src=src, dst=dst, nbytes=nbytes, conns=64,
                      link_region=r)
        simulate_transfers([t1])
        simulate_transfers([tn])
        bw1 = nbytes / (t1.finish - r.latency) / MB
        bwn = nbytes / (tn.finish - r.latency) / MB
        rows.append({"name": f"table1/{r.name}", "bw_single_MBps": bw1,
                     "bw_multi_MBps": bwn, "latency_ms": r.latency * 1e3})
        if verbose:
            print(f"{r.name:12s} {bw1:12.1f} {bwn:12.1f} "
                  f"{r.latency * 1e3:11.2f}")
    return rows


if __name__ == "__main__":
    run()
