"""BENCH_6: the perf trajectory record this PR starts.

Measures the two things PR 6 changed — engine throughput and encode
throughput — writes them to ``benchmarks/out/BENCH_6.json`` and gates
against the committed record ``benchmarks/BENCH_6.json`` so a future PR
that regresses either by >10% fails the bench run.

Cross-machine comparisons use *ratios*, not absolute seconds:

* ``encode.speedup``        — fused ``quantize_flat_batch`` MB/s over the
  legacy pure-NumPy per-message codec MB/s, small-message regime (this
  is where per-message dispatch overhead dominated).
* ``engine.replay_per_unit``— cached-replay cells/s normalised by a
  fixed NumPy reference workload timed in the same process: pure engine
  dispatch overhead, no spawn noise, machine-independent.
* ``engine.parallel_speedup`` (informational, recorded when workers>1)
  — serial wall over parallel wall on the synthetic grid. On a quick
  grid the spawn+import cost dominates, so this is < 1 by design; it is
  recorded to track the trajectory, not gated.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np

BENCH_NAME = "trajectory"
BENCH_ORDER = 990  # after every fig study
BENCH_IN_QUICK = True

_RECORD = os.path.join(os.path.dirname(__file__), "BENCH_6.json")
_OUT = os.path.join(os.path.dirname(__file__), "out", "BENCH_6.json")
# BENCH_7 (PR 7, fleet-scale engine): written by benchmarks/fig11_scale.py
# on every bench run; gated here against the committed record
_RECORD7 = os.path.join(os.path.dirname(__file__), "BENCH_7.json")
_OUT7 = os.path.join(os.path.dirname(__file__), "out", "BENCH_7.json")

# encode bench: many small messages — the regime the batched API targets
_N_MSGS, _N_ELEMS = 64, 10_000
# engine bench: enough cells that per-cell dispatch overhead integrates
_N_CELLS = 24
_GATE = 0.9  # measured must stay within 10% of the committed record


def _ref_unit_s() -> float:
    """A fixed NumPy workload timed on this machine: the normaliser that
    makes engine throughput comparable across hosts."""
    a = np.random.default_rng(0).normal(size=(256, 256)).astype(np.float32)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(8):
            a = np.tanh(a @ a.T) * np.float32(0.1)
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_cell(cell):
    """Synthetic engine cell: a deterministic ~ms NumPy workload (module
    level so --workers can pickle it)."""
    n = cell.params["n"]
    a = np.random.default_rng(n).normal(size=(128, 128)).astype(np.float32)
    for _ in range(16):
        a = np.tanh(a @ a.T) * np.float32(0.1)
    return {"sim_time_s": float(abs(a).sum()), "n": n}


def _encode_bench():
    from repro.kernels import ops, ref
    from repro.kernels.quantize import ROW_TILE
    block = 256
    rng = np.random.default_rng(42)
    msgs = [rng.normal(size=_N_ELEMS).astype(np.float32)
            for _ in range(_N_MSGS)]
    nbytes = _N_MSGS * _N_ELEMS * 4
    mult = block * ROW_TILE

    def numpy_legacy():
        out = []
        for x in msgs:
            xp = np.zeros(-(-x.size // mult) * mult, np.float32)
            xp[: x.size] = x
            q, s = ref.quantize_blocks_np(xp.reshape(-1, block))
            out.append({"q": q.reshape(-1), "scales": s.reshape(-1),
                        "block": block, "orig_len": x.size})
        return out

    def fused():
        out = ops.quantize_flat_batch(msgs, block=block)
        return [{k: np.asarray(v) if k in ("q", "scales") else v
                 for k, v in p.items()} for p in out]

    fused()  # warm the jit cache before timing either path
    legacy_pk = numpy_legacy()
    # interleaved best-of-9: the ratio (not the absolute MB/s) is the
    # recorded number, so both paths must see the same machine noise
    t = [float("inf"), float("inf")]
    for _ in range(9):
        t0 = time.perf_counter()
        numpy_legacy()
        t[0] = min(t[0], time.perf_counter() - t0)
        t0 = time.perf_counter()
        fused_pk = fused()
        t[1] = min(t[1], time.perf_counter() - t0)
    # the wire-critical int8 payload must be bit-identical across paths
    q_bitexact = all(np.array_equal(a["q"], b["q"])
                     for a, b in zip(legacy_pk, fused_pk))
    # and vs the per-message batched-API entry point: fully identical
    per_msg = [ops.quantize_flat(x, block=block) for x in msgs]
    wire_identical = all(
        np.array_equal(np.asarray(a["q"]), b["q"])
        and np.array_equal(np.asarray(a["scales"]), b["scales"])
        for a, b in zip(per_msg, fused_pk))
    mb = nbytes / 2**20
    return {"n_msgs": _N_MSGS, "elems_per_msg": _N_ELEMS,
            "numpy_mb_s": mb / t[0], "batched_mb_s": mb / t[1],
            "speedup": t[0] / t[1], "q_bitexact": q_bitexact,
            "wire_bytes_identical": wire_identical}


def _engine_bench(workers: int):
    from repro.sweep import Axis, Engine, Study, Sweep
    sw = Sweep(name="bench6",
               axes=(Axis("params.n", values=tuple(range(_N_CELLS))),))
    study = Study(name="bench6", sweeps=lambda quick: (sw,),
                  cell=_bench_cell)
    cells = sw.expand()
    tmp = tempfile.mkdtemp(prefix="bench6_")
    try:
        eng = Engine(os.path.join(tmp, "serial"))
        t0 = time.perf_counter()
        serial = eng.run_cells(study, cells, verbose=False)
        serial_wall = time.perf_counter() - t0
        replay_wall = float("inf")
        for _ in range(5):  # ~ms-scale: best-of-5 beats the scheduler
            t0 = time.perf_counter()
            replay = eng.run_cells(study, cells, verbose=False)
            replay_wall = min(replay_wall, time.perf_counter() - t0)
        assert replay == serial and eng.last_stats.n_cached == _N_CELLS
        unit = _ref_unit_s()
        out = {"n_cells": _N_CELLS,
               "serial_cells_s": _N_CELLS / serial_wall,
               "replay_cells_s": _N_CELLS / replay_wall,
               "replay_per_unit": _N_CELLS / replay_wall * unit,
               "ref_unit_s": unit}
        if workers > 1:
            eng_p = Engine(os.path.join(tmp, "par"))
            t0 = time.perf_counter()
            par = eng_p.run_cells(study, cells, verbose=False,
                                  workers=workers)
            par_wall = time.perf_counter() - t0
            with open(eng.store_path("bench6"), "rb") as f:
                blob_s = f.read()
            with open(eng_p.store_path("bench6"), "rb") as f:
                blob_p = f.read()
            assert par == serial, "--workers changed the results"
            assert blob_s == blob_p, "--workers changed the store bytes"
            out.update({"workers": workers,
                        "parallel_wall_s": par_wall,
                        "parallel_speedup": serial_wall / par_wall,
                        "store_bytes_identical": True})
        return out
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _gate(measured: dict, verbose: bool) -> None:
    if not os.path.exists(_RECORD):
        if verbose:
            print(f"[trajectory] no committed record at {_RECORD}; "
                  f"nothing to gate against")
        return
    with open(_RECORD) as f:
        rec = json.load(f)
    checks = [
        ("encode.speedup", measured["encode"]["speedup"],
         rec["encode"]["speedup"]),
        ("engine.replay_per_unit", measured["engine"]["replay_per_unit"],
         rec["engine"]["replay_per_unit"]),
    ]
    for name, got, want in checks:
        assert got >= _GATE * want, (
            f"perf regression: {name} measured {got:.3f} < "
            f"{_GATE:.0%} of the recorded {want:.3f} (BENCH_6)")
        if verbose:
            print(f"[trajectory] gate ok: {name} {got:.3f} "
                  f"(recorded {want:.3f})")


def _gate_bench7(verbose: bool) -> None:
    """BENCH_7 (fleet-scale engine): gate fig11's measured ratios.

    The fig11 study writes ``out/BENCH_7.json`` when it runs; in a bench
    sweep it runs before this module (BENCH_ORDER). Skips quietly when
    the measurement is absent (e.g. ``--only trajectory``). The gates
    are the PR's absolute invariants — a >= 5x engine speedup at 1k
    clients and a flat streaming-hub memory peak — not machine-relative
    ratios, so they hold on any host."""
    if not os.path.exists(_RECORD7) or not os.path.exists(_OUT7):
        if verbose:
            print("[trajectory] BENCH_7: no fig11 measurement/record to "
                  "gate against")
        return
    with open(_OUT7) as f:
        got = json.load(f)
    assert got["speedup_1k"] >= 5.0, (
        f"perf regression: fig11 engine speedup at 1k clients "
        f"{got['speedup_1k']:.2f}x < the required 5x (BENCH_7)")
    assert got["mem_ratio_max_fleet"] <= 1.5, (
        f"perf regression: streaming-hub peak memory grew "
        f"{got['mem_ratio_max_fleet']:.2f}x with fleet size (BENCH_7)")
    if verbose:
        print(f"[trajectory] gate ok: fig11 speedup_1k "
              f"{got['speedup_1k']:.1f}x, mem ratio "
              f"{got['mem_ratio_max_fleet']:.2f}x")


def run(verbose: bool = True, quick: bool = False, fresh: bool = False,
        workers: int = 0):
    encode = _encode_bench()
    assert encode["q_bitexact"], "batched codec broke int8 wire parity"
    assert encode["wire_bytes_identical"], \
        "batched codec broke per-message wire parity"
    engine = _engine_bench(workers)
    measured = {"bench": "BENCH_6", "recorded_at_pr": 6,
                "encode": encode, "engine": engine}
    os.makedirs(os.path.dirname(_OUT), exist_ok=True)
    with open(_OUT, "w") as f:
        json.dump(measured, f, indent=2)
    if verbose:
        print(f"[trajectory] encode: numpy {encode['numpy_mb_s']:.0f} "
              f"MB/s -> batched {encode['batched_mb_s']:.0f} MB/s "
              f"(x{encode['speedup']:.2f}, wire bytes identical)")
        par = (f", x{engine['parallel_speedup']:.2f} with "
               f"{engine['workers']} workers" if "workers" in engine else "")
        print(f"[trajectory] engine: {engine['serial_cells_s']:.0f} "
              f"cells/s serial, {engine['replay_cells_s']:.0f} cells/s "
              f"replay{par}")
        print(f"[trajectory] record -> {_OUT}")
    _gate(measured, verbose)
    _gate_bench7(verbose)
    msg_bytes = encode["elems_per_msg"] * 4
    return [{"name": "trajectory/encode",
             "us_per_call": 1e6 * msg_bytes / (encode["batched_mb_s"]
                                               * 2**20),
             "derived": f"speedup={encode['speedup']:.3g};"
                        f"batched_mb_s={encode['batched_mb_s']:.4g}"},
            {"name": "trajectory/engine",
             "us_per_call": 1e6 / engine["replay_cells_s"],
             "derived": f"replay_per_unit="
                        f"{engine['replay_per_unit']:.4g}"}]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=0)
    args = ap.parse_args()
    run(quick=args.quick, workers=args.workers)
