"""Fig 8 (beyond the paper): fault tolerance under chunk loss & churn.

The paper's §II-C/§VII fault-tolerance contrast, measured instead of
asserted: gRPC-family backends tolerate link faults and dynamic
participation (lost chunks are retransmitted by the sender, departed
clients are simply not counted, rejoining clients re-fetch the current
model from the durable store), while MPI's static world aborts the round
and pays checkpoint-restore + re-run.

Cells (14-client WAN, 2 clients per Table-I region, tier Big) — three
declarative sweeps through the shared engine:

* ``fedbuff x {grpc, grpc+s3} x {clean, zero, loss...}`` — event-driven
  runs under a deterministic ``LinkFaultModel`` (per-chunk loss, seeded;
  gRPC rides 8 MB pipelined chunks, gRPC+S3 additionally sees S3 GET
  retries). ``zero`` forces an explicit zero-rate fault model — the
  bit-for-bit equivalence probe against ``clean`` (no model installed).
* ``hier x {clean, zero, loss}`` — chunk loss on the hier relay WAN
  edge, a real faultable backend channel since the scenario redesign.
* extras — the MPI abort model (ckpt restore + re-run), churn traces
  through fedbuff (S3 late-join re-fetch) and hier (relay quorum), and
  the hier full-quorum == flat FedAvg fidelity probe.

Validations (CI gate):
1. with loss injected, fedbuff/grpc and fedbuff/grpc+s3 still complete
   every aggregation, with sim time <= OVERHEAD_BOUND x the zero-loss
   run and > 0 retransmits;
2. a zero-rate fault model is bit-for-bit identical to no fault model
   (event traces equal — the fault path charges nothing when idle);
3. the MPI abort pays more than 2x a clean round (restore + re-run);
4. churn runs complete with departures/rejoins/late re-fetches
   accounted, and hier skips + re-folds a churned region;
5. hier with full quorum and no churn still equals flat FedAvg exactly
   (the quorum machinery is a no-op when nobody leaves).

The engine writes ``benchmarks/out/fig8_faults_wan.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ENGINE, scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import TensorPayload, VirtualPayload
from repro.fl.async_strategies import FedBuffStrategy, HierarchicalStrategy
from repro.fl.client import FLClient
from repro.fl.fault import AvailabilityTrace, mpi_abort_recovery_time
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep

BENCH_ORDER = 70
N_CLIENTS = 14
CHUNK_MB = 8.0  # direct backends ride pipelined chunks (loss granularity)
OVERHEAD_BOUND = 2.0  # lossy run must stay within this factor of clean
CKPT_RESTORE_BW = 1024 ** 3  # bytes/s checkpoint restore (local disk)
FAULT_SEED = 8
TIER = "big"


def _losses(quick):
    return (0.1,) if quick else (0.05, 0.15)


def _sweeps(quick):
    base = scenario_for("geo_distributed", num_clients=N_CLIENTS,
                        seed=FAULT_SEED, name="fig8")
    max_agg = 3 if quick else 5
    return (
        # chunking is backend-coupled (direct backends ride pipelined
        # chunks; the object store uploads whole) — a conditional
        # sub-axis states that in the spec instead of hiding it in _cell
        Sweep(name="fig8:fedbuff", base=base,
              axes=(Axis("channel.backend", values=("grpc", "grpc+s3"),
                         sub={"grpc": (Axis("params.chunk_mb",
                                            values=(CHUNK_MB,)),),
                              "grpc+s3": (Axis("params.chunk_mb",
                                               values=(0.0,)),)}),
                    Axis("params.loss",
                         values=("clean", "zero") + _losses(quick))),
              params={"variant": "fedbuff", "max_agg": max_agg}),
        Sweep(name="fig8:hier", base=base,
              axes=(Axis("params.loss",
                         values=("clean", "zero", _losses(quick)[0])),),
              params={"variant": "hier_loss", "max_agg": max_agg}),
        Sweep(name="fig8:extras", base=base,
              axes=(Axis("params.variant",
                         values=("mpi_abort", "churn_fedbuff",
                                 "churn_hier", "hier_fidelity")),),
              params={"max_agg": max_agg}),
    )


def _make_deployment(backend_name, tier, *, link_loss=0.0,
                     store_fail_rate=0.0, chunk_mb=0.0):
    rt = build_runtime(scenario_for(
        "geo_distributed", backend=backend_name, num_clients=N_CLIENTS,
        link_loss=link_loss, fail_rate=store_fail_rate, chunk_mb=chunk_mb,
        seed=FAULT_SEED, name=f"fig8:{backend_name}:loss={link_loss:g}"))
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s("geo_distributed"))
               for h in rt.env.clients]
    return (rt.make_backend("server"), clients, rt.fabric, rt.store)


def _force_zero_rate(fabric):
    # a zero-rate fault model must be bit-for-bit the fault-free path;
    # build_runtime installs None for loss=0, so force an explicit
    # zero-rate model for the equivalence probe
    from repro.core.netsim import LinkFaultModel
    fabric.fault_model = LinkFaultModel(chunk_loss_rate=0.0,
                                        seed=FAULT_SEED)


def _run_fedbuff(backend_name, tier, max_agg, *, loss=None,
                 availability=None, chunk_mb=0.0):
    sb, clients, fabric, store = _make_deployment(
        backend_name, tier, link_loss=loss or 0.0,
        store_fail_rate=(loss or 0.0) if backend_name == "grpc+s3" else 0.0,
        chunk_mb=chunk_mb)
    if loss == 0.0:
        _force_zero_rate(fabric)
    strategy = FedBuffStrategy(buffer_k=max(2, N_CLIENTS // 2),
                               staleness_exponent=0.5)
    sched = FLScheduler(sb, clients, strategy, local_steps=1,
                        availability=availability)
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig8"),
                    max_aggregations=max_agg)
    return {"sim_time_s": rep.sim_time,
            "n_aggregations": rep.n_aggregations,
            "aggregations_per_hour": rep.aggregations_per_hour,
            "retransmits": fabric.stats["retransmits"],
            "transfers_failed": fabric.stats["transfers_failed"],
            "scheduler_transfer_failures": rep.n_transfer_failures,
            "departures": rep.n_departures, "rejoins": rep.n_rejoins,
            "late_refetches": rep.n_late_refetches,
            "discarded": rep.n_discarded,
            "s3_retries": store.stats["retries"],
            "trace": tuple(sched.loop.trace)}


def _mpi_abort_model(tier):
    """Synchronous MPI round with one lost rank: measured clean round
    time vs the modelled abort-recovery bill."""
    sb, clients, _, _ = _make_deployment("mpi_generic", tier)
    server = FLServer(sb, clients, live=False, local_steps=1,
                      quorum_fraction=0.5)
    clean = server.run_round(VirtualPayload(tier.payload_bytes, tag="r0"))
    faulted = server.run_round(VirtualPayload(tier.payload_bytes, tag="r1"),
                               dropped={"client3"})
    assert faulted.aborted, "MPI round with a lost rank must abort"
    restore_s = tier.payload_bytes / CKPT_RESTORE_BW + 1.0
    recovery_s = mpi_abort_recovery_time(restore_s, clean.round_time)
    return {"clean_round_s": clean.round_time,
            "recovery_s": recovery_s,
            # the failure bill: the aborted round's wasted time + restore
            # + the re-run
            "faulted_round_total_s": faulted.round_time + recovery_s,
            "abort_factor": (faulted.round_time + recovery_s)
            / clean.round_time}


def _run_hier(tier, max_agg, *, loss=None):
    """Chunk loss on the relay WAN edge (a real backend channel now —
    before the scenario redesign this hop was analytic and LinkFaultModel
    could not touch it)."""
    sb, clients, fabric, store = _make_deployment(
        "grpc", tier, link_loss=loss or 0.0, chunk_mb=CHUNK_MB)
    if loss == 0.0:
        _force_zero_rate(fabric)
    strategy = HierarchicalStrategy(region_quorum=1.0, chunk_mb=CHUNK_MB)
    sched = FLScheduler(sb, clients, strategy, local_steps=1)
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig8hl"),
                    max_aggregations=max_agg)
    return {"sim_time_s": rep.sim_time,
            "n_aggregations": rep.n_aggregations,
            "retransmits": fabric.stats["retransmits"],
            "transfers_failed": fabric.stats["transfers_failed"],
            "trace": tuple(sched.loop.trace)}


# ---------------------------------------------------------------------------
# churn: availability traces through fedbuff and hier
# ---------------------------------------------------------------------------

def _churn_trace(train_s):
    """Deterministic churn: both clients of one region (3 and 10 share
    hongkong) leave mid-round — the region churns below quorum for hier —
    one rejoins within the run; an unrelated client blips."""
    return AvailabilityTrace.parse(
        f"client3:leave@{0.9 * train_s},join@{1.5 * train_s};"
        f"client10:leave@{0.95 * train_s};"
        f"client5:leave@{1.1 * train_s},join@{1.4 * train_s}")


def _run_hier_churn(tier, max_agg):
    sb, clients, fabric, _ = _make_deployment("grpc", tier)
    strategy = HierarchicalStrategy(region_quorum=1.0)
    train_s = tier.train_s("geo_distributed")
    sched = FLScheduler(sb, clients, strategy, local_steps=1,
                        availability=_churn_trace(train_s))
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig8h"),
                    max_aggregations=max_agg)
    return {"sim_time_s": rep.sim_time,
            "n_aggregations": rep.n_aggregations,
            "departures": rep.n_departures, "rejoins": rep.n_rejoins,
            "rounds_with_skips": strategy.rounds_with_skips,
            "client_updates": rep.n_client_updates}


def _hier_quorum_fidelity():
    """hier + full quorum + no churn == flat FedAvg (exact)."""
    from benchmarks.fig7_compression_wan import (_init_params,
                                                 _live_deployment)
    n, rounds = 8, 1
    sb, clients = _live_deployment(n)
    server = FLServer(sb, clients, local_steps=2)
    params = _init_params()
    for _ in range(rounds):
        server.run_round(TensorPayload(params))
        params = server.global_params

    sb2, clients2 = _live_deployment(n)
    strat = HierarchicalStrategy(staleness_exponent=0.0, region_quorum=1.0)
    sched = FLScheduler(sb2, clients2, strat, local_steps=2)
    sched.run(TensorPayload(_init_params()), max_aggregations=rounds)
    err = max(float(np.max(np.abs(np.asarray(sched.global_params[k])
                                  - np.asarray(params[k]))))
              for k in params)
    return err


# ---------------------------------------------------------------------------
# the study: cell dispatch + report assembly
# ---------------------------------------------------------------------------

def _loss_value(loss):
    """'clean' -> no fault model; 'zero' -> explicit zero-rate model;
    a number -> that chunk-loss rate."""
    if loss == "clean":
        return None
    if loss == "zero":
        return 0.0
    return float(loss)


def _cell(cell):
    tier = TIERS[TIER]
    max_agg = cell.params["max_agg"]
    variant = cell.params.get("variant")
    if variant == "mpi_abort":
        return _mpi_abort_model(tier)
    if variant == "churn_fedbuff":
        train_s = tier.train_s("geo_distributed")
        return _run_fedbuff("grpc+s3", tier, max_agg,
                            availability=_churn_trace(train_s))
    if variant == "churn_hier":
        return _run_hier_churn(tier, max_agg)
    if variant == "hier_fidelity":
        return {"max_abs_err": _hier_quorum_fidelity()}
    loss = _loss_value(cell.params["loss"])
    if variant == "fedbuff":
        return _run_fedbuff(cell.overrides["channel.backend"], tier,
                            max_agg, loss=loss,
                            chunk_mb=cell.params["chunk_mb"])
    return _run_hier(tier, max_agg, loss=loss)


def _name(cell):
    variant = cell.params.get("variant")
    if variant == "mpi_abort":
        return "fig8/mpi_abort"
    if variant == "churn_fedbuff":
        return "fig8/churn/fedbuff_s3"
    if variant == "churn_hier":
        return "fig8/churn/hier"
    if variant == "hier_fidelity":
        return "fig8/hier_full_quorum_vs_flat"
    loss = cell.params["loss"]
    if variant == "fedbuff":
        return (f"fig8/fedbuff/{cell.overrides['channel.backend']}/"
                f"loss={loss}")
    return f"fig8/hier/grpc/relay_loss={loss}"


_FEDBUFF_KEYS = ("sim_time_s", "n_aggregations", "aggregations_per_hour",
                 "retransmits", "transfers_failed",
                 "scheduler_transfer_failures", "departures", "rejoins",
                 "late_refetches", "discarded", "s3_retries")


def _fedbuff_dict(r):
    return {k: r.get(k) for k in _FEDBUFF_KEYS}


def _finalize(results, quick, verbose):
    losses = _losses(quick)
    by = {r.cell: r for r in results}
    report = {"n_clients": N_CLIENTS, "tier": TIER,
              "chunk_mb": CHUNK_MB, "overhead_bound": OVERHEAD_BOUND,
              "cells": {}}
    rows = []

    # 1) chunk-loss sweep + zero-loss bit-for-bit equivalence
    for backend_name in ["grpc", "grpc+s3"]:
        base = by[f"fig8/fedbuff/{backend_name}/loss=clean"]
        zero = by[f"fig8/fedbuff/{backend_name}/loss=zero"]
        cell = {"clean": _fedbuff_dict(base),
                "zero_loss_identical":
                base.metrics["trace"] == zero.metrics["trace"]
                and base.sim_time_s == zero.sim_time_s,
                "loss": {}}
        for loss in losses:
            r = by[f"fig8/fedbuff/{backend_name}/loss={loss}"]
            m = _fedbuff_dict(r)
            m["overhead_factor"] = m["sim_time_s"] / base.sim_time_s
            cell["loss"][str(loss)] = m
            rows.append({"name": f"fig8/fedbuff/{backend_name}/loss={loss}",
                         "round_s": m["sim_time_s"] / max(
                             m["n_aggregations"], 1),
                         "overhead_factor": m["overhead_factor"],
                         "retransmits": m["retransmits"]})
            if verbose:
                print(f"[fig8] fedbuff {backend_name:9s} loss={loss:<5g} "
                      f"sim={m['sim_time_s']:8.1f}s "
                      f"(x{m['overhead_factor']:.2f} of clean) "
                      f"retransmits={m['retransmits']:.0f} "
                      f"s3_retries={m['s3_retries']:.0f} "
                      f"failed={m['transfers_failed']:.0f}")
        report["cells"][backend_name] = cell

    # 1b) chunk loss on the hier relay WAN edge
    hier_base = by["fig8/hier/grpc/relay_loss=clean"]
    hier_zero = by["fig8/hier/grpc/relay_loss=zero"]
    hier_loss = by[f"fig8/hier/grpc/relay_loss={losses[0]}"]
    report["hier_relay_loss"] = {
        "clean_sim_time_s": hier_base.sim_time_s,
        "zero_loss_identical":
        hier_base.metrics["trace"] == hier_zero.metrics["trace"]
        and hier_base.sim_time_s == hier_zero.sim_time_s,
        "loss": losses[0],
        "sim_time_s": hier_loss.sim_time_s,
        "n_aggregations": hier_loss.get("n_aggregations"),
        "retransmits": hier_loss.retransmits,
        "transfers_failed": hier_loss.transfers_failed,
        "overhead_factor": hier_loss.sim_time_s / hier_base.sim_time_s}
    rows.append({"name": f"fig8/hier/grpc/relay_loss={losses[0]}",
                 "round_s": hier_loss.sim_time_s / max(
                     hier_loss.get("n_aggregations"), 1),
                 "overhead_factor": report["hier_relay_loss"][
                     "overhead_factor"],
                 "retransmits": hier_loss.retransmits})
    if verbose:
        h = report["hier_relay_loss"]
        print(f"[fig8] hier    grpc      loss={h['loss']:<5g} "
              f"sim={h['sim_time_s']:8.1f}s "
              f"(x{h['overhead_factor']:.2f} of clean) relay-edge "
              f"retransmits={h['retransmits']:.0f}")

    # 2) MPI abort-recovery model
    mpi = dict(by["fig8/mpi_abort"].metrics)
    report["mpi_abort"] = mpi
    rows.append({"name": "fig8/mpi_abort", "round_s": mpi["clean_round_s"],
                 "abort_factor": mpi["abort_factor"]})
    if verbose:
        print(f"[fig8] mpi abort: clean={mpi['clean_round_s']:.1f}s "
              f"faulted={mpi['faulted_round_total_s']:.1f}s "
              f"(x{mpi['abort_factor']:.2f}: ckpt restore + re-run)")

    # 3) churn through fedbuff (S3 late-join re-fetch) and hier (quorum)
    churn = _fedbuff_dict(by["fig8/churn/fedbuff_s3"])
    report["churn_fedbuff"] = churn
    hier = dict(by["fig8/churn/hier"].metrics)
    hier["sim_time_s"] = by["fig8/churn/hier"].sim_time_s
    report["churn_hier"] = hier
    rows.append({"name": "fig8/churn/fedbuff_s3",
                 "round_s": churn["sim_time_s"] / max(
                     churn["n_aggregations"], 1),
                 "departures": churn["departures"],
                 "late_refetches": churn["late_refetches"]})
    rows.append({"name": "fig8/churn/hier",
                 "round_s": hier["sim_time_s"] / max(
                     hier["n_aggregations"], 1),
                 "rounds_with_skips": hier["rounds_with_skips"]})
    if verbose:
        print(f"[fig8] churn fedbuff/grpc+s3: {churn['departures']} left, "
              f"{churn['rejoins']} rejoined "
              f"({churn['late_refetches']} S3 late re-fetches), "
              f"{churn['discarded']} in-flight updates discarded, "
              f"{churn['n_aggregations']} aggregations")
        print(f"[fig8] churn hier (region quorum): "
              f"{hier['rounds_with_skips']} rounds skipped a region, "
              f"{hier['n_aggregations']} aggregations completed")

    # 4) hier full-quorum/no-churn fidelity
    err = by["fig8/hier_full_quorum_vs_flat"].metrics["max_abs_err"]
    report["hier_fidelity_err"] = err
    rows.append({"name": "fig8/hier_full_quorum_vs_flat",
                 "max_abs_err": err})
    if verbose:
        print(f"[fig8] hier(full quorum, no churn) vs flat FedAvg: "
              f"max|err| = {err:.2e}")

    report["validation"] = _validate(report, verbose)
    return report, rows


def _validate(report, verbose):
    for backend_name, cell in report["cells"].items():
        assert cell["zero_loss_identical"], (
            f"fig8: {backend_name} zero-rate fault model diverged from "
            f"fault-free run (must be bit-for-bit)")
        clean_aggs = cell["clean"]["n_aggregations"]
        for loss, m in cell["loss"].items():
            assert m["n_aggregations"] == clean_aggs, (
                f"fig8: {backend_name} loss={loss} wedged: only "
                f"{m['n_aggregations']}/{clean_aggs} aggregations")
            assert m["overhead_factor"] <= OVERHEAD_BOUND, (
                f"fig8: {backend_name} loss={loss} overhead "
                f"x{m['overhead_factor']:.2f} > {OVERHEAD_BOUND}")
            recovered = m["retransmits"] + m["s3_retries"]
            assert recovered > 0, (
                f"fig8: {backend_name} loss={loss} injected faults never "
                f"fired (retransmits+s3_retries == 0)")
    hier_loss = report["hier_relay_loss"]
    assert hier_loss["zero_loss_identical"], (
        "fig8: hier zero-rate fault model diverged from fault-free run")
    assert hier_loss["retransmits"] > 0, (
        "fig8: chunk loss on the hier relay WAN edge never fired — the "
        "relay hop must ride the faultable backend channel")
    assert hier_loss["n_aggregations"] >= 1 and \
        hier_loss["overhead_factor"] <= OVERHEAD_BOUND, (
        f"fig8: hier under relay-edge loss wedged or overran "
        f"(x{hier_loss['overhead_factor']:.2f})")
    mpi = report["mpi_abort"]
    assert mpi["abort_factor"] > 2.0, (
        f"fig8: MPI abort-recovery must cost more than 2x a clean round "
        f"(wasted round + restore + re-run), got x{mpi['abort_factor']:.2f}")
    churn = report["churn_fedbuff"]
    assert churn["departures"] >= 2 and churn["rejoins"] >= 1, \
        "fig8: churn trace did not replay"
    assert churn["late_refetches"] >= 1, \
        "fig8: rejoining grpc+s3 client never re-fetched from the store"
    hier = report["churn_hier"]
    assert hier["rounds_with_skips"] >= 1, \
        "fig8: hier never skipped a below-quorum region under churn"
    assert hier["n_aggregations"] >= 1, "fig8: hier wedged under churn"
    assert report["hier_fidelity_err"] <= 1e-4, (
        f"fig8: hier(full quorum) drifted {report['hier_fidelity_err']:.2e} "
        f"from flat FedAvg with no churn")
    if verbose:
        print("[fig8] validation: retransmit recovery bounded "
              f"(<= x{report['overhead_bound']}), zero-loss bit-for-bit, "
              f"MPI abort x{mpi['abort_factor']:.2f}, churn + relay quorum "
              "replayed, hier==flat with full quorum")
    return {"bounded_overhead": True, "zero_loss_bit_for_bit": True,
            "mpi_abort_factor": mpi["abort_factor"],
            "hier_rounds_with_skips": hier["rounds_with_skips"]}


STUDY = Study(
    name="fig8", title="Fig 8: fault tolerance under chunk loss & churn",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig8_faults_wan.json", order=BENCH_ORDER,
    version=2)  # v2: chunk_mb moved from _cell into a conditional sub-axis

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
