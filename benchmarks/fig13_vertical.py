"""Fig 13: vertical / split FL — the workload that flips the fig10 table.

Horizontal FL ships tier-sized model payloads a few times per round, so
the big-tier geo-distributed cell belongs to gRPC+S3 (fig10's headline).
Vertical FL inverts the traffic shape: every batch moves a small
activation wire up and an equally small gradient wire back, so a round
is dozens of latency-bound exchanges instead of one bandwidth-bound
broadcast. Per-message store latency (PUT + presign + GET) is pure
overhead at those sizes, and the decision table flips: pure gRPC beats
gRPC+S3 *even at the big tier geo-distributed* — the exact cell gRPC+S3
owns in fig10.

Grid: backend x tier x cut depth over geo-distributed vertical-mode
scenarios, every cell a full event-driven ``run_scenario`` run (the
same path ``fl_train --mode vertical`` drives). Quick grid: 2 fixed
backends x 2 tiers x 1 cut (+ AUTO, which is always swept so the
routing assertions hold in CI).

Validations (CI gate):
1. split == unsplit numerics: a real model-zoo ResNet cut by
   ``SplitPlan`` reproduces the unsplit loss and gradients within float
   tolerance (the vertical path changes *where* compute happens, never
   *what* is computed);
2. the table flip: pure gRPC beats gRPC+S3 on vertical traffic at the
   big tier geo-distributed, for every cut depth in the grid;
3. AUTO routes per message — every sub-threshold activation/gradient
   wire rides gRPC — and is never slower than the worst fixed backend
   in any cell.

The engine writes ``benchmarks/out/fig13_vertical.json``.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import ENGINE, scenario_for
from repro.scenario import SplitSpec
from repro.sweep import Axis, Study, Sweep, run_scenario

BENCH_ORDER = 95
FIXED_BACKENDS = ("grpc", "grpc+s3")
PARITY_TOL = 1e-5  # split-vs-unsplit numerics gate (measured: exact)


def _tiers(quick):
    return ("medium", "big") if quick else ("small", "medium", "big")


def _cuts(quick):
    return (2,) if quick else (1, 2, 4)


def _sweeps(quick):
    base = scenario_for("geo_distributed", mode="vertical",
                        num_clients=4, name="fig13:geo_distributed")
    base = dataclasses.replace(base, split=SplitSpec())
    return (Sweep(
        name="fig13:geo_distributed", base=base,
        axes=(Axis("fleet.tier", values=_tiers(quick)),
              Axis("split.cut_layer", values=_cuts(quick)),
              Axis("channel.backend",
                   values=FIXED_BACKENDS + ("auto",)))),)


def _cell(cell):
    return run_scenario(cell.scenario)


def _name(cell):
    return (f"fig13/{cell.scenario.fleet.tier}/"
            f"cut{cell.scenario.split.cut_layer}/"
            f"{cell.scenario.channel.backend}")


def _split_parity():
    """Gate 1: cut a real zoo model and check the split pipeline computes
    the *same* loss and gradients as the unsplit one. Runs in-process on
    a reduced ResNet (the same family ``fl_train --live`` deploys)."""
    import jax
    import jax.numpy as jnp

    from repro.fl.vertical import SplitPlan
    from repro.models.vision import ResNet, ResNetConfig

    model = ResNet(ResNetConfig(name="resnet-parity", widths=(8, 16),
                                blocks_per_stage=2, num_classes=5,
                                image_size=8))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (4, 8, 8, 3)),
             "labels": jnp.array([0, 1, 2, 3])}
    plan = SplitPlan(model, cut_layer=2)

    ref_loss, ref_g = jax.value_and_grad(
        lambda p: model.loss(p, batch)[0])(params)
    bottom, top = plan.split_params(params)
    split_loss, (g_bottom, g_top) = jax.value_and_grad(
        lambda b, t: plan.loss(b, t, batch)[0], argnums=(0, 1))(bottom, top)
    split_g = plan.merge_params(g_bottom, g_top)
    loss_diff = float(abs(ref_loss - split_loss))
    grad_diff = max(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(ref_g),
                                    jax.tree.leaves(split_g)))
    return {"loss_diff": loss_diff, "grad_diff": grad_diff}


def _finalize(results, quick, verbose):
    cells: dict = {}
    routing: dict = {}
    for r in results:
        _, tier, cut, backend = r.cell.split("/")
        cells.setdefault((tier, cut), {})[backend] = \
            r.metrics["round_s"]
        if backend == "auto":
            routing[(tier, cut)] = r.metrics.get("auto_decisions", {})
    report = {"parity_tol": PARITY_TOL, "cells": []}
    for (tier, cut), times in cells.items():
        fixed = {b: t for b, t in times.items() if b != "auto"}
        worst = max(fixed, key=fixed.get)
        report["cells"].append({
            "tier": tier, "cut": cut,
            "round_s": dict(sorted(times.items(), key=lambda kv: kv[1])),
            "fastest": min(fixed, key=fixed.get),
            "worst_fixed": worst,
            "s3_over_grpc": times["grpc+s3"] / times["grpc"],
            "auto_over_best": times["auto"] / min(fixed.values()),
            "auto_decisions": routing.get((tier, cut), {})})
    if verbose:
        print("\n== Fig 13: vertical FL — per-round time by backend ==")
        print(f"{'tier':8s} {'cut':6s} {'grpc':>9s} {'grpc+s3':>9s} "
              f"{'auto':>9s} {'s3/grpc':>8s}")
        for e in report["cells"]:
            t = e["round_s"]
            print(f"{e['tier']:8s} {e['cut']:6s} {t['grpc']:9.2f} "
                  f"{t['grpc+s3']:9.2f} {t['auto']:9.2f} "
                  f"{e['s3_over_grpc']:8.3f}")
    report["validation"] = _validate(report, quick, verbose)
    return report, [r.row() for r in results]


def _validate(report, quick, verbose):
    # 1) split == unsplit numerics on a real zoo model
    parity = _split_parity()
    assert parity["loss_diff"] <= PARITY_TOL, (
        f"fig13: split loss deviates from unsplit by "
        f"{parity['loss_diff']:.2e} (tol {PARITY_TOL})")
    assert parity["grad_diff"] <= PARITY_TOL, (
        f"fig13: split gradients deviate from unsplit by "
        f"{parity['grad_diff']:.2e} (tol {PARITY_TOL})")
    # 2) the fig10 table flip: gRPC beats gRPC+S3 on vertical traffic at
    #    the big tier geo-distributed, at every cut depth in the grid
    big = [e for e in report["cells"] if e["tier"] == "big"]
    assert big, "fig13: no big-tier cells in the grid"
    for e in big:
        assert e["round_s"]["grpc"] < e["round_s"]["grpc+s3"], (
            f"fig13: expected the table flip (grpc < grpc+s3) for "
            f"big/{e['cut']}, got grpc {e['round_s']['grpc']:.2f}s vs "
            f"grpc+s3 {e['round_s']['grpc+s3']:.2f}s")
    # 3) AUTO routes per message and is never slower than the worst
    #    fixed backend anywhere
    for e in report["cells"]:
        worst = e["round_s"][e["worst_fixed"]]
        auto = e["round_s"]["auto"]
        assert auto <= worst * (1 + 1e-6), (
            f"fig13: AUTO ({auto:.2f}s) slower than the worst fixed "
            f"backend {e['worst_fixed']} ({worst:.2f}s) for "
            f"{e['tier']}/{e['cut']}")
        dec = e["auto_decisions"]
        assert any(k.startswith("activation:") for k in dec), (
            f"fig13: AUTO recorded no per-message activation routing "
            f"decisions for {e['tier']}/{e['cut']}: {dec}")
        for k in dec:
            mt, _, chosen = k.partition(":")
            if mt in ("activation", "grad"):
                # every vertical wire in this grid is far below the
                # 10 MB store threshold -> must ride pure gRPC
                assert chosen == "grpc", (
                    f"fig13: AUTO routed sub-threshold {mt} wire to "
                    f"{chosen} for {e['tier']}/{e['cut']}")
    flips = {(e["tier"], e["cut"]): e["s3_over_grpc"] for e in big}
    if verbose:
        worst_flip = min(flips.values())
        print(f"[fig13] validation: split==unsplit (loss diff "
              f"{parity['loss_diff']:.1e}, grad diff "
              f"{parity['grad_diff']:.1e}); table flip holds at big/geo "
              f"(grpc+s3 pays >= {worst_flip:.3f}x); AUTO routes every "
              f"activation/grad wire to grpc and is never worst")
    return {"parity": parity,
            "flip_s3_over_grpc_big": {f"{t}/{c}": v
                                      for (t, c), v in flips.items()},
            "auto_never_worst": True,
            "auto_routes_small_wires_to_grpc": True}


STUDY = Study(
    name="fig13", title="Fig 13: vertical/split FL — the table flip",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig13_vertical.json", order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
