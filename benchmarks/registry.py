"""Benchmark study registry: discovery instead of a hand-maintained list.

``benchmarks/run.py`` used to keep its own import tuple + module list —
a new study that forgot to add itself there silently dropped out of
``--quick``/``--only``. Discovery walks the ``benchmarks`` package
instead: every module (minus the infrastructure set below) must expose
either a sweep ``STUDY`` (the engine-driven fig modules) or a legacy
``run(verbose=...)`` callable; anything else is a loud error, so a study
can be *added* by creating its file and cannot be silently lost.

Ordering comes from the module's ``BENCH_ORDER`` int (``STUDY.order``
for sweep studies); modules without one sort last. ``BENCH_IN_QUICK =
False`` (or ``Study.in_quick``) keeps a module out of the ``--quick``
CI gate (the JAX-heavy kernel/cross-pod modules).
"""
from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from typing import Callable, List

# infrastructure modules that are not studies
_EXCLUDE = {"run", "common", "registry", "__init__", "__main__"}


@dataclasses.dataclass
class BenchEntry:
    """One runnable benchmark module."""
    name: str            # the --only handle (fig2, table1, kernels, ...)
    module: object
    run: Callable        # run(verbose=True[, quick=...][, fresh=...])
    order: int
    in_quick: bool
    accepts_quick: bool  # whether run() takes a quick= kwarg
    accepts_fresh: bool  # whether run() takes a fresh= kwarg (sweep
    #                      studies: per-study run-store invalidation)
    accepts_workers: bool = False  # whether run() takes workers= (sweep
    #                      studies: parallel cell execution)


def _entry(modname: str) -> BenchEntry:
    mod = importlib.import_module(f"benchmarks.{modname}")
    study = getattr(mod, "STUDY", None)
    run = getattr(mod, "run", None)
    if run is None:
        raise RuntimeError(
            f"benchmarks.{modname} defines neither STUDY nor run(); every "
            f"module in benchmarks/ must be a runnable study (or be added "
            f"to registry._EXCLUDE)")
    if study is not None:
        return BenchEntry(name=study.name, module=mod, run=run,
                          order=study.order, in_quick=study.in_quick,
                          accepts_quick=True, accepts_fresh=True,
                          accepts_workers=True)
    import inspect
    name = getattr(mod, "BENCH_NAME", modname.split("_")[0])
    params = inspect.signature(run).parameters
    return BenchEntry(
        name=name, module=mod, run=run,
        order=getattr(mod, "BENCH_ORDER", 1000),
        in_quick=getattr(mod, "BENCH_IN_QUICK", True),
        accepts_quick="quick" in params,
        accepts_fresh="fresh" in params,
        accepts_workers="workers" in params)


def discover() -> List[BenchEntry]:
    """Every benchmark module in the package, ordered for run.py."""
    import benchmarks
    names = sorted(m.name for m in pkgutil.iter_modules(benchmarks.__path__)
                   if m.name not in _EXCLUDE
                   and not m.name.startswith("_"))
    entries = [_entry(n) for n in names]
    seen: dict = {}
    for e in entries:
        if e.name in seen:
            raise RuntimeError(
                f"duplicate benchmark name '{e.name}' "
                f"({seen[e.name].module.__name__} vs {e.module.__name__})")
        seen[e.name] = e
    return sorted(entries, key=lambda e: (e.order, e.name))
