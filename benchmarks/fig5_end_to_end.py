"""Fig 5: end-to-end FL round, per-state durations (communication /
migration / serialization / waiting / training / aggregation) for every
backend x environment x model tier.

One server + 7 clients, 1 local epoch (paper §VI). Client compute time is
the tier's calibrated per-round seconds; payloads are tier-sized virtual
buffers; all communication runs through the real backend implementations
over the Table-I-calibrated network model.
"""
from __future__ import annotations

from benchmarks.common import ENGINE, backends_for, scenario_for
from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import VirtualPayload
from repro.fl.client import FLClient
from repro.fl.server import FLServer
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep, wire_stats

BENCH_ORDER = 40
ENVS = ("lan", "geo_proximal", "geo_distributed")


def _sweeps(quick):
    return tuple(
        Sweep(name=f"fig5:{env_name}",
              base=scenario_for(env_name, name=f"fig5:{env_name}"),
              axes=(Axis("fleet.tier", values=tuple(TIER_ORDER)),
                    Axis("channel.backend",
                         values=tuple(backends_for(env_name)))),
              params={"round_idx": 1})
        for env_name in ENVS)


def _cell(cell):
    env_name = cell.scenario.topology.kind
    tier = TIERS[cell.scenario.fleet.tier]
    rt = build_runtime(cell.scenario)
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s(env_name))
               for h in rt.env.clients]
    server = FLServer(rt.make_backend("server"), clients, local_steps=1,
                      live=False)
    payload = VirtualPayload(tier.payload_bytes,
                             tag=f"r{cell.params['round_idx']}")
    rep = server.run_round(payload)
    return {"round_s": rep.round_time, "server": rep.server,
            "clients": rep.clients,
            "peak_server_mem": rep.peak_server_memory,
            "sim_time_s": rep.round_time, "n_rounds": 1,
            "stage_charges": {
                **{f"server.{k}": v for k, v in rep.server.items()},
                **{f"client.{k}": v for k, v in rep.clients.items()}},
            **wire_stats(rt.fabric, rt.store)}


def _name(cell):
    return (f"fig5/{cell.scenario.topology.kind}/"
            f"{cell.scenario.fleet.tier}/{cell.scenario.channel.backend}")


def _finalize(results, quick, verbose):
    rows = [{"name": r.cell, "round_s": r.metrics["round_s"],
             "server": r.metrics["server"], "clients": r.metrics["clients"],
             "peak_server_mem": r.metrics["peak_server_mem"]}
            for r in results]
    d = {r["name"]: r["round_s"] for r in rows}
    if verbose:
        for env_name in ENVS:
            names = backends_for(env_name)
            print(f"\n== Fig 5 ({env_name}): end-to-end round time + "
                  "per-state breakdown ==")
            print(f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names)
                  + "   (round seconds)")
            for tier_name in TIER_ORDER:
                vals = [d[f"fig5/{env_name}/{tier_name}/{b}"]
                        for b in names]
                print(f"{tier_name:8s}" + "".join(f"{v:>14.1f}"
                                                  for v in vals))
            if env_name == "geo_distributed":
                for tn in TIER_ORDER:
                    g = d[f"fig5/geo_distributed/{tn}/grpc"]
                    s = d[f"fig5/geo_distributed/{tn}/grpc+s3"]
                    print(f"   gRPC+S3 speedup over gRPC ({tn}): "
                          f"{g / s:.2f}x")
    _validate(rows, verbose)
    return None, rows


def _validate(rows, verbose):
    d = {r["name"]: r["round_s"] for r in rows}
    # PAPER CLAIM (§VI, abstract): geo-distributed large models,
    # gRPC+S3 is 3.5-3.8x faster end-to-end than gRPC
    speedup = d["fig5/geo_distributed/large/grpc"] / \
        d["fig5/geo_distributed/large/grpc+s3"]
    assert 3.2 <= speedup <= 4.2, f"S3 speedup {speedup:.2f} out of band"
    # PAPER CLAIM (§VI): small/medium models, training dominates ->
    # backends comparable in LAN/GeoProx (within ~35%)
    for tn in ("small", "medium"):
        vals = [d[f"fig5/lan/{tn}/{b}"] for b in
                ("mpi_generic", "mpi_mem_buff", "torch_rpc")]
        assert max(vals) / min(vals) < 1.35
    # PAPER CLAIM (§VI): LAN large models, gRPC dramatically slower than
    # the buffer backends (paper: ~9x; our serialization model yields >3.5x
    # — see EXPERIMENTS.md for the delta discussion)
    best_lan = min(d[f"fig5/lan/large/{b}"] for b in
                   ("mpi_mem_buff", "torch_rpc"))
    ratio = d["fig5/lan/large/grpc"] / best_lan
    assert ratio > 3.5, f"LAN gRPC penalty only {ratio:.1f}x"
    # gRPC competitive for small payloads geo-distributed (§VI)
    small_ratio = d["fig5/geo_distributed/small/grpc"] / \
        d["fig5/geo_distributed/small/grpc+s3"]
    assert small_ratio < 1.4
    if verbose:
        print(f"\n[fig5] validation: S3 large speedup={speedup:.2f}x (paper "
              f"3.5-3.8x); LAN gRPC penalty={ratio:.1f}x (paper ~9x)")


STUDY = Study(
    name="fig5", title="Fig 5: end-to-end FL round per-state durations",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
