"""Fig 5: end-to-end FL round, per-state durations (communication /
migration / serialization / waiting / training / aggregation) for every
backend x environment x model tier.

One server + 7 clients, 1 local epoch (paper §VI). Client compute time is
the tier's calibrated per-round seconds; payloads are tier-sized virtual
buffers; all communication runs through the real backend implementations
over the Table-I-calibrated network model.
"""
from __future__ import annotations

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import VirtualPayload, make_backend
from repro.fl.client import FLClient
from repro.fl.server import FLServer
from benchmarks.common import backends_for, deployment


def _round_time(backend_name, env_name, tier, round_idx=1):
    env, fabric, store = deployment(env_name)
    clients = []
    for host in env.clients:
        cb = make_backend(backend_name, env, fabric, host.host_id,
                          store=store)
        clients.append(FLClient(host.host_id, cb,
                                sim_train_s=tier.train_s(env_name)))
    sb = make_backend(backend_name, env, fabric, "server", store=store)
    server = FLServer(sb, clients, local_steps=1, live=False)
    payload = VirtualPayload(tier.payload_bytes, tag=f"r{round_idx}")
    report = server.run_round(payload)
    return report


def run(verbose=True):
    rows = []
    for env_name in ("lan", "geo_proximal", "geo_distributed"):
        names = backends_for(env_name)
        if verbose:
            print(f"\n== Fig 5 ({env_name}): end-to-end round time + "
                  "per-state breakdown ==")
            print(f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names)
                  + "   (round seconds)")
        for tier_name in TIER_ORDER:
            tier = TIERS[tier_name]
            vals = []
            for b in names:
                rep = _round_time(b, env_name, tier)
                vals.append(rep.round_time)
                rows.append({
                    "name": f"fig5/{env_name}/{tier_name}/{b}",
                    "round_s": rep.round_time,
                    "server": rep.server, "clients": rep.clients,
                    "peak_server_mem": rep.peak_server_memory,
                })
            if verbose:
                print(f"{tier_name:8s}" + "".join(f"{v:>14.1f}"
                                                  for v in vals))
        if verbose and env_name == "geo_distributed":
            d = {r["name"]: r["round_s"] for r in rows}
            for tn in TIER_ORDER:
                g = d[f"fig5/geo_distributed/{tn}/grpc"]
                s = d[f"fig5/geo_distributed/{tn}/grpc+s3"]
                print(f"   gRPC+S3 speedup over gRPC ({tn}): {g / s:.2f}x")
    _validate(rows, verbose)
    return rows


def _validate(rows, verbose):
    d = {r["name"]: r["round_s"] for r in rows}
    # PAPER CLAIM (§VI, abstract): geo-distributed large models,
    # gRPC+S3 is 3.5-3.8x faster end-to-end than gRPC
    speedup = d["fig5/geo_distributed/large/grpc"] / \
        d["fig5/geo_distributed/large/grpc+s3"]
    assert 3.2 <= speedup <= 4.2, f"S3 speedup {speedup:.2f} out of band"
    # PAPER CLAIM (§VI): small/medium models, training dominates ->
    # backends comparable in LAN/GeoProx (within ~35%)
    for tn in ("small", "medium"):
        vals = [d[f"fig5/lan/{tn}/{b}"] for b in
                ("mpi_generic", "mpi_mem_buff", "torch_rpc")]
        assert max(vals) / min(vals) < 1.35
    # PAPER CLAIM (§VI): LAN large models, gRPC dramatically slower than
    # the buffer backends (paper: ~9x; our serialization model yields >3.5x
    # — see EXPERIMENTS.md for the delta discussion)
    best_lan = min(d[f"fig5/lan/large/{b}"] for b in
                   ("mpi_mem_buff", "torch_rpc"))
    ratio = d["fig5/lan/large/grpc"] / best_lan
    assert ratio > 3.5, f"LAN gRPC penalty only {ratio:.1f}x"
    # gRPC competitive for small payloads geo-distributed (§VI)
    small_ratio = d["fig5/geo_distributed/small/grpc"] / \
        d["fig5/geo_distributed/small/grpc+s3"]
    assert small_ratio < 1.4
    if verbose:
        print(f"\n[fig5] validation: S3 large speedup={speedup:.2f}x (paper "
              f"3.5-3.8x); LAN gRPC penalty={ratio:.1f}x (paper ~9x)")


if __name__ == "__main__":
    run()
