"""Fig 12: multi-tenant fabric — co-located FL jobs on shared WAN links.

Three studies over one deployment family (a geo star whose declared
server<->client edges are the contended pipes), all driven through
``MultiScenario`` / ``run_multi`` on one shared EventLoop + Fabric:

* **Co-location** (fifo): two churned big-tier fedbuff jobs on thin
  8 MB/s uplinks each run slower than solo, but the links stay busy —
  aggregate round throughput holds >= 0.9x the solo sum.
* **Priority admission**: the same pair under ``policy="priority"``
  keeps the foreground job within 1.25x its solo round time (the
  background tenant absorbs the contention).
* **Decision flip**: the fig10-style solo decision table (winner
  backend per tier, comm-exposed semisync rounds) is recomputed with a
  checkpoint-sync traffic generator co-located on the same links. A
  foreground flow queues behind the hog's 1.2 GB residual no matter how
  small its own payload is, while grpc+s3's store legs ride the object
  store instead of the contended pipes — so at least one tier's winner
  flips from a fabric backend to grpc+s3 under contention.

Gates (the PR's acceptance criteria, re-checked on every bench run):

* single-tenant bit-identity: one job driven through the whole tenancy
  machinery (job namespace, MultiScheduler bootstrap, shared_links off)
  must replay the exact solo event trace and wire stats — the refactor's
  safety net, gated the way fig11 gates the fleet engine.
* co-located jobs each slower than solo, aggregate throughput >= 0.9x.
* priority keeps the foreground within 1.25x its solo round time.
* admission weights: unit ``JobSpec.weight`` is a bit-identical no-op
  under fair share, and a 3:1 weighting shows up as a >= 2x (target 3x)
  fg/bg granted-rate ratio in the pipes' co-active segments.
* >= 1 tier flips its winner backend vs the solo decision table.

Writes ``benchmarks/out/fig12_multitenant.json``.
"""
from __future__ import annotations

import json
import os

BENCH_NAME = "fig12"
BENCH_ORDER = 111  # right after fig11, before the trajectory gate
BENCH_IN_QUICK = True

_OUT = os.path.join(os.path.dirname(__file__), "out",
                    "fig12_multitenant.json")

# -- the contended deployment family ----------------------------------------
N_CLIENTS = 4
LATENCY_MS = 40.0
# co-location cells: thin shared uplinks, availability churn to break
# the deterministic convoy (phase-locked identical tenants never meet)
COLO_BW_MB = 8.0
COLO_CHURN = "auto:400/40"
COLO_HORIZON_S = 2000.0
COLO_ROUNDS = 5
FG_START_S = 13.0
MIN_AGG_THROUGHPUT = 0.9
MAX_PRIORITY_SLOWDOWN = 1.25
# decision-flip cells: mid-bandwidth uplinks where a fabric backend
# wins solo, + a near-continuous large-tier traffic generator
FLIP_BW_MB = 300.0
FLIP_TIERS_FULL = ("small", "medium", "big")
FLIP_TIERS_QUICK = ("small", "big")
FLIP_BACKENDS = ("mpi_generic", "mpi_mem_buff", "grpc", "torch_rpc",
                 "grpc+s3")
FLIP_ROUNDS = 3
HOG_ROUNDS = 150


def _topo(bw_mb: float):
    from repro.scenario import EdgeSpec, TopologySpec
    edges = tuple(EdgeSpec(src="server", dst=f"client{i}",
                           bw_single_mb=bw_mb, bw_multi_mb=bw_mb,
                           latency_ms=LATENCY_MS)
                  for i in range(N_CLIENTS))
    return TopologySpec(kind="geo_distributed", num_clients=N_CLIENTS,
                        edges=edges)


def _colo_scenario(name: str, seed: int):
    from repro.scenario import (ChannelSpec, FaultSpec, FleetSpec, Scenario,
                                StrategySpec)
    return Scenario(name=name, seed=seed, topology=_topo(COLO_BW_MB),
                    fleet=FleetSpec(tier="big"),
                    channel=ChannelSpec(backend="grpc"),
                    faults=FaultSpec(availability_trace=COLO_CHURN,
                                     trace_horizon_s=COLO_HORIZON_S),
                    strategy=StrategySpec(mode="fedbuff", rounds=COLO_ROUNDS,
                                          buffer_k=2))


def _flip_fg(tier: str, backend: str):
    from repro.scenario import (ChannelSpec, FleetSpec, Scenario,
                                StrategySpec)
    return Scenario(name=f"fig12-flip-{tier}-{backend}", seed=0,
                    topology=_topo(FLIP_BW_MB),
                    fleet=FleetSpec(tier=tier),
                    channel=ChannelSpec(backend=backend),
                    strategy=StrategySpec(mode="semisync", rounds=FLIP_ROUNDS,
                                          quorum_fraction=1.0))


def _flip_hog():
    """Checkpoint-sync tenant: all wire, no training gaps (train_s
    override) — near-continuous 1.2 GB flows on every shared edge."""
    from repro.scenario import (ChannelSpec, FleetSpec, Scenario,
                                StrategySpec)
    return Scenario(name="fig12-hog", seed=1, topology=_topo(FLIP_BW_MB),
                    fleet=FleetSpec(tier="large", train_s=0.1),
                    channel=ChannelSpec(backend="mpi_mem_buff"),
                    strategy=StrategySpec(mode="fedbuff", rounds=HOG_ROUNDS,
                                          buffer_k=1))


def _pair(policy: str, w_fg: float = 1.0, w_bg: float = 1.0):
    from repro.scenario import FabricSpec, JobSpec, MultiScenario
    return MultiScenario(
        name=f"fig12-pair-{policy}",
        fabric=FabricSpec(policy=policy, shared_links=True),
        jobs=(JobSpec("fg", _colo_scenario("fig12-fg", 0), priority=1,
                      start_s=FG_START_S, weight=w_fg),
              JobSpec("bg", _colo_scenario("fig12-bg", 1), weight=w_bg)))


# -- gate 1: single-tenant bit-identity -------------------------------------

def _solo_trace(sc, tag: str):
    """Plain pre-tenancy solo run: build_runtime + FLScheduler."""
    from repro.configs.paper_tiers import TIERS
    from repro.core.message import VirtualPayload
    from repro.fl import make_strategy
    from repro.fl.fault import make_availability
    from repro.fl.scheduler import FLScheduler
    from repro.scenario import build_runtime
    from repro.sweep.runners import make_clients
    rt = build_runtime(sc)
    clients = make_clients(rt, compression=sc.channel.compression)
    strategy = make_strategy(sc.fl_config(), sc.topology.num_clients)
    availability = make_availability(
        sc.faults.availability_trace, [c.client_id for c in clients],
        horizon_s=sc.faults.trace_horizon_s, seed=sc.seed)
    sched = FLScheduler(rt.make_backend("server", compression="none"),
                        clients, strategy,
                        local_steps=sc.fleet.local_steps,
                        availability=availability,
                        cohort_k=sc.fleet.cohort_k, cohort_seed=sc.seed,
                        streaming_hub=sc.strategy.streaming_hub)
    tier = TIERS[sc.fleet.tier]
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag=tag),
                    max_aggregations=sc.strategy.rounds)
    return rep, list(sched.loop.trace), rt.fabric


def _tenant_trace(sc, job_name: str):
    """The same scenario through the full tenancy machinery: namespaced
    job on a FabricSpec'd fabric, bootstrapped by MultiScheduler on a
    shared loop (shared_links off = the single-tenant safety net)."""
    from repro.configs.paper_tiers import TIERS
    from repro.core.backends import make_backend
    from repro.core.message import VirtualPayload
    from repro.core.netsim import NCAL
    from repro.core.objectstore import ObjectStore
    from repro.core.transport import Fabric, FabricSpec
    from repro.fl import make_strategy
    from repro.fl.client import FLClient
    from repro.fl.fault import make_availability
    from repro.fl.multijob import MultiScheduler
    from repro.fl.scheduler import EventLoop, FLScheduler
    from repro.scenario import fault_model_for
    env = sc.topology.build()
    fabric = Fabric(env, fault_model=fault_model_for(sc),
                    spec=FabricSpec(policy="fifo", shared_links=False))
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    handle = fabric.job(job_name)
    store = ObjectStore(NCAL, fail_rate=sc.faults.store_fail_rate)
    tier = TIERS[sc.fleet.tier]

    def mk(host_id, compression):
        return make_backend(sc.channel.backend, env, fabric, host_id,
                            store=store,
                            compression=None if compression in ("", "none")
                            else compression,
                            wire_codec=sc.channel.wire_codec,
                            chunk_mb=sc.channel.chunk_mb, job=handle)

    loop = EventLoop()
    clients = [FLClient(h.host_id, mk(h.host_id, sc.channel.compression),
                        sim_train_s=tier.train_s(sc.topology.kind))
               for h in env.clients]
    strategy = make_strategy(sc.fl_config(), sc.topology.num_clients)
    availability = make_availability(
        sc.faults.availability_trace, [c.client_id for c in clients],
        horizon_s=sc.faults.trace_horizon_s, seed=sc.seed)
    sched = FLScheduler(mk("server", "none"), clients, strategy,
                        local_steps=sc.fleet.local_steps,
                        availability=availability,
                        cohort_k=sc.fleet.cohort_k, cohort_seed=sc.seed,
                        streaming_hub=sc.strategy.streaming_hub, loop=loop)
    multi = MultiScheduler(loop)
    multi.add_job(job_name, sched,
                  VirtualPayload(tier.payload_bytes, tag=f"multi-{job_name}"),
                  max_aggregations=sc.strategy.rounds)
    rep = multi.run()[job_name]
    return rep, list(loop.trace), fabric, handle


def _identity_gate():
    sc = _colo_scenario("fig12-ident", 0)
    sc.validate()
    # the solo payload tag must match the multi driver's job-derived tag
    rep_s, trace_s, fab_s = _solo_trace(sc, tag="multi-solo")
    rep_m, trace_m, fab_m, handle = _tenant_trace(sc, "solo")
    # the only multi-only event is the bootstrap marker
    trace_m = [e for e in trace_m if not e[1].startswith("job-start:")]
    identical = trace_s == trace_m
    assert identical, (
        "fig12: single-tenant trace diverged through the tenancy "
        "machinery (job namespace + MultiScheduler + FabricSpec)")
    assert rep_s.sim_time == rep_m.sim_time
    stats_s = {k: fab_s.stats[k] for k in ("messages", "bytes")}
    stats_m = {k: fab_m.stats[k] for k in ("messages", "bytes")}
    stats_j = {k: fab_m.stats_for(handle.name)[k]
               for k in ("messages", "bytes")}
    assert stats_s == stats_m == stats_j, (
        f"fig12: single-tenant wire stats diverged: solo {stats_s}, "
        f"multi global {stats_m}, multi per-job {stats_j}")
    return {"trace_identical": identical, "events": len(trace_s),
            "sim_time_s": rep_s.sim_time, **stats_s}


# -- gates 2+3: co-location and priority admission --------------------------

def _colocation_gates():
    from repro.sweep.runners import run_multi, run_scenario
    solo = {name: run_scenario(_colo_scenario(f"fig12-{name}", seed))
            for name, seed in (("fg", 0), ("bg", 1))}
    out = {"solo": {n: {"round_s": r["round_s"]} for n, r in solo.items()}}

    fifo = run_multi(_pair("fifo"))
    ratios = {n: fifo["jobs"][n]["round_s"] / solo[n]["round_s"]
              for n in ("fg", "bg")}
    agg = (sum(1.0 / fifo["jobs"][n]["round_s"] for n in ("fg", "bg"))
           / sum(1.0 / solo[n]["round_s"] for n in ("fg", "bg")))
    for n, r in ratios.items():
        assert r > 1.0, (
            f"fig12: co-located job '{n}' was not slower than solo "
            f"({r:.3f}x) — the shared uplink shows no contention")
    assert agg >= MIN_AGG_THROUGHPUT, (
        f"fig12: aggregate round throughput {agg:.3f}x solo < "
        f"{MIN_AGG_THROUGHPUT}x — co-location is pathological, not shared")
    out["fifo"] = {"slowdown": ratios, "aggregate_throughput": agg}

    prio = run_multi(_pair("priority"))
    fg_ratio = prio["jobs"]["fg"]["round_s"] / solo["fg"]["round_s"]
    assert fg_ratio <= MAX_PRIORITY_SLOWDOWN, (
        f"fig12: priority admission left the foreground at {fg_ratio:.3f}x "
        f"solo (bound {MAX_PRIORITY_SLOWDOWN}x)")
    out["priority"] = {
        "fg_slowdown": fg_ratio,
        "bg_slowdown": prio["jobs"]["bg"]["round_s"] / solo["bg"]["round_s"]}
    return out


# -- gate: admission-weighted fair share -------------------------------------

WEIGHT_FG = 3.0  # the weighted re-run's fg:bg admission weights
# the weight is a guaranteed *floor* (cap * w / Σw), not a proportional
# split — a tenant alone on the pipe still takes full cap — so the
# co-active fg/bg grant ratio lands between 1 and w, not at w. Gates:
# the 3:1 run must tilt grants toward fg in absolute terms (> 1x where
# the equal-weight run measures ~0.84x) and by >= 1.3x vs equal weights
# (measured: 1.26/0.84 = 1.50x; the sim is deterministic)
MIN_GRANT_RATIO = 1.0
MIN_GRANT_GAIN = 1.3


def _grant_stats(fabric):
    """Walk every shared pipe's granted ``(t0, t1, rate, prio, job)``
    segments: total granted bytes per job, plus the fg/bg rate ratio
    over the intervals where BOTH tenants hold segments on the same
    pipe — the window where the weighted guarantee actually bites."""
    granted = {"fg": 0.0, "bg": 0.0}
    co_fg = co_bg = 0.0
    for pipe in fabric._pipes.values():
        pts = sorted({t for (a, b, *_r) in pipe.resv for t in (a, b)})
        for (a, b, r, _p, j) in pipe.resv:
            granted[j] = granted.get(j, 0.0) + r * (b - a)
        for lo, hi in zip(pts, pts[1:]):
            mid = (lo + hi) / 2.0
            rates = {"fg": 0.0, "bg": 0.0}
            for (a, b, r, _p, j) in pipe.resv:
                if a <= mid < b:
                    rates[j] = rates.get(j, 0.0) + r
            if rates["fg"] > 0.0 and rates["bg"] > 0.0:
                co_fg += rates["fg"] * (hi - lo)
                co_bg += rates["bg"] * (hi - lo)
    ratio = co_fg / co_bg if co_bg > 0.0 else float("inf")
    return granted, ratio


def _weighted_gates():
    """JobSpec.weight through the fair-share admission formula: unit
    weights are a no-op (bit-identical pair run), and a 3:1 weighting
    shows up as a ~3:1 granted-rate ratio wherever both tenants contend
    the same pipe."""
    from repro.sweep.runners import run_multi
    rt_base: dict = {}
    base = run_multi(_pair("fair-share"), runtime_out=rt_base)
    explicit = run_multi(_pair("fair-share", 1.0, 1.0))
    assert base["jobs"] == explicit["jobs"], (
        "fig12: explicit weight=1.0 diverged from the default-weight "
        "fair-share pair — unit weights must be a bit-identical no-op")
    _, base_ratio = _grant_stats(rt_base["fabric"])

    rt: dict = {}
    weighted = run_multi(_pair("fair-share", WEIGHT_FG, 1.0),
                         runtime_out=rt)
    granted, ratio = _grant_stats(rt["fabric"])
    assert ratio > MIN_GRANT_RATIO, (
        f"fig12: 3:1 weighting granted only {ratio:.2f}x fg/bg rate in "
        f"co-active segments (gate > {MIN_GRANT_RATIO}x)")
    assert ratio >= base_ratio * MIN_GRANT_GAIN, (
        f"fig12: 3:1 weighting shifted the co-active grant ratio only "
        f"{ratio / base_ratio:.2f}x vs equal weights "
        f"({base_ratio:.2f} -> {ratio:.2f}; gate >= {MIN_GRANT_GAIN}x)")
    assert weighted["jobs"]["fg"]["round_s"] <= \
        base["jobs"]["fg"]["round_s"] * (1 + 1e-9), (
        f"fig12: weight {WEIGHT_FG:g} made the foreground SLOWER than "
        f"equal-weight fair share "
        f"({weighted['jobs']['fg']['round_s']:.2f}s vs "
        f"{base['jobs']['fg']['round_s']:.2f}s)")
    return {
        "weights": {"fg": WEIGHT_FG, "bg": 1.0},
        "unit_weight_identical": True,
        "granted_bytes": granted,
        "co_active_grant_ratio": ratio,
        "co_active_grant_ratio_equal": base_ratio,
        "fg_round_s": {"equal": base["jobs"]["fg"]["round_s"],
                       "weighted": weighted["jobs"]["fg"]["round_s"]},
        "bg_round_s": {"equal": base["jobs"]["bg"]["round_s"],
                       "weighted": weighted["jobs"]["bg"]["round_s"]}}


# -- gate 4: the decision table flips under contention -----------------------

def _decision_table(tiers):
    from repro.scenario import FabricSpec, JobSpec, MultiScenario
    from repro.sweep.runners import run_multi, run_scenario
    hog = _flip_hog()
    table = {}
    for tier in tiers:
        cells = {}
        for backend in FLIP_BACKENDS:
            fg = _flip_fg(tier, backend)
            solo = run_scenario(fg)["round_s"]
            ms = MultiScenario(
                name=f"fig12-flip-{tier}-{backend}",
                fabric=FabricSpec(policy="fifo", shared_links=True),
                jobs=(JobSpec("fg", fg, start_s=7.0, rounds=FLIP_ROUNDS),
                      JobSpec("bg", hog, rounds=HOG_ROUNDS)))
            contended = run_multi(ms)["jobs"]["fg"]["round_s"]
            cells[backend] = {"solo_round_s": solo,
                              "contended_round_s": contended}
        solo_winner = min(cells, key=lambda b: cells[b]["solo_round_s"])
        cont_winner = min(cells, key=lambda b: cells[b]["contended_round_s"])
        table[tier] = {"cells": cells, "solo_winner": solo_winner,
                       "contended_winner": cont_winner,
                       "flipped": solo_winner != cont_winner}
    flips = [t for t, row in table.items() if row["flipped"]]
    assert flips, (
        "fig12: no (backend, tier) cell flipped its winner under "
        "contention — the solo decision table survived co-location")
    return table, flips


def run(verbose: bool = True, quick: bool = False):
    tiers = FLIP_TIERS_QUICK if quick else FLIP_TIERS_FULL
    identity = _identity_gate()
    colo = _colocation_gates()
    weighted = _weighted_gates()
    table, flips = _decision_table(tiers)

    result = {
        "bench": "fig12_multitenant",
        "deployment": {"clients": N_CLIENTS, "latency_ms": LATENCY_MS,
                       "colo_bw_mb": COLO_BW_MB, "flip_bw_mb": FLIP_BW_MB,
                       "churn": COLO_CHURN, "fg_start_s": FG_START_S},
        "single_tenant_identity": identity,
        "colocation": colo,
        "weighted_fair_share": weighted,
        "decision_table": table,
        "flipped_tiers": flips,
    }
    os.makedirs(os.path.dirname(_OUT), exist_ok=True)
    with open(_OUT, "w") as f:
        json.dump(result, f, indent=2)

    rows = [{"name": "fig12/identity",
             "trace_identical": identity["trace_identical"]},
            {"name": "fig12/fifo",
             "fg_slowdown": colo["fifo"]["slowdown"]["fg"],
             "bg_slowdown": colo["fifo"]["slowdown"]["bg"],
             "aggregate_throughput": colo["fifo"]["aggregate_throughput"]},
            {"name": "fig12/priority",
             "fg_slowdown": colo["priority"]["fg_slowdown"]},
            {"name": "fig12/weighted",
             "co_active_grant_ratio": weighted["co_active_grant_ratio"],
             "fg_round_s_weighted": weighted["fg_round_s"]["weighted"]}]
    rows += [{"name": f"fig12/flip/{t}",
              "solo_winner": row["solo_winner"],
              "contended_winner": row["contended_winner"]}
             for t, row in table.items()]

    if verbose:
        print("\n== Fig 12: multi-tenant fabric (shared links, admission "
              "policies, decision flip) ==")
        print(f"single-tenant identity: trace of {identity['events']} "
              f"events + wire stats bit-identical through the tenancy "
              f"machinery")
        f_ = colo["fifo"]
        print(f"fifo co-location: fg {f_['slowdown']['fg']:.3f}x / "
              f"bg {f_['slowdown']['bg']:.3f}x solo round time, aggregate "
              f"throughput {f_['aggregate_throughput']:.3f}x "
              f"(gate >= {MIN_AGG_THROUGHPUT}x)")
        print(f"priority admission: fg {colo['priority']['fg_slowdown']:.3f}x"
              f" solo (gate <= {MAX_PRIORITY_SLOWDOWN}x), bg absorbs at "
              f"{colo['priority']['bg_slowdown']:.3f}x")
        w = weighted
        print(f"weighted fair share ({WEIGHT_FG:g}:1): unit weights "
              f"bit-identical; co-active grant ratio "
              f"{w['co_active_grant_ratio_equal']:.2f} -> "
              f"{w['co_active_grant_ratio']:.2f} (gates > "
              f"{MIN_GRANT_RATIO}x and >= {MIN_GRANT_GAIN}x shift); "
              f"fg round {w['fg_round_s']['equal']:.2f}s -> "
              f"{w['fg_round_s']['weighted']:.2f}s")
        print(f"{'tier':>8s} {'solo winner':>14s} {'contended':>14s}")
        for t, row in table.items():
            mark = "  << FLIP" if row["flipped"] else ""
            print(f"{t:>8s} {row['solo_winner']:>14s} "
                  f"{row['contended_winner']:>14s}{mark}")
        print(f"[fig12] record -> {_OUT}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="decision table over 2 tiers instead of 3")
    args = ap.parse_args()
    run(quick=args.quick)
