"""Fig 11: fleet-scale engine — rounds/s and peak server memory vs fleet size.

Sweeps the fleet axis (14 / 100 / 1k, full adds 10k) through the
semisync scheduler in its fleet configuration — calendar event queue,
vectorised flow solver, seeded cohort sampling (K=100 above 100
clients) and the streaming O(model) hub — and compares against the
un-vectorised pre-PR hot path (heapq queue, scalar solver, linear
inbox scan, linear host lookup, dense O(clients) hub, full-fleet
participation) re-enabled via the baseline context managers.

Gates (the PR's acceptance criteria, re-checked on every bench run):

* 14-client traces bit-identical: the new engine at paper scale must
  replay the exact historical event sequence.
* >= 5x rounds/s at 1k clients: the fleet configuration vs the pre-PR
  path (the only way to run 1k clients before this change).
* sub-linear peak server memory: the streaming hub holds the peak flat
  while the dense hub grows with the fleet.

Writes ``benchmarks/out/fig11_scale.json`` plus the BENCH_7 trajectory
record ``benchmarks/out/BENCH_7.json`` (gated by benchmarks/trajectory.py
against the committed ``benchmarks/BENCH_7.json``).
"""
from __future__ import annotations

import contextlib
import json
import os
import time

BENCH_NAME = "fig11"
BENCH_ORDER = 110  # after the paper figs, before the trajectory gate
BENCH_IN_QUICK = True

_OUT = os.path.join(os.path.dirname(__file__), "out", "fig11_scale.json")
_BENCH7 = os.path.join(os.path.dirname(__file__), "out", "BENCH_7.json")

FLEETS_QUICK = (14, 100, 1_000)
FLEETS_FULL = (14, 100, 1_000, 10_000)
ROUNDS = 5
COHORT_K = 100  # fleets above this sample a seeded K-of-N cohort
SPEEDUP_FLEET = 1_000  # the ISSUE's >= 5x gate point
MIN_SPEEDUP = 5.0


def _build(n: int, engine: str):
    from repro.fl import make_strategy
    from repro.fl.scheduler import FLScheduler
    from repro.scenario import Scenario, build_runtime
    from repro.scenario.spec import FleetSpec, StrategySpec, TopologySpec
    from repro.sweep.runners import make_clients
    # the pre-PR path has no cohort sampling: full-fleet participation
    cohort = COHORT_K if engine == "new" and n > COHORT_K else 0
    sc = Scenario(name=f"fig11_{n}_{engine}",
                  topology=TopologySpec(kind="geo_distributed",
                                        num_clients=n),
                  fleet=FleetSpec(tier="small", local_steps=4,
                                  cohort_k=cohort),
                  strategy=StrategySpec(mode="semisync",
                                        quorum_fraction=0.8))
    sc.validate()
    rt = build_runtime(sc)
    clients = make_clients(rt, compression="none")
    strategy = make_strategy(sc.fl_config(), n)
    kw = dict(local_steps=4, cohort_k=cohort, cohort_seed=sc.seed)
    if engine == "new":
        kw.update(event_queue="calendar", streaming_hub=True)
    else:
        kw.update(event_queue="heap", streaming_hub=False)
    return FLScheduler(rt.make_backend("server", compression="none"),
                       clients, strategy, **kw), cohort


def _legacy_ctx():
    """The pre-PR hot path, re-enabled: scalar fluid solver, O(inbox)
    recv scan, O(clients) host lookup (results identical, complexity
    historical)."""
    from repro.core.netsim import linear_host_lookup, scalar_transfers
    from repro.core.transport import linear_inbox
    stack = contextlib.ExitStack()
    stack.enter_context(scalar_transfers())
    stack.enter_context(linear_inbox())
    stack.enter_context(linear_host_lookup())
    return stack


def _run(n: int, engine: str):
    from repro.configs.paper_tiers import TIERS
    from repro.core.message import VirtualPayload
    from repro.core.netsim import MB
    sched, cohort = _build(n, engine)
    ctx = _legacy_ctx() if engine == "legacy" else contextlib.nullcontext()
    t0 = time.perf_counter()
    with ctx:
        rep = sched.run(VirtualPayload(TIERS["small"].payload_bytes,
                                       tag="fig11"),
                        max_aggregations=ROUNDS)
    wall = time.perf_counter() - t0
    return {"fleet": n, "engine": engine, "cohort_k": cohort,
            "rounds": rep.n_aggregations,
            "wall_s": wall,
            "rounds_per_s": rep.n_aggregations / wall,
            "sim_time_s": rep.sim_time,
            "peak_server_MB": sched.backend.endpoint.memory.peak / MB,
            "trace": sched.loop.trace}


def run(verbose: bool = True, quick: bool = False):
    fleets = FLEETS_QUICK if quick else FLEETS_FULL
    rows, points = [], {}
    for n in fleets:
        r = _run(n, "new")
        points[n] = r
        rows.append({"name": f"fig11/{n}/new",
                     "rounds_per_s": r["rounds_per_s"],
                     "peak_server_MB": r["peak_server_MB"]})

    # gate 1: paper-scale trace bit-identity against the pre-PR path
    legacy_14 = _run(14, "legacy")
    trace_identical = points[14]["trace"] == legacy_14["trace"]
    assert trace_identical, (
        "fig11: 14-client trace diverged from the pre-PR heapq/dense path")

    # gate 2: >= 5x rounds/s at 1k clients over the un-vectorised path
    legacy_1k = _run(SPEEDUP_FLEET, "legacy")
    rows.append({"name": f"fig11/{SPEEDUP_FLEET}/legacy",
                 "rounds_per_s": legacy_1k["rounds_per_s"],
                 "peak_server_MB": legacy_1k["peak_server_MB"]})
    speedup = points[SPEEDUP_FLEET]["rounds_per_s"] \
        / legacy_1k["rounds_per_s"]
    assert speedup >= MIN_SPEEDUP, (
        f"fig11: {speedup:.2f}x rounds/s at {SPEEDUP_FLEET} clients "
        f"< the required {MIN_SPEEDUP:.0f}x over the un-vectorised path")

    # gate 3: sub-linear peak server memory vs fleet size (the streaming
    # hub holds the peak near-flat; linear growth would track fleet/14)
    n_max = max(fleets)
    mem_ratio = points[n_max]["peak_server_MB"] \
        / max(points[14]["peak_server_MB"], 1e-9)
    sublinear_bound = 0.25 * n_max / 14
    assert mem_ratio <= sublinear_bound, (
        f"fig11: peak server memory grew {mem_ratio:.1f}x from 14 to "
        f"{n_max} clients (bound {sublinear_bound:.1f}x) — not sub-linear")

    result = {
        "bench": "fig11_scale", "rounds": ROUNDS,
        "mode": "semisync", "cohort_k": COHORT_K,
        "fleets": {str(n): {k: v for k, v in p.items() if k != "trace"}
                   for n, p in points.items()},
        "legacy_1k": {k: v for k, v in legacy_1k.items() if k != "trace"},
        "speedup_1k": speedup,
        "trace_identical_14": trace_identical,
        "mem_ratio_max_fleet": mem_ratio,
        "dense_peak_1k_MB": legacy_1k["peak_server_MB"],
    }
    os.makedirs(os.path.dirname(_OUT), exist_ok=True)
    with open(_OUT, "w") as f:
        json.dump(result, f, indent=2)
    # the BENCH_7 trajectory record: machine-portable ratios only
    with open(_BENCH7, "w") as f:
        json.dump({"bench": "BENCH_7", "recorded_at_pr": 7,
                   "speedup_1k": speedup,
                   "mem_ratio_max_fleet": mem_ratio,
                   "max_fleet": n_max,
                   "streaming_peak_MB": points[n_max]["peak_server_MB"],
                   "dense_peak_1k_MB": legacy_1k["peak_server_MB"]},
                  f, indent=2)
    if verbose:
        print("\n== Fig 11: fleet-scale engine (semisync, rounds/s and "
              "peak server MB) ==")
        print(f"{'fleet':>8s} {'cohort':>7s} {'rounds/s':>10s} "
              f"{'peak MB':>9s}")
        for n in fleets:
            p = points[n]
            print(f"{n:8d} {p['cohort_k'] or n:7d} "
                  f"{p['rounds_per_s']:10.2f} {p['peak_server_MB']:9.1f}")
        print(f"legacy @ {SPEEDUP_FLEET}: "
              f"{legacy_1k['rounds_per_s']:.2f} rounds/s, "
              f"{legacy_1k['peak_server_MB']:.1f} MB peak "
              f"(heap+scalar+linear+dense, full fleet)")
        print(f"speedup @ {SPEEDUP_FLEET}: {speedup:.1f}x "
              f"(gate >= {MIN_SPEEDUP:.0f}x) | 14-client trace identical: "
              f"{trace_identical} | mem {mem_ratio:.2f}x at {n_max} "
              f"(sub-linear bound {sublinear_bound:.1f}x)")
        print(f"[fig11] record -> {_OUT}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="fleet points 14/100/1k (full adds 10k)")
    args = ap.parse_args()
    run(quick=args.quick)
