"""Beyond-paper: the gRPC+S3 split applied at TPU-fleet scale.

Pods = silos; DCN = WAN. Compares cross-pod parameter-sync strategies for
each assigned arch (payload = its full parameter pytree in bf16):

  per-step all-reduce | local-K + f32 delta | local-K + int8 delta (QSGD)
  | local-K + gRPC+S3-style single-upload/multi-download between pod
  leaders over the geo-distributed WAN (multi-datacenter training).

Reports sync seconds per optimizer step at K=1 vs K=8 local steps.
"""
from __future__ import annotations

BENCH_NAME = "crosspod"
BENCH_ORDER = 210
BENCH_IN_QUICK = False  # JAX-heavy; skipped by the CI smoke

from repro.configs import ARCH_ORDER, get_config
from repro.core import FLMessage, VirtualPayload, make_backend
from repro.models import param_count
from repro.roofline.analysis import DCN_BW
from benchmarks.common import deployment

N_PODS = 2
HOSTS_PER_POD = 64  # v5e: 256 chips / 4 per host


def _dcn_allreduce_s(nbytes: float) -> float:
    """Ring all-reduce between pods over DCN, all hosts participating."""
    eff = 2 * (N_PODS - 1) / N_PODS * nbytes
    return eff / (HOSTS_PER_POD * DCN_BW)


def run(verbose=True):
    rows = []
    if verbose:
        print("\n== Cross-pod sync cost per optimizer step (pods=silos) ==")
        print(f"{'arch':26s} {'params':>8s} {'step AR':>10s} {'K8 f32':>10s} "
              f"{'K8 int8':>10s} {'K8 s3-wan':>11s}")
    env, fabric, store = deployment("geo_distributed")
    for arch in ARCH_ORDER:
        cfg = get_config(arch)
        n = param_count(cfg)
        nbytes = 2.0 * n  # bf16 payload
        per_step = _dcn_allreduce_s(nbytes)
        k8_f32 = _dcn_allreduce_s(2.0 * nbytes) / 8  # f32 delta every 8
        k8_int8 = _dcn_allreduce_s(0.5 * nbytes + nbytes / 256) / 8
        # pod leaders exchange via object store over true WAN (the paper's
        # backend, multi-datacenter): upload once + N-1 downloads
        be = make_backend("grpc+s3", env, fabric, "server", store=store)
        msgs = [FLMessage("sync", "server", f"client{i}",
                          payload=VirtualPayload(int(nbytes * 0.25),
                                                 tag=arch))
                for i in range(N_PODS - 1)]
        _, arrives = be.broadcast(msgs, 0.0)
        k8_s3 = max(arrives) / 8
        for c in env.clients:
            fabric.endpoints[c.host_id].inbox.clear()
        rows.append({"name": f"crosspod/{arch}", "params_B": n / 1e9,
                     "per_step_ar_s": per_step, "k8_f32_s": k8_f32,
                     "k8_int8_s": k8_int8, "k8_s3_wan_s": k8_s3})
        if verbose:
            print(f"{arch:26s} {n / 1e9:7.1f}B {per_step:10.3f} "
                  f"{k8_f32:10.3f} {k8_int8:10.3f} {k8_s3:11.3f}")
    if verbose:
        print("   (per-step AR = fully synchronous DP over DCN; K8 = DiLoCo-"
              "style local steps; int8 = QSGD kernel payloads;\n    s3-wan = "
              "pod leaders in different datacenters via the paper's hybrid "
              "backend, int8 payload)")
    return rows


if __name__ == "__main__":
    run()
