"""Fig 9 (beyond the paper): topology as a tuning knob under WAN.

Marfoq & Neglia's *Throughput-Optimal Topology Design for Cross-Silo FL*
argues the aggregation topology is a first-class knob next to backend
choice. The scenario layer makes that claim runnable: each cell of this
study is literally one ``Scenario`` whose ``TopologySpec`` names a graph
preset, enumerated over backends by one declarative Sweep:

* ``star``      — the paper's default hub-and-spoke: synchronous FedAvg
  rounds, every silo's update crosses its own WAN link to the hub.
* ``multi_hub`` — hierarchical per-region relays (HierarchicalStrategy):
  LAN-class intra-region reduce from the graph's DC edges, then one
  multi-connection WAN hop per region over the relay's real backend
  channel.
* ``ring``      — a token ring over the graph's client-client edges
  (bottleneck-of-both-hub-links capacity, summed latency): the partial
  aggregate hops silo to silo and the last one closes to the hub. Every
  hop is a real backend send over the ring edge.

14 clients (2 per Table-I region), tier Big, gRPC and gRPC+S3.

Validations (CI gate):
1. hierarchical (multi_hub) beats star round time for gRPC at the big
   tier on the WAN — aggregating inside the region before crossing the
   WAN pays;
2. the ring is never the fastest topology at 14 clients for any backend —
   serialising 14 WAN hops loses to both alternatives (its O(n) critical
   path is the Marfoq et al. argument against plain rings at silo count).

The engine writes ``benchmarks/out/fig9_topology_wan.json``.
"""
from __future__ import annotations

from benchmarks.common import ENGINE, scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import FLMessage, VirtualPayload
from repro.fl.async_strategies import HierarchicalStrategy
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep

BENCH_ORDER = 80
N_CLIENTS = 14
BACKENDS = ("grpc", "grpc+s3")
TOPOLOGIES = ("star", "multi_hub", "ring")
TIER = "big"


def _sweeps(quick):
    return (Sweep(name="fig9",
                  base=scenario_for("star", num_clients=N_CLIENTS,
                                    name="fig9"),
                  axes=(Axis("channel.backend", values=BACKENDS),
                        Axis("topology.kind", values=TOPOLOGIES)),
                  params={"rounds": 2 if quick else 4}),)


def _scenario(topology, backend, mode):
    return scenario_for(topology, backend=backend, num_clients=N_CLIENTS,
                        mode=mode, name=f"fig9:{topology}:{backend}")


def _run_star(backend, tier, rounds):
    """Synchronous FedAvg over the pure hub-and-spoke graph."""
    sc = _scenario("star", backend, "sync")
    rt = build_runtime(sc)
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s("star"))
               for h in rt.env.clients]
    server = FLServer(rt.make_backend("server"), clients, local_steps=1,
                      live=False)
    for r in range(rounds):
        server.run_round(VirtualPayload(tier.payload_bytes,
                                        tag=f"fig9-star-r{r}"))
    return {"scenario": sc.to_dict(), "round_s": server.now / rounds,
            "sim_time_s": server.now, "rounds": rounds}


def _run_hier(backend, tier, rounds):
    """Hierarchical relays over the multi_hub graph (real relay WAN
    channel, intra-region reduce over the graph's DC edges)."""
    sc = _scenario("multi_hub", backend, "hier")
    rt = build_runtime(sc)
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s("multi_hub"))
               for h in rt.env.clients]
    strategy = HierarchicalStrategy(staleness_exponent=0.0)
    sched = FLScheduler(rt.make_backend("server"), clients, strategy,
                        local_steps=1)
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig9-hier"),
                    max_aggregations=rounds)
    return {"scenario": sc.to_dict(),
            "round_s": rep.sim_time / max(rep.n_aggregations, 1),
            "sim_time_s": rep.sim_time, "rounds": rep.n_aggregations}


def _run_ring(backend, tier, rounds):
    """Token-ring aggregation over the ring graph's client-client edges:
    broadcast the model, train, then the partial aggregate circles
    silo -> silo (each hop a real backend send over the ring edge) and
    the last silo closes to the hub."""
    sc = _scenario("ring", backend, "sync")
    rt = build_runtime(sc)
    ids = [h.host_id for h in rt.env.clients]
    bes = {cid: rt.make_backend(cid) for cid in ids}
    server_be = rt.make_backend("server")
    train_s = tier.train_s("ring")
    t0, n = 0.0, len(ids)
    for r in range(rounds):
        payload = VirtualPayload(tier.payload_bytes, tag=f"fig9-ring-r{r}")
        msgs = [FLMessage("model_sync", "server", cid, payload=payload)
                for cid in ids]
        _, arrives = server_be.broadcast(msgs, t0)
        ready = []
        for cid, arrive in zip(ids, arrives):
            got = bes[cid].recv(arrive + 1e9)
            ready.append(max(rt_ for _, rt_ in got) + train_s)
        # the token: client i folds its update into the partial and
        # forwards it to client i+1; the last closes to the hub
        t = ready[0]
        for i, cid in enumerate(ids):
            nxt = ids[i + 1] if i + 1 < n else "server"
            partial = VirtualPayload(tier.payload_bytes,
                                     tag=f"fig9-ring-r{r}-hop{i}")
            h = bes[cid].isend(FLMessage("client_update", cid, nxt,
                                         payload=partial), t)
            rcv = bes[nxt].recv(h.arrive + 1e9) if nxt != "server" \
                else server_be.recv(h.arrive + 1e9)
            landed = max(rt_ for _, rt_ in rcv)
            # the next silo forwards once it holds the token AND its own
            # update is trained
            t = max(landed, ready[i + 1]) if i + 1 < n else landed
        t0 = t
    return {"scenario": sc.to_dict(), "round_s": t0 / rounds,
            "sim_time_s": t0, "rounds": rounds}


RUNNERS = {"star": _run_star, "multi_hub": _run_hier, "ring": _run_ring}


def _cell(cell):
    topo = cell.scenario.topology.kind
    backend = cell.scenario.channel.backend
    return RUNNERS[topo](backend, TIERS[TIER], cell.params["rounds"])


def _name(cell):
    return (f"fig9/{cell.scenario.topology.kind}/"
            f"{cell.scenario.channel.backend}")


def _finalize(results, quick, verbose):
    report = {"n_clients": N_CLIENTS, "tier": TIER, "cells": {}}
    rows = []
    for r in results:
        _, topo, backend = r.cell.split("/")
        cell = report["cells"].setdefault(backend, {})
        cell[topo] = {"scenario": r.metrics["scenario"],
                      "round_s": r.metrics["round_s"],
                      "sim_time_s": r.sim_time_s,
                      "rounds": r.metrics["rounds"]}
        rows.append({"name": r.cell, "round_s": r.metrics["round_s"]})
    if verbose:
        for backend, cell in report["cells"].items():
            parts = "  ".join(f"{t}={cell[t]['round_s']:8.1f}s"
                              for t in RUNNERS)
            print(f"[fig9] {backend:9s}  {parts}")
    report["validation"] = _validate(report, verbose)
    return report, rows


def _validate(report, verbose):
    grpc = report["cells"]["grpc"]
    assert grpc["multi_hub"]["round_s"] < grpc["star"]["round_s"], (
        f"fig9: hierarchical (multi_hub) must beat star for the big tier "
        f"on gRPC over WAN; got hier={grpc['multi_hub']['round_s']:.1f}s "
        f"vs star={grpc['star']['round_s']:.1f}s")
    ring_never_fastest = True
    for backend, cell in report["cells"].items():
        best_alt = min(cell["star"]["round_s"], cell["multi_hub"]["round_s"])
        assert cell["ring"]["round_s"] > best_alt, (
            f"fig9: ring came out fastest for {backend} at {N_CLIENTS} "
            f"clients ({cell['ring']['round_s']:.1f}s vs {best_alt:.1f}s) "
            f"— the O(n) token path should lose")
    if verbose:
        speedup = grpc["star"]["round_s"] / grpc["multi_hub"]["round_s"]
        print(f"[fig9] validation: multi_hub beats star on gRPC "
              f"({speedup:.2f}x); ring never fastest at {N_CLIENTS} "
              f"clients")
    return {"hier_beats_star_grpc": True,
            "ring_never_fastest": ring_never_fastest,
            "grpc_star_over_hier":
            grpc["star"]["round_s"] / grpc["multi_hub"]["round_s"]}


STUDY = Study(
    name="fig9", title="Fig 9: topology as a tuning knob under WAN",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig9_topology_wan.json", order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
