"""Fig 2: effect of concurrent dispatch on gRPC — achieved bandwidth (top)
and sender memory (bottom) for N.California -> Bahrain."""
from __future__ import annotations

from repro.configs.paper_tiers import TIERS
from repro.core import FLMessage, VirtualPayload, make_backend
from repro.core.netsim import MB
from benchmarks.common import deployment


def run(verbose=True):
    env, fabric, store = deployment("geo_distributed")
    bahrain = "client6"
    nbytes = TIERS["big"].payload_bytes  # 253 MB payloads
    rows = []
    if verbose:
        print("\n== Fig 2: gRPC concurrent dispatch, CA -> Bahrain "
              "(253MB payloads) ==")
        print(f"{'channels':>9s} {'agg BW MB/s':>12s} {'peak mem MB':>12s}")
    for n in (1, 2, 4, 8, 16):
        be = make_backend("grpc", env, fabric, "server", store=store)
        msgs = [FLMessage("m", "server", bahrain,
                          payload=VirtualPayload(nbytes, tag=f"c{i}"))
                for i in range(n)]
        done, arrives = be.broadcast(msgs, 0.0)
        span = max(arrives)
        bw = n * nbytes / span / MB
        peak = be.endpoint.memory.peak / MB
        rows.append({"name": f"fig2/channels{n}", "bw_MBps": bw,
                     "peak_mem_MB": peak})
        if verbose:
            print(f"{n:9d} {bw:12.1f} {peak:12.1f}")
        fabric.endpoints[bahrain].inbox.clear()
        be.endpoint.memory.reset()
    # paper claims: bw grows with channels; memory grows ~linearly
    assert rows[-1]["bw_MBps"] > 3 * rows[0]["bw_MBps"]
    assert rows[-1]["peak_mem_MB"] > 8 * rows[0]["peak_mem_MB"]
    return rows


if __name__ == "__main__":
    run()
