"""Fig 2: effect of concurrent dispatch on gRPC — achieved bandwidth (top)
and sender memory (bottom) for N.California -> Bahrain."""
from __future__ import annotations

from benchmarks.common import ENGINE, scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import FLMessage, VirtualPayload
from repro.core.netsim import MB
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep, wire_stats

BENCH_ORDER = 20
BAHRAIN = "client6"


def _sweeps(quick):
    return (Sweep(name="fig2",
                  base=scenario_for("geo_distributed", backend="grpc",
                                    name="fig2"),
                  axes=(Axis("params.channels", values=(1, 2, 4, 8, 16)),)),)


def _cell(cell):
    rt = build_runtime(cell.scenario)
    n = cell.params["channels"]
    nbytes = TIERS["big"].payload_bytes  # 253 MB payloads
    be = rt.make_backend("server")
    msgs = [FLMessage("m", "server", BAHRAIN,
                      payload=VirtualPayload(nbytes, tag=f"c{i}"))
            for i in range(n)]
    done, arrives = be.broadcast(msgs, 0.0)
    span = max(arrives)
    return {"bw_MBps": n * nbytes / span / MB,
            "peak_mem_MB": be.endpoint.memory.peak / MB,
            "sim_time_s": span, **wire_stats(rt.fabric)}


def _finalize(results, quick, verbose):
    rows = [r.row() for r in results]
    if verbose:
        print("\n== Fig 2: gRPC concurrent dispatch, CA -> Bahrain "
              "(253MB payloads) ==")
        print(f"{'channels':>9s} {'agg BW MB/s':>12s} {'peak mem MB':>12s}")
        for r in results:
            print(f"{r.params['channels']:9d} "
                  f"{r.metrics['bw_MBps']:12.1f} "
                  f"{r.metrics['peak_mem_MB']:12.1f}")
    # paper claims: bw grows with channels; memory grows ~linearly
    assert rows[-1]["bw_MBps"] > 3 * rows[0]["bw_MBps"]
    assert rows[-1]["peak_mem_MB"] > 8 * rows[0]["peak_mem_MB"]
    return None, rows


STUDY = Study(
    name="fig2", title="Fig 2: gRPC concurrent dispatch (CA -> Bahrain)",
    sweeps=_sweeps, cell=_cell,
    cell_name=lambda c: f"fig2/channels{c.params['channels']}",
    finalize=_finalize, order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
