"""Fig 4b: speedup of concurrent over sequential transmission of 10
messages (Large uses 5), per backend and environment."""
from __future__ import annotations

from benchmarks.common import ENGINE, backends_for, scenario_for
from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import FLMessage, VirtualPayload
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep

BENCH_ORDER = 31
ENVS = ("lan", "geo_proximal", "geo_distributed")


def _sweeps(quick):
    return tuple(
        Sweep(name=f"fig4b:{env_name}",
              base=scenario_for(env_name, name=f"fig4b:{env_name}"),
              axes=(Axis("fleet.tier", values=tuple(TIER_ORDER)),
                    Axis("channel.backend",
                         values=tuple(backends_for(env_name)))))
        for env_name in ENVS)


def _cell(cell):
    env_name = cell.scenario.topology.kind
    tier = TIERS[cell.scenario.fleet.tier]
    n = 5 if tier.name == "large" else 10
    rt = build_runtime(cell.scenario)
    dst = "client3" if env_name == "geo_distributed" else "client0"
    be = rt.make_backend("server")
    mk = lambda i: FLMessage(
        "m", "server", dst,
        payload=VirtualPayload(tier.payload_bytes, tag=f"{i}"))
    _, seq_arr = be.sequential_broadcast([mk(i) for i in range(n)], 0.0)
    rt.fabric.endpoints[dst].inbox.clear()
    _, conc_arr = be.broadcast([mk(100 + i) for i in range(n)], 0.0)
    return {"speedup": max(seq_arr) / max(conc_arr),
            "sim_time_s": max(conc_arr)}


def _name(cell):
    return (f"fig4b/{cell.scenario.topology.kind}/"
            f"{cell.scenario.fleet.tier}/{cell.scenario.channel.backend}")


def _finalize(results, quick, verbose):
    rows = [r.row() for r in results]
    if verbose:
        print("\n== Fig 4b: concurrent/sequential speedup "
              "(10 msgs, Large: 5) ==")
        by = {r.cell: r.metrics["speedup"] for r in results}
        for env_name in ENVS:
            names = backends_for(env_name)
            print(f"-- {env_name}")
            print("  " + f"{'tier':8s}" + "".join(f"{b:>14s}"
                                                  for b in names))
            for tier_name in TIER_ORDER:
                vals = [by[f"fig4b/{env_name}/{tier_name}/{b}"]
                        for b in names]
                print(f"  {tier_name:8s}" + "".join(f"{v:>14.2f}"
                                                    for v in vals))
    _validate(rows)
    return None, rows


def _validate(rows):
    d = {r["name"]: r["speedup"] for r in rows}
    # paper: substantial gains geo-distributed (up to ~7x for gRPC)
    assert d["fig4b/geo_distributed/big/grpc"] > 4
    # paper: MPI backends *decline* with concurrency on LAN
    assert d["fig4b/lan/big/mpi_mem_buff"] < 1.05
    # concurrency never helps much when a single stream saturates (LAN rpc)
    assert d["fig4b/geo_distributed/big/torch_rpc"] >= 0.9


STUDY = Study(
    name="fig4b", title="Fig 4b: concurrent/sequential speedup",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
