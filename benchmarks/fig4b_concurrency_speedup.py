"""Fig 4b: speedup of concurrent over sequential transmission of 10
messages (Large uses 5), per backend and environment."""
from __future__ import annotations

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import FLMessage, VirtualPayload, make_backend
from benchmarks.common import backends_for, deployment


def run(verbose=True):
    rows = []
    if verbose:
        print("\n== Fig 4b: concurrent/sequential speedup "
              "(10 msgs, Large: 5) ==")
    for env_name in ("lan", "geo_proximal", "geo_distributed"):
        names = backends_for(env_name)
        if verbose:
            print(f"-- {env_name}")
            print("  " + f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names))
        for tier_name in TIER_ORDER:
            tier = TIERS[tier_name]
            n = 5 if tier_name == "large" else 10
            vals = []
            for b in names:
                env, fabric, store = deployment(env_name)
                dst = "client3" if env_name == "geo_distributed" else "client0"
                be = make_backend(b, env, fabric, "server", store=store)
                mk = lambda i: FLMessage(
                    "m", "server", dst,
                    payload=VirtualPayload(tier.payload_bytes, tag=f"{i}"))
                _, seq_arr = be.sequential_broadcast([mk(i) for i in range(n)],
                                                     0.0)
                fabric.endpoints[dst].inbox.clear()
                _, conc_arr = be.broadcast([mk(100 + i) for i in range(n)], 0.0)
                speedup = max(seq_arr) / max(conc_arr)
                vals.append(speedup)
                rows.append({"name": f"fig4b/{env_name}/{tier_name}/{b}",
                             "speedup": speedup})
            if verbose:
                print(f"  {tier_name:8s}" + "".join(f"{v:>14.2f}"
                                                    for v in vals))
    _validate(rows)
    return rows


def _validate(rows):
    d = {r["name"]: r["speedup"] for r in rows}
    # paper: substantial gains geo-distributed (up to ~7x for gRPC)
    assert d["fig4b/geo_distributed/big/grpc"] > 4
    # paper: MPI backends *decline* with concurrency on LAN
    assert d["fig4b/lan/big/mpi_mem_buff"] < 1.05
    # concurrency never helps much when a single stream saturates (LAN rpc)
    assert d["fig4b/geo_distributed/big/torch_rpc"] >= 0.9


if __name__ == "__main__":
    run()
