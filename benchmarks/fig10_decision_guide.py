"""Fig 10: the §VII decision-guideline study — backend selection as data.

The paper's headline deliverable is not a figure but §VII's practical
guidance: which communication backend to pick for a given FL task
(model tier) and network (environment). This study turns that guidance
into a measured decision table: one sweep over
``backend x environment x tier x wire compression``, every cell a full
synchronous FL round through ``build_runtime`` (the fig5 measurement),
reduced to a printed + JSON table of the fastest backend per
(model-tier, network) — with the §VII guideline itself encoded as a
rule and validated against the measured optimum.

Guideline-as-code (``_recommend``): trusted networks (LAN / proximal
region) ride the zero-copy MPI buffer backend; untrusted WANs ride gRPC
below the 10 MB wire threshold and gRPC+S3 above it — the same policy
the AUTO backend routes by per message.

Validations (CI gate; uncompressed slice):
1. gRPC+S3 is the measured-fastest backend for the big tier
   geo-distributed (paper §VI/§VII: up to ~3.8x over gRPC for Large —
   asserted at >= 2x for Big in the quick grid, and in the 3.2-4.2x
   band for Large in the full grid);
2. an MPI variant is (co-)fastest on LAN for the big tier — within the
   1% measurement tie band — and gRPC pays >= 2x over it;
3. AUTO is never slower than the *worst* fixed backend in any cell
   (including the compressed slices): the §VII router can be adopted
   blindly without risking the pathological choice;
4. the guideline recommendation lands within 5% of the measured optimum
   in every uncompressed cell — the decision table agrees with §VII.

The engine writes ``benchmarks/out/fig10_decision_guide.json``.
"""
from __future__ import annotations

from benchmarks.common import ENGINE, backends_for, scenario_for
from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import VirtualPayload
from repro.fl.client import FLClient
from repro.fl.server import FLServer
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep, wire_stats

BENCH_ORDER = 90
ENVS = ("lan", "geo_proximal", "geo_distributed")
TIE_BAND = 1.01       # backends within 1% of the minimum are co-fastest
GUIDELINE_BAND = 1.05  # the §VII recommendation must be within 5% of best
SMALL_WIRE = 10 * 1024 * 1024  # paper: < 10 MB -> pure gRPC


def _tiers(quick):
    return ("small", "big") if quick else tuple(TIER_ORDER)


def _codecs(quick):
    return ("none", "zlib")


def _sweeps(quick):
    return tuple(
        Sweep(name=f"fig10:{env}",
              base=scenario_for(env, name=f"fig10:{env}"),
              axes=(Axis("fleet.tier", values=_tiers(quick)),
                    Axis("channel.wire_codec", values=_codecs(quick)),
                    Axis("channel.backend",
                         values=tuple(backends_for(env)) + ("auto",))))
        for env in ENVS)


def _cell(cell):
    env = cell.scenario.topology.kind
    tier = TIERS[cell.scenario.fleet.tier]
    rt = build_runtime(cell.scenario)
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s(env))
               for h in rt.env.clients]
    server = FLServer(rt.make_backend("server"), clients, local_steps=1,
                      live=False)
    rep = server.run_round(VirtualPayload(tier.payload_bytes, tag="r1"))
    return {"round_s": rep.round_time, "sim_time_s": rep.round_time,
            "n_rounds": 1,
            "stage_charges": {
                **{f"server.{k}": v for k, v in rep.server.items()},
                **{f"client.{k}": v for k, v in rep.clients.items()}},
            **wire_stats(rt.fabric, rt.store)}


def _name(cell):
    return (f"fig10/{cell.scenario.topology.kind}/"
            f"{cell.scenario.fleet.tier}/"
            f"{cell.scenario.channel.wire_codec}/"
            f"{cell.scenario.channel.backend}")


def _recommend(env: str, tier_name: str) -> str:
    """§VII's deployment guideline as a rule (what the table is checked
    against): trusted networks -> the zero-copy MPI buffer backend;
    untrusted WAN -> gRPC under the 10 MB wire threshold, gRPC+S3 over
    it."""
    if env in ("lan", "geo_proximal"):
        return "mpi_mem_buff"
    if TIERS[tier_name].payload_bytes < SMALL_WIRE:
        return "grpc"
    return "grpc+s3"


def _decide(times: dict, env: str, tier_name: str) -> dict:
    """One decision-table entry from a cell's per-backend round times."""
    fixed = {b: t for b, t in times.items() if b != "auto"}
    fastest = min(fixed, key=fixed.get)
    best = fixed[fastest]
    winners = sorted(b for b, t in fixed.items() if t <= best * TIE_BAND)
    rec = _recommend(env, tier_name)
    return {"environment": env, "tier": tier_name,
            "round_s": dict(sorted(times.items(), key=lambda kv: kv[1])),
            "fastest": fastest, "co_fastest": winners,
            "recommended": rec,
            "recommended_over_best": times[rec] / best,
            "auto_over_best": times["auto"] / best,
            "worst_fixed": max(fixed, key=fixed.get),
            "speedup_best_over_worst": max(fixed.values()) / best}


def _finalize(results, quick, verbose):
    cells: dict = {}
    for r in results:
        _, env, tier_name, codec, backend = r.cell.split("/")
        cells.setdefault((env, tier_name, codec), {})[backend] = \
            r.metrics["round_s"]
    report = {"tie_band": TIE_BAND, "guideline_band": GUIDELINE_BAND,
              "decision": [], "compressed": []}
    for (env, tier_name, codec), times in cells.items():
        entry = _decide(times, env, tier_name)
        entry["wire_codec"] = codec
        (report["decision"] if codec == "none"
         else report["compressed"]).append(entry)
    if verbose:
        print("\n== Fig 10: §VII decision guide — fastest backend per "
              "(tier, network) ==")
        print(f"{'network':16s} {'tier':7s} {'fastest':13s} "
              f"{'recommended':13s} {'rec/best':>8s} {'auto/best':>9s} "
              f"{'best/worst':>10s}")
        for e in report["decision"]:
            print(f"{e['environment']:16s} {e['tier']:7s} "
                  f"{e['fastest']:13s} {e['recommended']:13s} "
                  f"{e['recommended_over_best']:8.3f} "
                  f"{e['auto_over_best']:9.3f} "
                  f"{e['speedup_best_over_worst']:10.2f}")
    report["validation"] = _validate(report, quick, verbose)
    rows = [r.row() for r in results]
    return report, rows


def _entry(report, env, tier_name):
    for e in report["decision"]:
        if e["environment"] == env and e["tier"] == tier_name:
            return e
    raise KeyError((env, tier_name))


def _validate(report, quick, verbose):
    # 1) big tier geo-distributed: gRPC+S3 measured fastest, >= 2x gRPC
    geo_big = _entry(report, "geo_distributed", "big")
    assert geo_big["fastest"] == "grpc+s3", (
        f"fig10: expected gRPC+S3 fastest for big/geo_distributed, got "
        f"{geo_big['fastest']}")
    s3_speedup = geo_big["round_s"]["grpc"] / geo_big["round_s"]["grpc+s3"]
    assert s3_speedup >= 2.0, (
        f"fig10: gRPC+S3 only {s3_speedup:.2f}x over gRPC for "
        f"big/geo_distributed (expected >= 2x)")
    large_speedup = None
    if not quick:
        geo_large = _entry(report, "geo_distributed", "large")
        assert geo_large["fastest"] == "grpc+s3"
        large_speedup = (geo_large["round_s"]["grpc"]
                         / geo_large["round_s"]["grpc+s3"])
        assert 3.2 <= large_speedup <= 4.2, (
            f"fig10: large-tier S3 speedup {large_speedup:.2f}x outside "
            f"the paper's 3.5-3.8x band (tolerance 3.2-4.2)")
    # 2) LAN big: an MPI variant co-fastest (1% tie band); gRPC >= 2x it
    lan_big = _entry(report, "lan", "big")
    mpi_winners = [b for b in lan_big["co_fastest"]
                   if b.startswith("mpi_")]
    assert mpi_winners, (
        f"fig10: no MPI variant co-fastest on LAN/big "
        f"(co-fastest: {lan_big['co_fastest']})")
    lan_penalty = (lan_big["round_s"]["grpc"]
                   / lan_big["round_s"]["mpi_mem_buff"])
    assert lan_penalty >= 2.0, (
        f"fig10: LAN gRPC penalty only {lan_penalty:.2f}x over "
        f"mpi_mem_buff (expected >= 2x)")
    # 3) AUTO never slower than the worst fixed backend, in *every* cell
    for e in report["decision"] + report["compressed"]:
        worst = e["round_s"][e["worst_fixed"]]
        auto = e["round_s"]["auto"]
        assert auto <= worst * (1 + 1e-6), (
            f"fig10: AUTO ({auto:.2f}s) slower than the worst fixed "
            f"backend {e['worst_fixed']} ({worst:.2f}s) for "
            f"{e['tier']}/{e['environment']}/{e['wire_codec']}")
    # 4) the §VII guideline lands within 5% of the measured optimum
    for e in report["decision"]:
        assert e["recommended_over_best"] <= GUIDELINE_BAND, (
            f"fig10: guideline pick {e['recommended']} is "
            f"{e['recommended_over_best']:.3f}x the optimum for "
            f"{e['tier']}/{e['environment']} (band {GUIDELINE_BAND})")
    if verbose:
        extra = (f", large {large_speedup:.2f}x (paper 3.5-3.8x)"
                 if large_speedup else "")
        print(f"[fig10] validation: grpc+s3 fastest big/geo "
              f"({s3_speedup:.2f}x over grpc{extra}); MPI co-fastest on "
              f"LAN (grpc pays {lan_penalty:.2f}x); AUTO never worse "
              f"than the worst fixed backend; guideline within "
              f"{GUIDELINE_BAND}x of optimum everywhere")
    return {"s3_speedup_big_geo": s3_speedup,
            "s3_speedup_large_geo": large_speedup,
            "lan_grpc_penalty": lan_penalty,
            "mpi_co_fastest_lan": mpi_winners,
            "auto_never_worst": True,
            "guideline_within_band": True}


STUDY = Study(
    name="fig10", title="Fig 10: §VII decision-guideline study",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig10_decision_guide.json", order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
