"""Shared helpers for the paper-figure benchmarks.

Every benchmark deployment is described by a ``repro.scenario.Scenario``
and built through its runtime — the same single path ``fl_train
--scenario`` takes — so a figure cell is literally an enumeration of
scenario specs. Since the sweep refactor those enumerations are
declarative ``repro.sweep.Sweep``s executed by the shared ``ENGINE``
below (fingerprinted cells, resumable run store under
``benchmarks/out/runstore/``); each fig module is a ``Study``
declaration, discovered by ``benchmarks/registry.py``."""
from __future__ import annotations

import os
import time

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.scenario import (ChannelSpec, FaultSpec, Scenario, StrategySpec,
                            TopologySpec, build_runtime)
from repro.sweep import Engine

ENVS = ["lan", "geo_proximal", "geo_distributed"]
BACKENDS = ["mpi_generic", "mpi_mem_buff", "grpc", "torch_rpc", "grpc+s3"]

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

# the one engine every paper study runs through (benchmarks/out is both
# the report dir and the run-store root)
ENGINE = Engine(OUT_DIR)


def scenario_for(env_name: str, *, backend: str = "grpc",
                 num_clients: int = 7, compression: str = "none",
                 wire_codec: str = "none", chunk_mb: float = 0.0,
                 link_loss: float = 0.0, fail_rate: float = 0.0,
                 mode: str = "sync", seed: int = 0,
                 name: str = "") -> Scenario:
    """One benchmark cell as a declarative scenario."""
    return Scenario(
        name=name or f"bench:{env_name}:{backend}", seed=seed,
        topology=TopologySpec.preset(env_name, num_clients=num_clients),
        channel=ChannelSpec(backend=backend, compression=compression,
                            wire_codec=wire_codec, chunk_mb=chunk_mb),
        faults=FaultSpec(link_loss=link_loss, store_fail_rate=fail_rate),
        strategy=StrategySpec(mode=mode))


def deployment(env_name: str, fail_rate: float = 0.0,
               num_clients: int = 7):
    """Build the named preset scenario's runtime; returns the classic
    (env, fabric, store) triple the figure modules consume."""
    rt = build_runtime(scenario_for(env_name, fail_rate=fail_rate,
                                    num_clients=num_clients))
    return rt.env, rt.fabric, rt.store


def backends_for(env_name: str):
    """Paper policy: grpc+s3 omitted on LAN (S3 latency would dominate)."""
    if env_name == "lan":
        return [b for b in BACKENDS if b != "grpc+s3"]
    return BACKENDS


def fmt_s(x: float) -> str:
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
