"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import Fabric, ObjectStore, make_backend, make_env
from repro.core.netsim import NCAL

ENVS = ["lan", "geo_proximal", "geo_distributed"]
BACKENDS = ["mpi_generic", "mpi_mem_buff", "grpc", "torch_rpc", "grpc+s3"]


def deployment(env_name: str, fail_rate: float = 0.0):
    env = make_env(env_name)
    fabric = Fabric(env)
    store = ObjectStore(NCAL, fail_rate=fail_rate)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return env, fabric, store


def backends_for(env_name: str):
    """Paper policy: grpc+s3 omitted on LAN (S3 latency would dominate)."""
    if env_name == "lan":
        return [b for b in BACKENDS if b != "grpc+s3"]
    return BACKENDS


def fmt_s(x: float) -> str:
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
