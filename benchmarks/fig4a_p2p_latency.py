"""Fig 4a: CPU-to-CPU p2p latency across backends, environments, tiers.
Geo-distributed is split into CA-VA (intra-continent) and CA-HK
(inter-continent), as in the paper."""
from __future__ import annotations

from benchmarks.common import ENGINE, backends_for, fmt_s, scenario_for
from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep

BENCH_ORDER = 30

# (env label, env name, destination host)
SCENARIOS = [("LAN", "lan", "client0"),
             ("GeoProx", "geo_proximal", "client0"),
             ("CA-VA", "geo_distributed", "client2"),
             ("CA-HK", "geo_distributed", "client3")]


def _sweeps(quick):
    return tuple(
        Sweep(name=f"fig4a:{label}",
              base=scenario_for(env_name, name=f"fig4a:{label}"),
              axes=(Axis("fleet.tier", values=tuple(TIER_ORDER)),
                    Axis("channel.backend",
                         values=tuple(backends_for(env_name)))),
              params={"label": label, "dst": dst})
        for label, env_name, dst in SCENARIOS)


def _cell(cell):
    rt = build_runtime(cell.scenario)
    be = rt.make_backend("server")
    tier = TIERS[cell.scenario.fleet.tier]
    return {"latency_s": be.p2p_time(tier.payload_bytes,
                                     cell.params["dst"])}


def _name(cell):
    return (f"fig4a/{cell.params['label']}/{cell.scenario.fleet.tier}/"
            f"{cell.scenario.channel.backend}")


def _finalize(results, quick, verbose):
    rows = [r.row() for r in results]
    if verbose:
        print("\n== Fig 4a: p2p latency (one message, server -> client) ==")
        by = {r.cell: r.metrics["latency_s"] for r in results}
        for label, env_name, _dst in SCENARIOS:
            names = backends_for(env_name)
            print(f"-- {label}")
            print("  " + f"{'tier':8s}" + "".join(f"{b:>14s}"
                                                  for b in names))
            for tier_name in TIER_ORDER:
                vals = [by[f"fig4a/{label}/{tier_name}/{b}"] for b in names]
                print(f"  {tier_name:8s}" + "".join(f"{fmt_s(v):>14s}"
                                                    for v in vals))
    _validate(rows)
    return None, rows


def _validate(rows):
    d = {r["name"]: r["latency_s"] for r in rows}
    # paper §V: LAN/GeoProx — buffer backends best (serialization dominates)
    assert d["fig4a/LAN/large/mpi_mem_buff"] < d["fig4a/LAN/large/grpc"]
    assert d["fig4a/LAN/large/mpi_mem_buff"] < d["fig4a/LAN/large/mpi_generic"]
    # paper §V: geo-distributed — multi-connection backends dominate
    assert d["fig4a/CA-HK/large/torch_rpc"] < d["fig4a/CA-HK/large/grpc"]
    assert d["fig4a/CA-HK/large/grpc+s3"] < d["fig4a/CA-HK/large/grpc"]
    # gRPC degrades with size over WAN
    assert (d["fig4a/CA-HK/large/grpc"] / d["fig4a/CA-HK/small/grpc"]) > 50


STUDY = Study(
    name="fig4a", title="Fig 4a: p2p latency across backends/envs/tiers",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
