"""Fig 4a: CPU-to-CPU p2p latency across backends, environments, tiers.
Geo-distributed is split into CA-VA (intra-continent) and CA-HK
(inter-continent), as in the paper."""
from __future__ import annotations

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import make_backend
from benchmarks.common import backends_for, deployment, fmt_s

# (env label, env name, destination host)
SCENARIOS = [("LAN", "lan", "client0"),
             ("GeoProx", "geo_proximal", "client0"),
             ("CA-VA", "geo_distributed", "client2"),
             ("CA-HK", "geo_distributed", "client3")]


def run(verbose=True):
    rows = []
    if verbose:
        print("\n== Fig 4a: p2p latency (one message, server -> client) ==")
    for label, env_name, dst in SCENARIOS:
        env, fabric, store = deployment(env_name)
        names = backends_for(env_name)
        if verbose:
            print(f"-- {label}")
            print("  " + f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names))
        for tier_name in TIER_ORDER:
            tier = TIERS[tier_name]
            vals = []
            for b in names:
                be = make_backend(b, env, fabric, "server", store=store)
                t = be.p2p_time(tier.payload_bytes, dst)
                vals.append(t)
                rows.append({"name": f"fig4a/{label}/{tier_name}/{b}",
                             "latency_s": t})
            if verbose:
                print(f"  {tier_name:8s}" + "".join(f"{fmt_s(v):>14s}"
                                                    for v in vals))
    _validate(rows)
    return rows


def _validate(rows):
    d = {r["name"]: r["latency_s"] for r in rows}
    # paper §V: LAN/GeoProx — buffer backends best (serialization dominates)
    assert d["fig4a/LAN/large/mpi_mem_buff"] < d["fig4a/LAN/large/grpc"]
    assert d["fig4a/LAN/large/mpi_mem_buff"] < d["fig4a/LAN/large/mpi_generic"]
    # paper §V: geo-distributed — multi-connection backends dominate
    assert d["fig4a/CA-HK/large/torch_rpc"] < d["fig4a/CA-HK/large/grpc"]
    assert d["fig4a/CA-HK/large/grpc+s3"] < d["fig4a/CA-HK/large/grpc"]
    # gRPC degrades with size over WAN
    assert (d["fig4a/CA-HK/large/grpc"] / d["fig4a/CA-HK/small/grpc"]) > 50


if __name__ == "__main__":
    run()
