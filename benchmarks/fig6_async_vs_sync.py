"""Fig 6 (beyond the paper): sync vs event-driven FL round throughput.

The paper's Fig 5 assumes lockstep rounds. This benchmark runs the same
deployments through the event-driven scheduler (fl/scheduler.py) and
compares, per backend x environment x mode:

* round throughput   — server aggregations per simulated hour;
* update throughput  — client updates incorporated per simulated hour;
* time-to-target     — simulated seconds until ``3 x n_clients``
  staleness-weighted (effective) client updates have been merged.

Modes: ``sync`` (FLServer.run_round), ``fedbuff`` (buffered async,
K = n/2, staleness discount 0.5), ``semisync`` (quorum 0.75 + deadline,
late arrivals folded into the next round), ``hier`` (per-region relay
aggregators: LAN-local reduce + one multi-connection WAN hop per region).

Deployments use 14 clients (2 per paper region on the WAN — the
multi-silo regime where topology starts to matter) with tier-calibrated
simulated local training and tier-sized virtual payloads, so the runs are
deterministic and CI-fast. Emits a JSON report
(``benchmarks/out/fig6_async_vs_sync.json``) and validates the headline
claim: async and hierarchical modes beat sync round throughput on the WAN
for at least one backend.
"""
from __future__ import annotations

import json
import math
import os

from benchmarks.common import scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import VirtualPayload
from repro.fl.async_strategies import (FedBuffStrategy, HierarchicalStrategy,
                                       SemiSyncStrategy)
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import build_runtime

N_CLIENTS = 14
OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "fig6_async_vs_sync.json")


def _make_deployment(backend_name, env_name, tier):
    rt = build_runtime(scenario_for(env_name, backend=backend_name,
                                    num_clients=N_CLIENTS,
                                    name=f"fig6:{env_name}:{backend_name}"))
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s(env_name))
               for h in rt.env.clients]
    return rt.make_backend("server"), clients


def _metrics(n_agg, n_updates, eff, span, target, time_to_target):
    span = max(span, 1e-9)
    return {
        "aggregations_per_hour": 3600.0 * n_agg / span,
        "updates_per_hour": 3600.0 * n_updates / span,
        "time_to_target_s": time_to_target,
        "sim_time_s": span,
        "n_aggregations": n_agg,
        "effective_updates": eff,
    }


def _run_sync(backend_name, env_name, tier, rounds, target):
    sb, clients = _make_deployment(backend_name, env_name, tier)
    server = FLServer(sb, clients, local_steps=1, live=False)
    t_target = None
    for r in range(rounds):
        # fresh payload per round: each merged model is a new object
        rep = server.run_round(VirtualPayload(tier.payload_bytes,
                                              tag=f"fig6-r{r}"))
        if t_target is None and (r + 1) * rep.n_participants >= target:
            t_target = server.now
    m = _metrics(rounds, rounds * N_CLIENTS, float(rounds * N_CLIENTS),
                 server.now, target, t_target)
    m["mean_staleness"] = 0.0
    return m


def _run_mode(mode, backend_name, env_name, tier, max_agg, target):
    sb, clients = _make_deployment(backend_name, env_name, tier)
    knobs = tier.async_knobs(env_name, N_CLIENTS)
    if mode == "fedbuff":
        strategy = FedBuffStrategy(
            buffer_k=knobs["buffer_k"],
            staleness_exponent=knobs["staleness_exponent"])
    elif mode == "semisync":
        strategy = SemiSyncStrategy(quorum_fraction=0.75,
                                    round_deadline_s=knobs["round_deadline_s"],
                                    staleness_exponent=0.25)
    elif mode == "hier":
        strategy = HierarchicalStrategy()
    else:
        raise KeyError(mode)
    sched = FLScheduler(sb, clients, strategy, local_steps=1)
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig6"),
                    max_aggregations=max_agg,
                    target_effective_updates=float(target))
    m = _metrics(rep.n_aggregations, rep.n_client_updates,
                 rep.effective_updates, rep.sim_time, target,
                 rep.time_to_target)
    m["mean_staleness"] = rep.mean_staleness
    return m


def run(verbose=True, quick=False):
    tiers = ["big"] if quick else ["big", "large"]
    cells = {
        "geo_distributed": ["grpc", "grpc+s3"] if quick
        else ["grpc", "torch_rpc", "grpc+s3"],
        "lan": ["grpc"] if quick else ["grpc", "torch_rpc"],
    }
    sync_rounds = 3 if quick else 5
    modes = ["sync", "fedbuff", "semisync", "hier"]
    target = 3 * N_CLIENTS
    # async modes need headroom: enough merges to pass the target even
    # with staleness discounts (fedbuff merges K=n/2 updates at a time)
    max_agg = 4 * sync_rounds

    rows, report = [], {"n_clients": N_CLIENTS, "target_effective_updates":
                        target, "cells": []}
    for env_name, backends in cells.items():
        for tier_name in tiers:
            tier = TIERS[tier_name]
            for backend_name in backends:
                cell = {"environment": env_name, "tier": tier_name,
                        "backend": backend_name, "modes": {}}
                for mode in modes:
                    if mode == "sync":
                        m = _run_sync(backend_name, env_name, tier,
                                      sync_rounds, target)
                    else:
                        m = _run_mode(mode, backend_name, env_name, tier,
                                      max_agg, target)
                    cell["modes"][mode] = m
                    rows.append({
                        "name": f"fig6/{env_name}/{tier_name}/"
                                f"{backend_name}/{mode}",
                        "round_s": 3600.0 / max(
                            m["aggregations_per_hour"], 1e-9),
                        "agg_per_h": m["aggregations_per_hour"],
                        "updates_per_h": m["updates_per_hour"],
                        "time_to_target_s": m["time_to_target_s"] or -1.0,
                        "mean_staleness": m["mean_staleness"],
                    })
                report["cells"].append(cell)
                if verbose:
                    parts = "  ".join(
                        f"{mo}={cell['modes'][mo]['aggregations_per_hour']:8.1f}/h"
                        for mo in modes)
                    print(f"[fig6] {env_name:16s} {tier_name:6s} "
                          f"{backend_name:9s}  {parts}")

    report["validation"] = _validate(report, verbose)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    if verbose:
        print(f"[fig6] JSON report -> {OUT_PATH}")
    return rows


def _validate(report, verbose):
    """Headline claim: on the WAN, async (fedbuff) and hierarchical modes
    both beat sync round throughput for at least one backend."""
    async_wins, hier_wins = [], []
    for cell in report["cells"]:
        if cell["environment"] != "geo_distributed":
            continue
        key = f"{cell['backend']}/{cell['tier']}"
        sync = cell["modes"]["sync"]["aggregations_per_hour"]
        if cell["modes"]["fedbuff"]["aggregations_per_hour"] > sync:
            async_wins.append(key)
        if cell["modes"]["hier"]["aggregations_per_hour"] > sync:
            hier_wins.append(key)
    both = sorted(set(async_wins) & set(hier_wins))
    assert both, (
        f"fig6: no WAN backend where async AND hier beat sync round "
        f"throughput (async wins: {async_wins}, hier wins: {hier_wins})")
    if verbose:
        print(f"[fig6] validation: async+hier beat sync on WAN for {both} "
              f"(async wins: {async_wins}; hier wins: {hier_wins})")
    return {"async_beats_sync_wan": sorted(async_wins),
            "hier_beats_sync_wan": sorted(hier_wins),
            "both_beat_sync_wan": both}


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
