"""Fig 6 (beyond the paper): sync vs event-driven FL round throughput.

The paper's Fig 5 assumes lockstep rounds. This benchmark runs the same
deployments through the event-driven scheduler (fl/scheduler.py) and
compares, per backend x environment x mode:

* round throughput   — server aggregations per simulated hour;
* update throughput  — client updates incorporated per simulated hour;
* time-to-target     — simulated seconds until ``3 x n_clients``
  staleness-weighted (effective) client updates have been merged.

Modes: ``sync`` (FLServer.run_round), ``fedbuff`` (buffered async,
K = n/2, staleness discount 0.5), ``semisync`` (quorum 0.75 + deadline,
late arrivals folded into the next round), ``hier`` (per-region relay
aggregators: LAN-local reduce + one multi-connection WAN hop per region).

Deployments use 14 clients (2 per paper region on the WAN — the
multi-silo regime where topology starts to matter) with tier-calibrated
simulated local training and tier-sized virtual payloads, so the runs are
deterministic and CI-fast. The cell grid is one declarative Sweep per
environment; the engine writes the JSON report
(``benchmarks/out/fig6_async_vs_sync.json``) and the validation asserts
the headline claim: async and hierarchical modes beat sync round
throughput on the WAN for at least one backend.
"""
from __future__ import annotations

from benchmarks.common import ENGINE, scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import VirtualPayload
from repro.fl.async_strategies import (FedBuffStrategy, HierarchicalStrategy,
                                       SemiSyncStrategy)
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep, wire_stats

BENCH_ORDER = 50
N_CLIENTS = 14
MODES = ("sync", "fedbuff", "semisync", "hier")


def _sweeps(quick):
    cells = {
        "geo_distributed": ("grpc", "grpc+s3") if quick
        else ("grpc", "torch_rpc", "grpc+s3"),
        "lan": ("grpc",) if quick else ("grpc", "torch_rpc"),
    }
    tiers = ("big",) if quick else ("big", "large")
    sync_rounds = 3 if quick else 5
    target = 3 * N_CLIENTS
    return tuple(
        Sweep(name=f"fig6:{env_name}",
              base=scenario_for(env_name, num_clients=N_CLIENTS,
                                name=f"fig6:{env_name}"),
              axes=(Axis("fleet.tier", values=tiers),
                    Axis("channel.backend", values=backends),
                    Axis("strategy.mode", values=MODES)),
              # async modes need headroom: enough merges to pass the
              # target even with staleness discounts (fedbuff merges
              # K=n/2 updates at a time)
              params={"sync_rounds": sync_rounds,
                      "max_agg": 4 * sync_rounds, "target": target})
        for env_name, backends in cells.items())


def _deployment(cell):
    rt = build_runtime(cell.scenario)
    tier = TIERS[cell.scenario.fleet.tier]
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s(
                            cell.scenario.topology.kind))
               for h in rt.env.clients]
    return rt, rt.make_backend("server"), clients


def _metrics(n_agg, n_updates, eff, span, time_to_target):
    span = max(span, 1e-9)
    return {
        "aggregations_per_hour": 3600.0 * n_agg / span,
        "updates_per_hour": 3600.0 * n_updates / span,
        "time_to_target_s": time_to_target,
        "sim_time_s": span,
        "n_aggregations": n_agg,
        "effective_updates": eff,
    }


def _cell(cell):
    tier = TIERS[cell.scenario.fleet.tier]
    mode = cell.scenario.strategy.mode
    env_name = cell.scenario.topology.kind
    target = cell.params["target"]
    rt, sb, clients = _deployment(cell)
    if mode == "sync":
        rounds = cell.params["sync_rounds"]
        server = FLServer(sb, clients, local_steps=1, live=False)
        t_target = None
        for r in range(rounds):
            # fresh payload per round: each merged model is a new object
            rep = server.run_round(VirtualPayload(tier.payload_bytes,
                                                  tag=f"fig6-r{r}"))
            if t_target is None and (r + 1) * rep.n_participants >= target:
                t_target = server.now
        m = _metrics(rounds, rounds * N_CLIENTS,
                     float(rounds * N_CLIENTS), server.now, t_target)
        m["mean_staleness"] = 0.0
        return {**m, "n_rounds": rounds, **wire_stats(rt.fabric, rt.store)}
    knobs = tier.async_knobs(env_name, N_CLIENTS)
    if mode == "fedbuff":
        strategy = FedBuffStrategy(
            buffer_k=knobs["buffer_k"],
            staleness_exponent=knobs["staleness_exponent"])
    elif mode == "semisync":
        strategy = SemiSyncStrategy(quorum_fraction=0.75,
                                    round_deadline_s=knobs["round_deadline_s"],
                                    staleness_exponent=0.25)
    elif mode == "hier":
        strategy = HierarchicalStrategy()
    else:
        raise KeyError(mode)
    sched = FLScheduler(sb, clients, strategy, local_steps=1)
    rep = sched.run(VirtualPayload(tier.payload_bytes, tag="fig6"),
                    max_aggregations=cell.params["max_agg"],
                    target_effective_updates=float(target))
    m = _metrics(rep.n_aggregations, rep.n_client_updates,
                 rep.effective_updates, rep.sim_time, rep.time_to_target)
    m["mean_staleness"] = rep.mean_staleness
    return {**m, "n_rounds": rep.n_aggregations,
            **wire_stats(rt.fabric, rt.store)}


def _name(cell):
    return (f"fig6/{cell.scenario.topology.kind}/"
            f"{cell.scenario.fleet.tier}/{cell.scenario.channel.backend}/"
            f"{cell.scenario.strategy.mode}")


_MODE_KEYS = ("aggregations_per_hour", "updates_per_hour",
              "time_to_target_s", "sim_time_s", "n_aggregations",
              "effective_updates", "mean_staleness")


def _finalize(results, quick, verbose):
    target = results[0].params["target"] if results else 3 * N_CLIENTS
    report = {"n_clients": N_CLIENTS, "target_effective_updates": target,
              "cells": []}
    rows, groups = [], {}
    for r in results:
        _, env, tier, backend, mode = r.cell.split("/")
        key = (env, tier, backend)
        if key not in groups:
            groups[key] = {"environment": env, "tier": tier,
                           "backend": backend, "modes": {}}
            report["cells"].append(groups[key])
        m = {k: r.get(k) for k in _MODE_KEYS}
        groups[key]["modes"][mode] = m
        rows.append({
            "name": r.cell,
            "round_s": 3600.0 / max(m["aggregations_per_hour"], 1e-9),
            "agg_per_h": m["aggregations_per_hour"],
            "updates_per_h": m["updates_per_hour"],
            "time_to_target_s": m["time_to_target_s"] or -1.0,
            "mean_staleness": m["mean_staleness"],
        })
    if verbose:
        for cell in report["cells"]:
            parts = "  ".join(
                f"{mo}={cell['modes'][mo]['aggregations_per_hour']:8.1f}/h"
                for mo in MODES)
            print(f"[fig6] {cell['environment']:16s} {cell['tier']:6s} "
                  f"{cell['backend']:9s}  {parts}")
    report["validation"] = _validate(report, verbose)
    return report, rows


def _validate(report, verbose):
    """Headline claim: on the WAN, async (fedbuff) and hierarchical modes
    both beat sync round throughput for at least one backend."""
    async_wins, hier_wins = [], []
    for cell in report["cells"]:
        if cell["environment"] != "geo_distributed":
            continue
        key = f"{cell['backend']}/{cell['tier']}"
        sync = cell["modes"]["sync"]["aggregations_per_hour"]
        if cell["modes"]["fedbuff"]["aggregations_per_hour"] > sync:
            async_wins.append(key)
        if cell["modes"]["hier"]["aggregations_per_hour"] > sync:
            hier_wins.append(key)
    both = sorted(set(async_wins) & set(hier_wins))
    assert both, (
        f"fig6: no WAN backend where async AND hier beat sync round "
        f"throughput (async wins: {async_wins}, hier wins: {hier_wins})")
    if verbose:
        print(f"[fig6] validation: async+hier beat sync on WAN for {both} "
              f"(async wins: {async_wins}; hier wins: {hier_wins})")
    return {"async_beats_sync_wan": sorted(async_wins),
            "hier_beats_sync_wan": sorted(hier_wins),
            "both_beat_sync_wan": both}


STUDY = Study(
    name="fig6", title="Fig 6: sync vs event-driven FL round throughput",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig6_async_vs_sync.json", order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
