"""Fig 4c: peak sender memory during a concurrent broadcast to 7 clients."""
from __future__ import annotations

from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import FLMessage, VirtualPayload, make_backend
from repro.core.netsim import MB
from benchmarks.common import backends_for, deployment


def run(verbose=True):
    rows = []
    env_name = "geo_distributed"
    names = backends_for(env_name)
    if verbose:
        print("\n== Fig 4c: peak sender memory, concurrent broadcast to 7 "
              "clients (MB) ==")
        print(f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names))
    for tier_name in TIER_ORDER:
        tier = TIERS[tier_name]
        vals = []
        for b in names:
            env, fabric, store = deployment(env_name)
            be = make_backend(b, env, fabric, "server", store=store)
            msgs = [FLMessage("m", "server", c.host_id,
                              payload=VirtualPayload(tier.payload_bytes))
                    for c in env.clients]
            be.broadcast(msgs, 0.0)
            peak = be.endpoint.memory.peak / MB
            vals.append(peak)
            rows.append({"name": f"fig4c/{tier_name}/{b}", "peak_MB": peak})
        if verbose:
            print(f"{tier_name:8s}" + "".join(f"{v:>14.1f}" for v in vals))
    _validate(rows)
    return rows


def _validate(rows):
    d = {r["name"]: r["peak_MB"] for r in rows}
    large = TIERS["large"].payload_bytes / MB
    # gRPC / MPI_GENERIC: one buffered copy per receiver (~7x payload)
    assert d["fig4c/large/grpc"] > 6 * large
    assert d["fig4c/large/mpi_generic"] > 6 * large
    # buffer backends: no payload copies
    assert d["fig4c/large/mpi_mem_buff"] < 0.5 * large
    assert d["fig4c/large/torch_rpc"] < 0.5 * large
    # gRPC+S3: exactly one serialized copy, independent of receiver count
    assert d["fig4c/large/grpc+s3"] < 1.5 * large


if __name__ == "__main__":
    run()
