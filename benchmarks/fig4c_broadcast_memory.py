"""Fig 4c: peak sender memory during a concurrent broadcast to 7 clients."""
from __future__ import annotations

from benchmarks.common import ENGINE, backends_for, scenario_for
from repro.configs.paper_tiers import TIER_ORDER, TIERS
from repro.core import FLMessage, VirtualPayload
from repro.core.netsim import MB
from repro.scenario import build_runtime
from repro.sweep import Axis, Study, Sweep

BENCH_ORDER = 32
ENV = "geo_distributed"


def _sweeps(quick):
    return (Sweep(name="fig4c",
                  base=scenario_for(ENV, name="fig4c"),
                  axes=(Axis("fleet.tier", values=tuple(TIER_ORDER)),
                        Axis("channel.backend",
                             values=tuple(backends_for(ENV))))),)


def _cell(cell):
    tier = TIERS[cell.scenario.fleet.tier]
    rt = build_runtime(cell.scenario)
    be = rt.make_backend("server")
    msgs = [FLMessage("m", "server", c.host_id,
                      payload=VirtualPayload(tier.payload_bytes))
            for c in rt.env.clients]
    be.broadcast(msgs, 0.0)
    return {"peak_MB": be.endpoint.memory.peak / MB}


def _name(cell):
    return (f"fig4c/{cell.scenario.fleet.tier}/"
            f"{cell.scenario.channel.backend}")


def _finalize(results, quick, verbose):
    rows = [r.row() for r in results]
    if verbose:
        names = backends_for(ENV)
        print("\n== Fig 4c: peak sender memory, concurrent broadcast to 7 "
              "clients (MB) ==")
        print(f"{'tier':8s}" + "".join(f"{b:>14s}" for b in names))
        by = {r.cell: r.metrics["peak_MB"] for r in results}
        for tier_name in TIER_ORDER:
            vals = [by[f"fig4c/{tier_name}/{b}"] for b in names]
            print(f"{tier_name:8s}" + "".join(f"{v:>14.1f}" for v in vals))
    _validate(rows)
    return None, rows


def _validate(rows):
    d = {r["name"]: r["peak_MB"] for r in rows}
    large = TIERS["large"].payload_bytes / MB
    # gRPC / MPI_GENERIC: one buffered copy per receiver (~7x payload)
    assert d["fig4c/large/grpc"] > 6 * large
    assert d["fig4c/large/mpi_generic"] > 6 * large
    # buffer backends: no payload copies
    assert d["fig4c/large/mpi_mem_buff"] < 0.5 * large
    assert d["fig4c/large/torch_rpc"] < 0.5 * large
    # gRPC+S3: exactly one serialized copy, independent of receiver count
    assert d["fig4c/large/grpc+s3"] < 1.5 * large


STUDY = Study(
    name="fig4c", title="Fig 4c: broadcast peak sender memory",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
