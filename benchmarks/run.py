"""Benchmark harness entry: one module per paper table/figure + the
beyond-paper cross-pod study. Prints a ``name,us_per_call,derived`` CSV
after the human-readable sections."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_kernels, crosspod_sync,
                            fig2_grpc_concurrency, fig4a_p2p_latency,
                            fig4b_concurrency_speedup, fig4c_broadcast_memory,
                            fig5_end_to_end, table1_links)

    modules = [
        ("table1", table1_links),
        ("fig2", fig2_grpc_concurrency),
        ("fig4a", fig4a_p2p_latency),
        ("fig4b", fig4b_concurrency_speedup),
        ("fig4c", fig4c_broadcast_memory),
        ("fig5", fig5_end_to_end),
        ("kernels", bench_kernels),
        ("crosspod", crosspod_sync),
    ]
    all_rows = []
    failures = 0
    for name, mod in modules:
        try:
            all_rows += mod.run(verbose=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[bench] {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    print("\nname,us_per_call,derived")
    for r in all_rows:
        us = r.get("us_per_call")
        if us is None:
            for key in ("latency_s", "round_s", "per_step_ar_s"):
                if key in r:
                    us = r[key] * 1e6
                    break
        derived = r.get("derived")
        if derived is None:
            derived = ";".join(f"{k}={v:.4g}" for k, v in r.items()
                               if k not in ("name", "us_per_call", "server",
                                            "clients")
                               and isinstance(v, (int, float)))
        print(f"{r['name']},{'' if us is None else f'{us:.1f}'},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
