"""Benchmark harness entry: one module per paper table/figure + the
beyond-paper cross-pod and fig6 async studies. Prints a
``name,us_per_call,derived`` CSV after the human-readable sections.

``--quick`` (the CI smoke) skips the JAX-heavy kernel/cross-pod modules
and runs fig6 in its reduced grid; ``--only NAME [NAME...]`` selects
specific modules.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="netsim-only subset with reduced grids (CI smoke)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these modules by name")
    args = ap.parse_args(argv)

    from benchmarks import (bench_kernels, crosspod_sync,
                            fig2_grpc_concurrency, fig4a_p2p_latency,
                            fig4b_concurrency_speedup, fig4c_broadcast_memory,
                            fig5_end_to_end, fig6_async_vs_sync,
                            fig7_compression_wan, fig8_faults_wan,
                            fig9_topology_wan, table1_links)

    modules = [
        ("table1", table1_links),
        ("fig2", fig2_grpc_concurrency),
        ("fig4a", fig4a_p2p_latency),
        ("fig4b", fig4b_concurrency_speedup),
        ("fig4c", fig4c_broadcast_memory),
        ("fig5", fig5_end_to_end),
        ("fig6", fig6_async_vs_sync),
        ("fig7", fig7_compression_wan),
        ("fig8", fig8_faults_wan),
        ("fig9", fig9_topology_wan),
        ("kernels", bench_kernels),
        ("crosspod", crosspod_sync),
    ]
    if args.quick:
        modules = [(n, m) for n, m in modules
                   if n not in ("kernels", "crosspod")]
    if args.only:
        known = {n for n, _ in modules}
        unknown = [n for n in args.only if n not in known]
        if unknown:
            ap.error(f"unknown module(s) {unknown}; choose from "
                     f"{sorted(known)}")
        modules = [(n, m) for n, m in modules if n in args.only]
    all_rows = []
    failures = 0
    for name, mod in modules:
        kw = {}
        if args.quick and "quick" in inspect.signature(mod.run).parameters:
            kw["quick"] = True
        try:
            all_rows += mod.run(verbose=True, **kw)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[bench] {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    print("\nname,us_per_call,derived")
    for r in all_rows:
        us = r.get("us_per_call")
        if us is None:
            for key in ("latency_s", "round_s", "per_step_ar_s"):
                if key in r:
                    us = r[key] * 1e6
                    break
        derived = r.get("derived")
        if derived is None:
            derived = ";".join(f"{k}={v:.4g}" for k, v in r.items()
                               if k not in ("name", "us_per_call", "server",
                                            "clients")
                               and isinstance(v, (int, float)))
        print(f"{r['name']},{'' if us is None else f'{us:.1f}'},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
