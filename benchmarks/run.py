"""Benchmark harness entry: one module per paper table/figure + the
beyond-paper cross-pod and fig6-10 studies. Prints a
``name,us_per_call,derived`` CSV after the human-readable sections.

Modules are *discovered* through ``benchmarks/registry.py`` — every
module in the package must be a runnable study (a sweep ``STUDY`` or a
legacy ``run``), so a new study cannot be silently dropped from
``--quick``/``--only``. ``--quick`` (the CI smoke) skips the JAX-heavy
kernel/cross-pod modules and runs the sweep studies in their reduced
grids; ``--only NAME [NAME...]`` selects specific modules; ``--fresh``
bypasses the sweep engine's run store and re-runs every cell.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="netsim-only subset with reduced grids (CI smoke)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these modules by name")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore the sweep run store; re-run every cell")
    ap.add_argument("--workers", type=int, default=0,
                    help="run missing sweep cells on N worker processes "
                         "(bit-identical results and run store vs serial)")
    args = ap.parse_args(argv)

    from benchmarks.registry import discover
    entries = discover()
    if args.quick:
        entries = [e for e in entries if e.in_quick]
    if args.only:
        known = {e.name for e in entries}
        unknown = [n for n in args.only if n not in known]
        if unknown:
            ap.error(f"unknown module(s) {unknown}; choose from "
                     f"{sorted(known)}")
        entries = [e for e in entries if e.name in args.only]
    all_rows = []
    failures = 0
    for e in entries:
        kw = {"quick": True} if args.quick and e.accepts_quick else {}
        if args.fresh and e.accepts_fresh:
            # per-study invalidation: only the *selected* studies re-run;
            # the other studies' cached cells stay in the run store
            kw["fresh"] = True
        if args.workers and e.accepts_workers:
            kw["workers"] = args.workers
        try:
            all_rows += e.run(verbose=True, **kw)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[bench] {e.name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    print("\nname,us_per_call,derived")
    for r in all_rows:
        us = r.get("us_per_call")
        if us is None:
            for key in ("latency_s", "round_s", "per_step_ar_s"):
                if key in r:
                    us = r[key] * 1e6
                    break
        derived = r.get("derived")
        if derived is None:
            derived = ";".join(f"{k}={v:.4g}" for k, v in r.items()
                               if k not in ("name", "us_per_call", "server",
                                            "clients")
                               and isinstance(v, (int, float)))
        print(f"{r['name']},{'' if us is None else f'{us:.1f}'},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
