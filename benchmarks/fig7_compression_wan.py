"""Fig 7 (beyond the paper): gradient compression on the wire stack.

The paper cites QSGD-family compression as orthogonal to the backend
choice; the ChannelStack (core/channel.py) makes it an insertable stage.
This benchmark measures what that composition buys on the paper's own
14-client WAN grid (2 clients per Table-I region), per backend x
compression:

* ``hier``     — per-region relay aggregation with compression on the
  relay -> hub WAN hop only (the LAN reduce stays exact);
* ``fedbuff``  — buffered async with client-update compression on the
  backend channel itself (full client -> server path).

Plus a *fidelity* study with real tensors (one extra sweep cell): hier
relays with QSGD (error feedback per region) must land within
quantisation tolerance of flat synchronous FedAvg after several rounds,
with the per-region residual bounded (error feedback does not
accumulate).

The engine writes ``benchmarks/out/fig7_compression_wan.json``; the
validation asserts the headline claims: qsgd on the hier WAN hop
improves round throughput over uncompressed hier for gRPC, and
hier+qsgd == flat FedAvg within tolerance.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ENGINE, scenario_for
from repro.configs.paper_tiers import TIERS
from repro.core import TensorPayload, VirtualPayload
from repro.fl.async_strategies import FedBuffStrategy, HierarchicalStrategy
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import build_runtime, with_overrides
from repro.sweep import Axis, Study, Sweep, wire_stats

BENCH_ORDER = 60
N_CLIENTS = 14
TIER = "big"


def _sweeps(quick):
    compressions = ("none", "qsgd") if quick else ("none", "qsgd",
                                                   "topk:0.05")
    modes = ("hier",) if quick else ("hier", "fedbuff")
    base = scenario_for("geo_distributed", num_clients=N_CLIENTS,
                        name="fig7")
    return (
        Sweep(name="fig7",
              base=with_overrides(base, {"fleet.tier": TIER}),
              axes=(Axis("strategy.mode", values=modes),
                    Axis("channel.backend", values=("grpc", "grpc+s3")),
                    Axis("channel.compression", values=compressions)),
              params={"max_agg": 3 if quick else 5}),
        Sweep(name="fig7:fidelity",
              base=scenario_for("geo_distributed", backend="grpc",
                                num_clients=8, name="fig7:fidelity"),
              params={"variant": "fidelity", "rounds": 2 if quick else 3}),
    )


def _make_deployment(cell, compression=None):
    sc = with_overrides(cell.scenario,
                        {"channel.compression": compression or "none"})
    rt = build_runtime(sc)
    tier = TIERS[TIER]
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        sim_train_s=tier.train_s("geo_distributed"))
               for h in rt.env.clients]
    return rt, rt.make_backend("server", compression="none"), clients


def _cell(cell):
    if cell.params.get("variant") == "fidelity":
        err, tol, upd, residuals = _fidelity(cell.params["rounds"])
        return {"max_abs_err": err, "tolerance": tol,
                "max_abs_update": upd, "ef_residual_inf_norms": residuals}
    mode = cell.scenario.strategy.mode
    comp = cell.scenario.channel.compression
    spec = None if comp == "none" else comp
    if mode == "hier":
        # compression rides the relay WAN hop inside the strategy
        rt, sb, clients = _make_deployment(cell)
        strategy = HierarchicalStrategy(wan_compression=spec)
    else:  # fedbuff: the client backends' channels compress the updates
        rt, sb, clients = _make_deployment(cell, compression=spec)
        strategy = FedBuffStrategy(buffer_k=max(2, N_CLIENTS // 2),
                                   staleness_exponent=0.5)
    sched = FLScheduler(sb, clients, strategy, local_steps=1)
    rep = sched.run(VirtualPayload(TIERS[TIER].payload_bytes, tag="fig7"),
                    max_aggregations=cell.params["max_agg"])
    return {"aggregations_per_hour": rep.aggregations_per_hour,
            "updates_per_hour": rep.client_updates_per_hour,
            "sim_time_s": rep.sim_time,
            "n_aggregations": rep.n_aggregations,
            "n_rounds": rep.n_aggregations,
            **wire_stats(rt.fabric, rt.store)}


def _name(cell):
    if cell.params.get("variant") == "fidelity":
        return "fig7/fidelity/hier_qsgd_vs_flat"
    return (f"fig7/{cell.scenario.strategy.mode}/"
            f"{cell.scenario.channel.backend}/"
            f"{cell.scenario.channel.compression}")


# ---------------------------------------------------------------------------
# fidelity: hier + qsgd (error feedback) vs flat synchronous FedAvg
# ---------------------------------------------------------------------------

N_FEATURES = 8 * 8 * 3
N_CLASSES = 4


def _linear_train_fn():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def train_fn(params, batch):
        def loss_fn(p):
            x = batch["images"].reshape(batch["images"].shape[0], -1)
            logits = x @ p["w"] + p["b"]
            onehot = jax.nn.one_hot(batch["labels"], N_CLASSES)
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot,
                                     axis=-1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        return jax.tree.map(lambda p, g: p - 0.1 * g, params, grads), loss
    return train_fn


def _live_deployment(n):
    from repro.data import make_silo_datasets
    rt = build_runtime(scenario_for("geo_distributed", backend="grpc",
                                    num_clients=n, name="fig7:fidelity"))
    silos = make_silo_datasets(n, kind="image", examples_per_silo=24,
                               num_classes=N_CLASSES, image_size=8, seed=0)
    clients = [FLClient(h.host_id, rt.make_backend(h.host_id),
                        dataset=silos[i], train_fn=_linear_train_fn(),
                        batch_size=8, sim_train_s=5.0, seed=i)
               for i, h in enumerate(rt.env.clients)]
    return rt.make_backend("server"), clients


def _init_params():
    import jax.numpy as jnp
    return {"w": jnp.zeros((N_FEATURES, N_CLASSES), jnp.float32),
            "b": jnp.zeros((N_CLASSES,), jnp.float32)}


def _fidelity(rounds):
    """Returns (max |hier_qsgd - flat|, quantisation tolerance, residual
    inf-norms per round-ish probe)."""
    n = 8
    sb, clients = _live_deployment(n)
    server = FLServer(sb, clients, local_steps=2)
    params = _init_params()
    for _ in range(rounds):
        server.run_round(TensorPayload(params))
        params = server.global_params
    flat_params = params

    sb2, clients2 = _live_deployment(n)
    strat = HierarchicalStrategy(staleness_exponent=0.0,
                                 wan_compression="qsgd")
    sched = FLScheduler(sb2, clients2, strat, local_steps=2)
    sched.run(TensorPayload(_init_params()), max_aggregations=rounds)

    err = max(float(np.max(np.abs(np.asarray(sched.global_params[k])
                                  - np.asarray(flat_params[k]))))
              for k in flat_params)
    # per-element quantisation step <= max|block| / 127; the relay
    # partials are O(update magnitude), so tolerate a few steps of the
    # largest update coordinate (error feedback keeps multi-round drift
    # in this band instead of accumulating rounds * step)
    init = _init_params()
    upd = max(float(np.max(np.abs(np.asarray(flat_params[k])
                                  - np.asarray(init[k]))))
              for k in flat_params)
    tol = max(8.0 * upd / 127.0, 1e-4)
    residuals = [float(np.max(np.abs(np.asarray(s.error))))
                 for s in strat.wan_ef_states()]
    return err, tol, upd, residuals


def _finalize(results, quick, verbose):
    compressions = ["none", "qsgd"] if quick else ["none", "qsgd",
                                                   "topk:0.05"]
    report = {"n_clients": N_CLIENTS, "tier": TIER, "cells": []}
    rows, groups = [], {}
    fid = None
    for r in results:
        if r.params.get("variant") == "fidelity":
            fid = r
            continue
        _, mode, backend, comp = r.cell.split("/")
        key = (mode, backend)
        if key not in groups:
            groups[key] = {"mode": mode, "backend": backend,
                           "compressions": {}}
            report["cells"].append(groups[key])
        m = {"aggregations_per_hour": r.get("aggregations_per_hour"),
             "updates_per_hour": r.get("updates_per_hour"),
             "sim_time_s": r.sim_time_s,
             "n_aggregations": r.get("n_aggregations")}
        groups[key]["compressions"][comp] = m
        rows.append({
            "name": r.cell,
            "round_s": 3600.0 / max(m["aggregations_per_hour"], 1e-9),
            "agg_per_h": m["aggregations_per_hour"],
            "updates_per_h": m["updates_per_hour"],
        })
    if verbose:
        for cell in report["cells"]:
            parts = "  ".join(
                f"{c}={cell['compressions'][c]['aggregations_per_hour']:8.1f}/h"
                for c in compressions)
            print(f"[fig7] {cell['mode']:8s} {cell['backend']:9s}  {parts}")

    report["fidelity"] = {
        "max_abs_err": fid.metrics["max_abs_err"],
        "tolerance": fid.metrics["tolerance"],
        "max_abs_update": fid.metrics["max_abs_update"],
        "ef_residual_inf_norms": fid.metrics["ef_residual_inf_norms"]}
    rows.append({"name": "fig7/fidelity/hier_qsgd_vs_flat",
                 "max_abs_err": fid.metrics["max_abs_err"],
                 "tolerance": fid.metrics["tolerance"]})
    if verbose:
        f = report["fidelity"]
        print(f"[fig7] fidelity: max|hier+qsgd - flat fedavg| = "
              f"{f['max_abs_err']:.2e} (tol {f['tolerance']:.2e}); "
              f"EF residual inf-norms "
              f"{['%.2e' % r for r in f['ef_residual_inf_norms']]}")
    report["validation"] = _validate(report, verbose)
    return report, rows


def _validate(report, verbose):
    """Headline claims: (1) qsgd on the hier relay WAN hop beats
    uncompressed hier round throughput for gRPC; (2) hier+qsgd matches
    flat FedAvg within quantisation tolerance, with the error-feedback
    residual bounded by the same band (not accumulating)."""
    wins = []
    for cell in report["cells"]:
        if cell["mode"] != "hier":
            continue
        base = cell["compressions"]["none"]["aggregations_per_hour"]
        comp = cell["compressions"]["qsgd"]["aggregations_per_hour"]
        if comp > base:
            wins.append(cell["backend"])
    assert "grpc" in wins, (
        f"fig7: qsgd on the hier WAN hop did not improve gRPC round "
        f"throughput (wins: {wins})")
    fid = report["fidelity"]
    assert fid["max_abs_err"] <= fid["tolerance"], (
        f"fig7: hier+qsgd drifted {fid['max_abs_err']:.3e} from flat "
        f"FedAvg (tolerance {fid['tolerance']:.3e})")
    assert all(r <= fid["tolerance"] for r in
               fid["ef_residual_inf_norms"]), (
        f"fig7: error-feedback residual unbounded: "
        f"{fid['ef_residual_inf_norms']} > {fid['tolerance']:.3e}")
    if verbose:
        print(f"[fig7] validation: hier qsgd > none for {wins}; "
              f"fidelity within tolerance")
    return {"hier_qsgd_beats_none": sorted(wins),
            "fidelity_within_tolerance": True}


STUDY = Study(
    name="fig7", title="Fig 7: wire-stack compression on the WAN",
    sweeps=_sweeps, cell=_cell, cell_name=_name, finalize=_finalize,
    out="fig7_compression_wan.json", order=BENCH_ORDER)

run = ENGINE.runner(STUDY)

if __name__ == "__main__":
    ENGINE.main(STUDY)
