"""Quickstart: build an assigned arch (reduced), train it on synthetic LM
data until loss drops, then decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_ORDER, smoke_config
from repro.configs.base import SMOKE_MESH, ShapeConfig, TrainConfig
from repro.data import lm_batch_iterator
from repro.launch.mesh import make_smoke_mesh
from repro.launch.step_builders import make_train_step
from repro.optim.optimizers import adamw_init


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-8b"
    assert arch in ARCH_ORDER, f"pick one of {ARCH_ORDER}"
    cfg = smoke_config(arch)
    print(f"[quickstart] arch={arch} (reduced: {cfg.num_layers} layers, "
          f"d={cfg.d_model})")

    shape = ShapeConfig(name="qs", seq_len=64, global_batch=8, kind="train")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40)
    mesh = make_smoke_mesh()
    bundle = make_train_step(cfg, shape, mesh, SMOKE_MESH, tcfg)
    model = bundle.model

    params, _ = model.init(jax.random.key(0))
    opt = adamw_init(params, tcfg)
    step_fn = jax.jit(bundle.fn)
    data = lm_batch_iterator(0, 8, 64, cfg.vocab_size)

    losses = []
    with mesh:
        for step in range(40):
            raw = next(data)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.external_embeddings:
                batch = {"embeds": jax.random.normal(
                    jax.random.key(step), (8, 64, cfg.d_model), jnp.bfloat16),
                    "targets": batch["targets"]}
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (8, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
            params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
            losses.append(float(m["loss"]))
            if step % 10 == 0:
                print(f"  step {step:3d}  loss {losses[-1]:.3f}")
    print(f"[quickstart] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'learned' if losses[-1] < losses[0] else 'no progress?!'})")

    if cfg.causal:
        cache = model.init_cache(2, 16)
        tok = jnp.zeros((2, 1), jnp.int32)
        out = []
        decode = jax.jit(model.decode_step)
        for pos in range(8):
            logits, cache = decode(params, cache,
                                   {"tokens": tok, "pos": jnp.int32(pos)})
            lg = logits[:, -1] if logits.ndim == 3 else logits
            tok = jnp.argmax(lg, -1, keepdims=True).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        print(f"[quickstart] greedy decode: {out}")


if __name__ == "__main__":
    main()
