import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Cross-pod federated training, actually executed on a (2,2,2) mesh of
host devices: each 'pod' runs K local AdamW steps on its own data shard,
then pods exchange int8-quantised deltas (the paper's cross-silo round at
pod granularity). Loss must drop and pods must stay in sync.

    python examples/multipod_fl_train.py
"""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.data import synthetic_lm_batch
from repro.launch.step_builders import make_fl_round_step
from repro.optim.optimizers import adamw_init


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mcfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"))
    cfg = smoke_config("qwen3-8b")
    K = 4
    shape = ShapeConfig(name="fl", seq_len=32, global_batch=8, kind="train")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=64,
                       crosspod_compression="int8")
    bundle = make_fl_round_step(cfg, shape, mesh, mcfg, tcfg, local_steps=K)
    model = bundle.model

    anchor, _ = model.init(jax.random.key(0))
    n_pods = 2
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape), t)
    params = stack(anchor)
    opt = jax.vmap(lambda p: adamw_init(p, tcfg))(params)

    fl_round = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings)
    rng = np.random.default_rng(0)
    losses = []
    with mesh:
        for rnd in range(8):
            raw = synthetic_lm_batch(rng, n_pods * K * 4, 32, cfg.vocab_size)
            batches = {k: jnp.asarray(v).reshape(n_pods, K, 4, 32)
                       for k, v in raw.items()}
            params, opt, anchor, loss = fl_round(params, opt, anchor,
                                                 batches,
                                                 jnp.int32(rnd * K))
            losses.append(float(loss))
            print(f"[multipod-fl] round {rnd} (K={K} local steps/pod, int8 "
                  f"delta sync): loss={losses[-1]:.3f}")
    # pods hold identical params after sync
    leaf = jax.tree.leaves(params)[0]
    drift = float(jnp.max(jnp.abs(leaf[0].astype(jnp.float32)
                                  - leaf[1].astype(jnp.float32))))
    print(f"[multipod-fl] loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"cross-pod param drift after sync = {drift:.2e}")
    assert losses[-1] < losses[0], "no learning?"
    assert drift < 1e-3, "pods out of sync"
    print("[multipod-fl] OK")


if __name__ == "__main__":
    main()
