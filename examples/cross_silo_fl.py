"""Cross-silo FL demo — the paper end to end.

Trains the Small tier (ResNet) across 7 geo-distributed silos under THREE
backends, printing the paper's per-state breakdown, then demonstrates the
fault story: a client drops mid-round — MPI aborts, gRPC+S3 sails on and
the late client re-fetches from the object store.

    PYTHONPATH=src python examples/cross_silo_fl.py
"""
from repro.configs.base import FLConfig
from repro.core import TensorPayload
from repro.launch.fl_train import build_deployment


def train_rounds(backend, rounds=2, dropped=None):
    cfg = FLConfig(backend=backend, environment="geo_distributed",
                   quorum_fraction=0.7)
    server, params, env, store = build_deployment(cfg, local_steps=3)
    out = []
    for r in range(rounds):
        rep = server.run_round(TensorPayload(params),
                               dropped=dropped if r == 0 else None)
        if server.global_params is not None:
            params = server.global_params
        out.append(rep)
    return out, store


def main():
    print("== cross-silo FL, 7 geo-distributed silos, Small tier ==")
    for backend in ("grpc", "torch_rpc", "grpc+s3"):
        reps, store = train_rounds(backend)
        r = reps[-1]
        print(f"\n-- {backend}: round={r.round_time:.2f}s sim, "
              f"loss {reps[0].losses:.3f} -> {reps[-1].losses:.3f}, "
              f"server peak mem {r.peak_server_memory / 2 ** 20:.1f}MB")
        print(f"   client states: comm={r.clients['communication']:.2f}s "
              f"train={r.clients['training']:.2f}s "
              f"ser={r.clients['serialization']:.3f}s "
              f"wait={r.clients['waiting']:.2f}s")

    print("\n== fault tolerance: client0+client1 drop mid-round ==")
    reps, _ = train_rounds("mpi_generic", rounds=1,
                           dropped={"client0", "client1"})
    print(f"   mpi_generic : aborted={reps[0].aborted} (static world -> "
          "restore checkpoint + re-run)")
    reps, store = train_rounds("grpc+s3", rounds=1,
                               dropped={"client0", "client1"})
    print(f"   grpc+s3     : aborted={reps[0].aborted}, "
          f"participants={reps[0].n_participants}/7 (quorum), "
          f"late clients re-fetch from S3 "
          f"(stats={dict(store.stats)})")


if __name__ == "__main__":
    main()
