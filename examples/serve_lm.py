"""Serve a reduced LM with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-1.2b"
    raise SystemExit(serve_main(["--arch", arch, "--requests", "4",
                                 "--prompt-len", "16", "--gen", "8"]))
