"""3-term roofline from a compiled dry-run artifact (TPU v5e targets).

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)   [ICI]
                  (+ DCN term reported separately for multi-pod)

HLO_FLOPs / collective_bytes come from the trip-count-aware HLO walk
(hlo_cost.py) over ``compiled.as_text()`` — the SPMD module is the
per-chip program, so terms divide only by per-chip peak rates.

Memory term: the CPU backend's fusion/copy structure differs from TPU
(XLA:CPU materialises loop-carried copies a TPU program would alias), so
raw HLO operand-byte sums overstate HBM traffic by >10x. Instead the
memory term uses the compiled buffer inventory from
``compiled.memory_analysis()``: every live buffer written once + read once
(args + outputs + 2*temps). The raw HLO-walk bytes are kept in the record
as ``hlo_walk_bytes`` (diagnostic upper bound). Both derive from the
compiled dry-run artifact.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.roofline.hlo_cost import Cost, entry_cost

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (brief's constant)
DCN_BW = 6.25e9  # bytes/s per host across pods


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    kind: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float  # buffer-inventory traffic (args + outputs + 2*temps)
    hlo_walk_bytes: float  # raw HLO operand-byte walk (diagnostic)
    coll_ici_bytes: float
    coll_dcn_bytes: float
    coll_by_op: dict
    model_flops: float  # 6*N(_active)*tokens for train, 2*N for fwd-only
    # seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    t_dcn: float = 0.0

    def finalize(self):
        self.t_compute = self.hlo_flops / PEAK_FLOPS
        self.t_memory = self.hlo_bytes / HBM_BW
        self.t_collective = self.coll_ici_bytes / ICI_BW
        self.t_dcn = self.coll_dcn_bytes / DCN_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective, "dcn": self.t_dcn}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective,
                   self.t_dcn)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (catches remat/dispatch waste).
        Program is per-chip, MODEL_FLOPS is global -> divide by chips."""
        per_chip_model = self.model_flops / self.chips
        return per_chip_model / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the program ran at
        its bound: (useful flops / peak) / bound_time."""
        per_chip_model = self.model_flops / self.chips
        ideal = per_chip_model / PEAK_FLOPS
        return ideal / max(self.bound_time, 1e-30)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, bound_time=self.bound_time,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS convention: 6*N*D for training; 2*N*D forward-only
    (prefill); 2*N_active per token for decode."""
    from repro.models.registry import active_param_count

    n_active = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens_per_step
    return 2.0 * n_active * shape.tokens_per_step


def analyze(compiled, *, arch: str, shape, kind: str, mesh_name: str,
            chips: int, pod_size: int, cfg) -> Roofline:
    cost = entry_cost(compiled.as_text(), pod_size=pod_size)
    mem = compiled.memory_analysis()
    traffic = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + 2 * mem.temp_size_in_bytes)
    rl = Roofline(
        arch=arch, shape=shape.name, kind=kind, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops, hlo_bytes=float(traffic),
        hlo_walk_bytes=cost.hbm_bytes,
        coll_ici_bytes=cost.coll_ici_bytes,
        coll_dcn_bytes=cost.coll_dcn_bytes, coll_by_op=cost.coll_by_op,
        model_flops=model_flops_for(cfg, shape))
    return rl.finalize()
