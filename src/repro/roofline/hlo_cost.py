"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` visits while bodies once (scan bodies are NOT
multiplied by trip count), which under-counts layer-scanned models by ~L x.
This walker parses ``compiled.as_text()`` and computes:

* flops            — dot-aware (2*M*N*K), fusion-recursive, while bodies
                     multiplied by ``known_trip_count``;
* hbm_bytes        — operand+result bytes of every materialising top-level
                     op (fusion internals excluded — post-fusion HLO means
                     fusion boundaries ARE the HBM traffic);
* collective_bytes — per op kind with ring-algorithm effective-bytes
                     formulas, replica-group aware (iota + explicit formats)
                     and split ICI vs cross-pod DCN.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# NB: tuple types may contain "/*index=5*/" comments (with '='), so match
# balanced-paren-free tuple bodies via [^)] rather than [^=].
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def _shape_info(type_str: str):
    """-> (elem_count, bytes) summed over tuple components."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dt]
    if elems == 0 and type_str.split("[")[0] in DTYPE_BYTES:
        # scalar like 'f32[]' already handled; bare 'pred' etc.
        elems, nbytes = 1, DTYPE_BYTES.get(type_str.split("[")[0], 4)
    return elems, nbytes


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    elems: int
    nbytes: int


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
            continue
        if line.strip() == "}" or line.startswith("}"):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        rest = line[m.end():]
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = rest[:i]
        attrs = rest[i + 1:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        elems, nbytes = _shape_info(type_str)
        comps[current].append(Instr(name, type_str, opcode, operands, attrs,
                                    elems, nbytes))
    return comps


# ---------------------------------------------------------------------------
# replica groups
# ---------------------------------------------------------------------------

def parse_replica_groups(attrs: str):
    """-> (group_size, groups_or_None). Handles explicit {{0,1},{2,3}} and
    iota [G,S]<=[dims]T(perm) formats."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        first = m.group(1)
        size = len(first.split(","))
        groups = []
        for g in re.findall(r"\{([\d,]+)\}", attrs.split("replica_groups=")[1]):
            groups.append([int(x) for x in g.split(",")])
        return max(size, 1), groups
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  attrs)
    if m:
        G, S = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if perm:
            arr = arr.transpose(perm)
        groups = arr.reshape(G, S)
        return S, groups.tolist()
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2)), None
    return 1, None


def crosses_pod(groups, pod_size: int) -> bool:
    if groups is None:
        return False
    for g in groups:
        pods = {d // pod_size for d in g}
        if len(pods) > 1:
            return True
    return False


def collective_effective_bytes(opcode: str, result_bytes: int,
                               operand_bytes: int, group: int) -> float:
    """Per-device bytes crossing links (ring algorithms)."""
    if group <= 1:
        return 0.0
    if opcode.startswith("all-reduce"):
        return 2.0 * (group - 1) / group * max(result_bytes, operand_bytes)
    if opcode.startswith("all-gather"):
        return (group - 1) / group * result_bytes
    if opcode.startswith("reduce-scatter"):
        return (group - 1) / group * operand_bytes
    if opcode.startswith("all-to-all"):
        return (group - 1) / group * max(result_bytes, operand_bytes)
    if opcode.startswith("collective"):
        return float(max(result_bytes, operand_bytes))
    return 0.0


# ---------------------------------------------------------------------------
# cost walk
# ---------------------------------------------------------------------------

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "domain",
             "opt-barrier"}

_FLOP_FREE = _SKIP_OPS | {"copy", "reshape", "transpose", "broadcast",
                          "slice", "dynamic-slice", "dynamic-update-slice",
                          "concatenate", "pad", "reverse", "gather",
                          "scatter", "convert", "while", "conditional",
                          "call", "fusion", "custom-call", "select",
                          "compare"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_ici_bytes: float = 0.0
    coll_dcn_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    bytes_by_op: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        merged = defaultdict(float)
        for d in (self.coll_by_op, o.coll_by_op):
            for k, v in d.items():
                merged[k] += v
        bmerged = defaultdict(float)
        for d in (self.bytes_by_op, o.bytes_by_op):
            for k, v in d.items():
                bmerged[k] += v
        return Cost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                    self.coll_ici_bytes + o.coll_ici_bytes,
                    self.coll_dcn_bytes + o.coll_dcn_bytes, dict(merged),
                    dict(bmerged))

    def scale(self, k: float):
        return Cost(self.flops * k, self.hbm_bytes * k,
                    self.coll_ici_bytes * k, self.coll_dcn_bytes * k,
                    {kk: v * k for kk, v in self.coll_by_op.items()},
                    {kk: v * k for kk, v in self.bytes_by_op.items()})


def _fusion_io_bytes(ins: Instr, called: List[Instr], shapes) -> float:
    """HBM traffic of a fusion = true reads + true writes.

    * operands consumed only through dynamic-slice/gather inside the fusion
      count as the sliced bytes, not the whole buffer;
    * a root dynamic-update-slice writes only the update slice (the big
      buffer is aliased in place).
    """
    if not called:
        return sum(_shape_info(shapes.get(o, ""))[1] for o in ins.operands) \
            + ins.nbytes
    inner_shapes = {i.name: i.type_str for i in called}
    # param index -> inner instr
    params = {}
    for ci in called:
        if ci.opcode == "parameter":
            try:
                idx = int(ci.operands[0]) if ci.operands else int(
                    re.search(r"parameter\((\d+)\)", ci.attrs or "").group(1))
            except Exception:  # noqa: BLE001
                idx = len(params)
            params[ci.name] = idx
    # users of each inner name
    users: Dict[str, list] = defaultdict(list)
    for ci in called:
        for o in ci.operands:
            users[o].append(ci)
    # several inner parameters may bind the same outer buffer: count each
    # unique outer operand once (at its widest access)
    per_outer: Dict[str, float] = {}
    for pname, idx in params.items():
        if idx >= len(ins.operands):
            continue
        outer = ins.operands[idx]
        full = _shape_info(shapes.get(outer, ""))[1]
        us = users.get(pname, [])
        if us and all(u.opcode in ("dynamic-slice", "gather") for u in us):
            eff = float(sum(u.nbytes for u in us))
        elif us and all(u.opcode == "dynamic-update-slice" and
                        u.operands and u.operands[0] == pname for u in us):
            eff = 0.0  # pure in-place write target
        else:
            eff = float(full)
        per_outer[outer] = max(per_outer.get(outer, 0.0), eff)
    read = sum(per_outer.values())
    root = called[-1]
    if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
        write = 2.0 * _shape_info(
            inner_shapes.get(root.operands[1], ""))[1]
    else:
        write = ins.nbytes
    return read + write


def _trip_count(instr: Instr, comps, symtab) -> float:
    m = re.search(r'known_trip_count[\'"]?:\s*\{[\'"]?n[\'"]?:\s*[\'"]?(\d+)',
                  instr.attrs)
    if m:
        return float(m.group(1))
    m = re.search(r"condition=%?([\w\.\-]+)", instr.attrs)
    if m and m.group(1) in comps:
        for ci in comps[m.group(1)]:
            if ci.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)", ci.attrs) or \
                    re.search(r"\((\d+)\)", ci.type_str)
                if mm:
                    return float(mm.group(1))
        for ci in comps[m.group(1)]:
            mm = re.search(r"constant\((\d+)\)",
                           ci.name + ci.attrs)
            if mm:
                return float(mm.group(1))
    return 1.0


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems = instr.elems
    lhs_t = shapes.get(instr.operands[0], "")
    dims = _first_shape_dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    k = 1
    if m and m.group(1) and dims:
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(dims):
                k *= dims[di]
    return 2.0 * out_elems * k


def _conv_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    rhs_t = shapes.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    kdims = _first_shape_dims(rhs_t)
    out_elems = instr.elems
    if not kdims:
        return 2.0 * out_elems
    # HWIO kernel: flops = 2 * out * (kh*kw*cin)
    per_out = 2.0 * float(np.prod(kdims[:-1]))
    return per_out * out_elems


def computation_cost(name: str, comps, pod_size: int,
                     _memo=None) -> Cost:
    if _memo is None:
        _memo = {}
    if name in _memo:
        return _memo[name]
    _memo[name] = Cost()  # cycle guard
    instrs = comps.get(name, [])
    shapes = {i.name: i.type_str for i in instrs}
    total = Cost()
    for ins in instrs:
        op = ins.opcode
        c = Cost()
        operand_bytes = sum(
            _shape_info(shapes.get(o, ""))[1] for o in ins.operands)
        if op == "dot":
            c.flops = _dot_flops(ins, shapes)
            c.hbm_bytes = operand_bytes + ins.nbytes
        elif op == "convolution":
            c.flops = _conv_flops(ins, shapes)
            c.hbm_bytes = operand_bytes + ins.nbytes
        elif op.startswith(COLLECTIVES) and not op.endswith("-done"):
            group, groups = parse_replica_groups(ins.attrs)
            eff = collective_effective_bytes(op, ins.nbytes, operand_bytes,
                                             group)
            base = op.replace("-start", "")
            c.coll_by_op = {base: eff}
            if pod_size and crosses_pod(groups, pod_size):
                c.coll_dcn_bytes = eff
            else:
                c.coll_ici_bytes = eff
            c.hbm_bytes = operand_bytes + ins.nbytes
        elif op == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            if m:
                inner = computation_cost(m.group(1), comps, pod_size, _memo)
                c = c + Cost(flops=inner.flops)
                c.coll_ici_bytes += inner.coll_ici_bytes
                c.coll_dcn_bytes += inner.coll_dcn_bytes
                c.hbm_bytes += _fusion_io_bytes(ins, comps.get(m.group(1), []),
                                                shapes)
            else:
                c.hbm_bytes += operand_bytes + ins.nbytes
        elif op in ("call", "conditional", "async-start", "custom-call"):
            for cname in re.findall(
                    r"(?:calls|to_apply|body|branch_computations)=\{?%?([\w\.\-]+)",
                    ins.attrs):
                c = c + computation_cost(cname, comps, pod_size, _memo)
            c.hbm_bytes += operand_bytes + ins.nbytes
        elif op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            trips = _trip_count(ins, comps, shapes)
            if mb:
                body = computation_cost(mb.group(1), comps, pod_size, _memo)
                c = c + body.scale(trips)
        elif op in _SKIP_OPS:
            pass
        elif op == "dynamic-update-slice":
            # in-place semantics: traffic = read+write of the update slice
            upd = _shape_info(shapes.get(ins.operands[1], ""))[1] \
                if len(ins.operands) > 1 else ins.nbytes
            c.hbm_bytes = 2.0 * upd
        elif op in ("dynamic-slice", "gather"):
            c.hbm_bytes = 2.0 * ins.nbytes  # read slice + write result
        elif op == "scatter":
            upd = _shape_info(shapes.get(ins.operands[2], ""))[1] \
                if len(ins.operands) > 2 else ins.nbytes
            c.hbm_bytes = 3.0 * upd
        else:
            # elementwise / reduce / copy etc: 1 flop per output elem
            if op not in _FLOP_FREE:
                c.flops = float(ins.elems)
            if op not in ("reshape", "broadcast", "convert"):
                c.hbm_bytes = operand_bytes + ins.nbytes
        if c.hbm_bytes and not c.bytes_by_op:
            c.bytes_by_op = {op: c.hbm_bytes}
        total = total + c
    _memo[name] = total
    return total


# TPU-class machine balance (peak flops / HBM bandwidth), flops per byte:
# ~197 Tf/s over ~0.82 TB/s ≈ 240. A kernel whose arithmetic intensity
# sits far below this is bandwidth-bound — more compute cannot speed it
# up, only fewer bytes can (which is what fusing a batch of encodes into
# one dispatch buys: the fixed dispatch/launch cost amortises and the
# rows stream once).
MACHINE_BALANCE_FLOPS_PER_BYTE = 240.0


def arithmetic_intensity(cost: Cost) -> float:
    """flops per HBM byte of a walked computation (inf when byte-free)."""
    if cost.hbm_bytes <= 0:
        return float("inf")
    return cost.flops / cost.hbm_bytes


def is_bandwidth_bound(cost: Cost, *, balance: float =
                       MACHINE_BALANCE_FLOPS_PER_BYTE) -> bool:
    """True when the computation's intensity sits below the machine
    balance point — the roofline says HBM bandwidth, not compute, limits
    it. The batched-codec CI assertion: the fused quantize stage must
    stay bandwidth-bound (it streams rows; if intensity ever climbs the
    fusion regressed into recomputation)."""
    return arithmetic_intensity(cost) < balance


def entry_cost(text: str, pod_size: int = 0) -> Cost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: computation with most instructions
        entry = max(comps, key=lambda k: len(comps[k]))
    return computation_cost(entry, comps, pod_size)
