from repro.roofline.analysis import (DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS,
                                     Roofline, analyze, model_flops_for)
from repro.roofline.hlo_cost import Cost, entry_cost

__all__ = ["analyze", "Roofline", "entry_cost", "Cost", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW", "DCN_BW"]
