"""Serializers — the behavioural split the paper measures (§V):

* ``GenericSerializer``  — serialises/transforms arbitrary objects into a
  fresh byte buffer (MPI_GENERIC's lowercase send, pickle-family). Allocates
  a full copy; throughput ~0.55 GB/s each way.
* ``ProtobufSerializer`` — gRPC's packing: protobuf field encode + HTTP/2
  framing; the slowest path (~0.16 GB/s) and also copies.
* ``BufferSerializer``   — MPI_MEM_BUFF / TensorRPC: zero-copy buffer
  views; near-C speed, but only for buffer-like (contiguous array) objects.

Throughputs are calibration constants from the paper's own measurements
(LAN serialization = up to 86 % of gRPC latency; see DESIGN.md §6) and are
charged in *simulated* time. The byte-level behaviour (copy vs view) is
real, so memory accounting is exact.
"""
from __future__ import annotations

import dataclasses
import io
import pickle
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.core.message import (FLMessage, PackedPayload, TensorPayload,
                                VirtualPayload)

GB = 1024 ** 3


@dataclasses.dataclass
class WireData:
    """What travels: either real buffers or a virtual size."""
    nbytes: int
    buffers: Optional[list] = None  # list of np arrays / bytes (zero-copy views)
    copied: bool = False  # did serialisation allocate a copy?
    obj: Optional[Any] = None  # structure needed to reconstruct
    codec: str = ""  # which serializer produced this wire (decode with same)
    # stage provenance (core/channel.py): one info dict per WireStage that
    # shaped this wire, in encode-application order. The receiving Channel
    # inverts them right-to-left; an empty list means a legacy bare wire
    # (decode_wire with the receiver's serializer, exactly as before).
    stages: list = dataclasses.field(default_factory=list)


class BaseSerializer:
    name = "base"
    gbps_out = float("inf")  # serialisation throughput (bytes/s), sender
    gbps_in = float("inf")  # deserialisation throughput, receiver
    copies = False

    def serialize(self, payload) -> WireData:
        wire = self._serialize(payload)
        wire.codec = self.name
        return wire

    def _serialize(self, payload) -> WireData:
        raise NotImplementedError

    def deserialize(self, wire: WireData):
        raise NotImplementedError

    def ser_time(self, nbytes: int) -> float:
        return nbytes / self.gbps_out if self.gbps_out != float("inf") else 0.0

    def deser_time(self, nbytes: int) -> float:
        return nbytes / self.gbps_in if self.gbps_in != float("inf") else 0.0


class GenericSerializer(BaseSerializer):
    """Pickle-style: full copy both ways (MPI_GENERIC)."""
    name = "generic"
    gbps_out = 0.55 * GB
    gbps_in = 0.85 * GB
    copies = True

    def _serialize(self, payload) -> WireData:
        if isinstance(payload, VirtualPayload):
            return WireData(nbytes=payload.nbytes, copied=True, obj=payload)
        if isinstance(payload, TensorPayload):
            leaves, treedef = jax.tree.flatten(payload.tree)
            buf = io.BytesIO()
            arrs = [np.asarray(l) for l in leaves]
            pickle.dump({"treedef": treedef,
                         "arrs": [a.tobytes() for a in arrs],  # the copy
                         "meta": [(a.shape, str(a.dtype)) for a in arrs]}, buf)
            data = buf.getvalue()
            return WireData(nbytes=len(data), buffers=[data], copied=True)
        if isinstance(payload, PackedPayload):
            buf = io.BytesIO()
            pickle.dump(jax.tree.map(np.asarray, payload.packed), buf)
            data = buf.getvalue()
            return WireData(nbytes=len(data), buffers=[data], copied=True)
        raise TypeError(type(payload))

    def deserialize(self, wire: WireData):
        if wire.obj is not None:
            return wire.obj
        obj = pickle.loads(wire.buffers[0])
        if isinstance(obj, dict) and "treedef" in obj:
            arrs = [np.frombuffer(b, dtype=dt).reshape(shape)
                    for b, (shape, dt) in zip(obj["arrs"], obj["meta"])]
            return TensorPayload(jax.tree.unflatten(obj["treedef"], arrs))
        return PackedPayload(obj)


class ProtobufSerializer(GenericSerializer):
    """gRPC: protobuf packing + HTTP/2 framing (slowest, copies)."""
    name = "protobuf"
    gbps_out = 0.16 * GB
    gbps_in = 0.35 * GB
    copies = True


class BufferSerializer(BaseSerializer):
    """Zero-copy views of contiguous buffers (MPI_MEM_BUFF / TensorRPC).
    Only accepts buffer-like payloads (array pytrees / packed / virtual)."""
    name = "membuff"
    gbps_out = float("inf")  # only a checksum pass; modelled as free
    gbps_in = float("inf")
    copies = False

    def _serialize(self, payload) -> WireData:
        if isinstance(payload, VirtualPayload):
            return WireData(nbytes=payload.nbytes, obj=payload)
        if isinstance(payload, TensorPayload):
            leaves, treedef = jax.tree.flatten(payload.tree)
            arrs = [np.asarray(l) for l in leaves]  # views, no copy
            return WireData(nbytes=sum(a.nbytes for a in arrs), buffers=arrs,
                            obj=("tree", treedef,
                                 [(a.shape, a.dtype) for a in arrs]))
        if isinstance(payload, PackedPayload):
            if "idx" in payload.packed:  # top-k sparse form
                arrs = [np.asarray(payload.packed["idx"]),
                        np.asarray(payload.packed["vals"])]
                return WireData(nbytes=sum(a.nbytes for a in arrs),
                                buffers=arrs,
                                obj=("topk", int(payload.packed["n"])))
            arrs = [np.asarray(payload.packed["q"]),
                    np.asarray(payload.packed["scales"])]
            return WireData(nbytes=sum(a.nbytes for a in arrs), buffers=arrs,
                            obj=("packed", payload.packed["block"],
                                 payload.packed["orig_len"]))
        raise TypeError(
            f"{self.name} can only send buffer-like objects, got {type(payload)}")

    def deserialize(self, wire: WireData):
        if wire.buffers is None:
            return wire.obj
        kind = wire.obj[0]
        if kind == "tree":
            _, treedef, _ = wire.obj
            return TensorPayload(jax.tree.unflatten(treedef, wire.buffers))
        if kind == "topk":
            return PackedPayload({"idx": wire.buffers[0],
                                  "vals": wire.buffers[1], "n": wire.obj[1]})
        _, block, orig = wire.obj
        return PackedPayload({"q": wire.buffers[0], "scales": wire.buffers[1],
                              "block": block, "orig_len": orig})


class TensorRPCSerializer(BufferSerializer):
    """TensorPipe-style: zero-copy tensors + a cheap header pass."""
    name = "tensor_rpc"
    gbps_out = 8.0 * GB  # small per-tensor bookkeeping
    gbps_in = 8.0 * GB


SERIALIZERS = {s.name: s for s in
               (GenericSerializer(), ProtobufSerializer(), BufferSerializer(),
                TensorRPCSerializer())}


def decode_wire(wire: WireData, fallback: BaseSerializer):
    """Deserialize with the codec that produced the wire (backends can
    differ between the send and receive path, e.g. AUTO routing)."""
    ser = SERIALIZERS.get(wire.codec, fallback)
    return ser.deserialize(wire)


def checksum(wire: WireData) -> int:
    if wire.buffers is None:
        return 0
    crc = 0
    for b in wire.buffers:
        crc = zlib.crc32(b if isinstance(b, bytes) else
                         np.ascontiguousarray(b).tobytes(), crc)
    return crc
