"""Discrete-event network model calibrated from the paper's Table I.

Star topology (FL server = hub). Each region carries the paper's measured
(single-connection BW, multi-connection BW, RTT latency) to the hub. A
transfer with ``conns`` connections is rate-capped at
``min(conns * bw_single, bw_multi)``; concurrently active transfers at a
host additionally share the host uplink/downlink via max-min fair
water-filling — this is what reproduces Fig 2 (concurrency recovers
throughput) and Fig 4b (concurrent-vs-sequential speedups saturating below
ideal).

All bandwidths stored in bytes/s, latencies in seconds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import heapq
import math
import zlib
from typing import Optional, Sequence

import numpy as np

MB = 1024 ** 2
GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class Region:
    """Paper Table I row: link characteristics to the hub (N. California)."""
    name: str
    bw_single: float  # bytes/s, one TCP connection
    bw_multi: float  # bytes/s, saturated multi-connection
    latency: float  # seconds, one-way-ish RTT as measured

    def conn_cap(self, conns: int) -> float:
        return min(conns * self.bw_single, self.bw_multi)


# Table I (g4dn.2xlarge, hub = North California)
NCAL = Region("ncal", 592 * MB, 2946 * MB, 0.44e-3)
OREGON = Region("oregon", 133 * MB, 573 * MB, 11e-3)
NVIRGINIA = Region("nvirginia", 39.4 * MB, 557 * MB, 32.3e-3)
HONGKONG = Region("hongkong", 16.3 * MB, 513 * MB, 83.3e-3)
STOCKHOLM = Region("stockholm", 11.4 * MB, 495 * MB, 90.9e-3)
SAOPAULO = Region("saopaulo", 8.27 * MB, 491 * MB, 90.9e-3)
BAHRAIN = Region("bahrain", 6.90 * MB, 444 * MB, 111e-3)

# LAN testbed (§IV-A): InfiniBand 5 GB/s @ 3.17 us; TCP fallback 1 GB/s
# @ 16.8 us (serialising backends ride TCP, buffer backends ride IB verbs).
LAN_IB = Region("lan_ib", 5.0 * GB, 5.0 * GB, 3.17e-6)
LAN_TCP = Region("lan_tcp", 1.0 * GB, 2.5 * GB, 16.8e-6)

GEO_REGIONS = [NCAL, OREGON, NVIRGINIA, HONGKONG, STOCKHOLM, SAOPAULO,
               BAHRAIN]
REGIONS = {r.name: r for r in GEO_REGIONS + [LAN_IB, LAN_TCP]}


@dataclasses.dataclass(frozen=True)
class Host:
    host_id: str
    region: Region
    uplink: float  # bytes/s host NIC budget (shared across transfers)
    downlink: float


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed edge of a deployment's topology graph.

    ``region`` carries the edge's capacity triple (single-connection BW,
    multi-connection saturation BW, latency); per-edge connection caps
    fold into ``bw_multi`` at build time. ``lan_class`` edges resolve to
    IB verbs or the TCP fallback per backend policy (buffer backends ride
    InfiniBand, serializing ones ride TCP) — the same split the implicit
    ``env.name == "lan"`` rule used to encode."""
    src: str
    dst: str
    region: Region
    lan_class: bool = False

    @property
    def latency(self) -> float:
        return self.region.latency

    def conn_cap(self, conns: int) -> float:
        return self.region.conn_cap(conns)


@dataclasses.dataclass(frozen=True)
class Environment:
    """One deployment regime: hosts + an explicit link graph.

    ``links`` maps ordered host-id pairs to graph edges
    (scenario.TopologySpec builds it). ``link()`` falls back to the
    historical implicit star rule for pairs the graph does not name —
    legacy hand-built Environments (links=None) behave exactly as before
    the graph existed."""
    name: str
    server: Host
    clients: tuple  # Host tuple
    has_object_store: bool = True
    trusted: bool = False  # LAN/within-org: MPI/RPC deployable
    links: Optional[dict] = None  # (src_id, dst_id) -> Link

    def host(self, host_id: str) -> Host:
        # lazily built id -> Host index (frozen dataclass, so it lives in
        # __dict__ via object.__setattr__): lookups are on every transfer's
        # hot path and a linear scan is quadratic at fleet scale
        if _LINEAR_LOOKUP[0]:  # pre-index baseline (fig11 speedup gate)
            if host_id == self.server.host_id:
                return self.server
            for c in self.clients:
                if c.host_id == host_id:
                    return c
            raise KeyError(host_id)
        idx = self.__dict__.get("_host_idx")
        if idx is None:
            idx = {c.host_id: c for c in self.clients}
            idx[self.server.host_id] = self.server
            object.__setattr__(self, "_host_idx", idx)
        try:
            return idx[host_id]
        except KeyError:
            raise KeyError(host_id) from None

    def link(self, src_id: str, dst_id: str) -> Link:
        """The graph edge a (src -> dst) transmission rides."""
        if self.links is not None:
            edge = self.links.get((src_id, dst_id))
            if edge is not None:
                return edge
        # implicit legacy rule: LAN links are LAN-class; WAN is a star
        # where the non-hub end dominates
        if self.name == "lan":
            return Link(src_id, dst_id, LAN_TCP, lan_class=True)
        src = self.host(src_id).region
        dst = self.host(dst_id).region
        return Link(src_id, dst_id, dst if dst.name != "ncal" else src)


def lan_env(num_clients: int = 7) -> Environment:
    mk = lambda i: Host(f"client{i}", LAN_TCP, 5.0 * GB, 5.0 * GB)
    return Environment("lan", Host("server", LAN_TCP, 5.0 * GB, 5.0 * GB),
                       tuple(mk(i) for i in range(num_clients)),
                       has_object_store=False, trusted=True)


def geo_proximal_env(num_clients: int = 7) -> Environment:
    mk = lambda i: Host(f"client{i}", NCAL, NCAL.bw_multi, NCAL.bw_multi)
    return Environment("geo_proximal",
                       Host("server", NCAL, NCAL.bw_multi, NCAL.bw_multi),
                       tuple(mk(i) for i in range(num_clients)), trusted=True)


def geo_distributed_env(num_clients: int = 7) -> Environment:
    """Paper's 7-region WAN testbed; >7 clients round-robin over the same
    regions (multi-client silos — the hierarchical-aggregation regime)."""
    regions = (GEO_REGIONS[i % len(GEO_REGIONS)] for i in range(num_clients))
    clients = tuple(Host(f"client{i}", r, r.bw_multi, r.bw_multi)
                    for i, r in enumerate(regions))
    return Environment("geo_distributed",
                       Host("server", NCAL, NCAL.bw_multi, NCAL.bw_multi),
                       clients)


# legacy constructors kept as the bit-for-bit reference the scenario
# presets are regression-tested against (tests/test_scenario.py)
ENVIRONMENTS = {
    "lan": lan_env,
    "geo_proximal": geo_proximal_env,
    "geo_distributed": geo_distributed_env,
}


def make_env(name: str, num_clients: int = 7) -> Environment:
    """Deprecated shim: environments are described by scenario specs now.
    Equivalent to ``TopologySpec.preset(name, num_clients).build()`` —
    which also accepts the graph presets (star/ring/multi_hub) the legacy
    constructors never had. Warns; no longer re-exported from
    ``repro.core``."""
    import warnings
    warnings.warn(
        "make_env is deprecated; use "
        "TopologySpec.preset(name, num_clients=...).build()",
        DeprecationWarning, stacklevel=2)
    from repro.scenario import TopologySpec
    return TopologySpec.preset(name, num_clients=num_clients).build()


# ---------------------------------------------------------------------------
# fluid-flow transfer simulation (max-min fair water-filling)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Transfer:
    start: float
    src: Host
    dst: Host
    nbytes: float
    conns: int = 1
    link_region: Optional[Region] = None  # defaults to the non-hub region
    tag: str = ""
    # aggregate link modeling (multi-tenant fabric): transfers that stamp
    # the same ``edge_key`` share ONE contended pipe of ``edge_cap``
    # bytes/s on top of their per-transfer caps and host NIC budgets —
    # the shared-bottleneck semantics of Marfoq et al.'s capacity model.
    # ``None`` (the default) declares no shared edge and leaves every
    # solver code path bit-identical to the pre-tenancy behaviour.
    edge_key: Optional[tuple] = None
    edge_cap: float = 0.0
    # filled by simulate():
    finish: float = math.inf

    def rate_cap(self) -> float:
        region = self.link_region or (
            self.dst.region if self.dst.region is not NCAL else self.src.region)
        return region.conn_cap(max(self.conns, 1))

    def latency(self) -> float:
        region = self.link_region or (
            self.dst.region if self.dst.region is not NCAL else self.src.region)
        return region.latency


def _fair_rates(active: Sequence[Transfer]) -> dict:
    """Max-min fair allocation under per-transfer caps + host NIC budgets
    + (when declared) per-edge aggregate pipe budgets: transfers stamping
    the same ``edge_key`` progressive-fill against one shared ``edge_cap``
    pool exactly the way they share a host NIC budget. With no edge keys
    in the active set the extra terms never execute — bit-identical to
    the pre-tenancy solver."""
    rates = {id(t): 0.0 for t in active}
    caps = {id(t): t.rate_cap() for t in active}
    up = {}
    down = {}
    edge = {}  # edge_key -> remaining aggregate pipe budget
    for t in active:
        up.setdefault(t.src.host_id, t.src.uplink)
        down.setdefault(t.dst.host_id, t.dst.downlink)
        if t.edge_key is not None:
            edge.setdefault(t.edge_key, t.edge_cap)
    unfrozen = set(rates)
    # progressive filling
    for _ in range(len(active) + 2):
        if not unfrozen:
            break
        # per-host fair share among its unfrozen transfers
        increments = {}
        for t in active:
            if id(t) not in unfrozen:
                continue
            n_up = sum(1 for u in active if id(u) in unfrozen
                       and u.src.host_id == t.src.host_id)
            n_dn = sum(1 for u in active if id(u) in unfrozen
                       and u.dst.host_id == t.dst.host_id)
            share = min(up[t.src.host_id] / n_up, down[t.dst.host_id] / n_dn,
                        caps[id(t)] - rates[id(t)])
            if t.edge_key is not None:
                n_e = sum(1 for u in active if id(u) in unfrozen
                          and u.edge_key == t.edge_key)
                share = min(share, edge[t.edge_key] / n_e)
            increments[id(t)] = max(share, 0.0)
        if not increments:
            break
        inc = min(increments.values())
        newly_frozen = set()
        for t in active:
            if id(t) not in unfrozen:
                continue
            rates[id(t)] += increments[id(t)]
            up[t.src.host_id] -= increments[id(t)]
            down[t.dst.host_id] -= increments[id(t)]
            if t.edge_key is not None:
                edge[t.edge_key] -= increments[id(t)]
            if rates[id(t)] >= caps[id(t)] - 1e-9 or increments[id(t)] <= 1e-9:
                newly_frozen.add(id(t))
        unfrozen -= newly_frozen
        if not newly_frozen:
            break
    return rates


# Fleet-scale dispatch: at and above this many transfers one fluid call
# switches from the per-transfer scalar loop to the NumPy flow solver
# (same max-min water-filling, vectorised + contended edges collapsed
# into weighted flows). Below it — every paper-scale run — the scalar
# path runs unconditionally, so small-fleet traces are bit-identical to
# the pre-vectorisation code by construction.
SIM_VECTORIZE_MIN = 64

_FORCE_SCALAR = [0]


@contextlib.contextmanager
def scalar_transfers():
    """Force the scalar reference solver regardless of transfer count
    (the fig11 legacy baseline and the vec-vs-scalar parity tests)."""
    _FORCE_SCALAR[0] += 1
    try:
        yield
    finally:
        _FORCE_SCALAR[0] -= 1


# ``Environment.host`` baseline switch: >0 forces the pre-index linear
# scan over the client tuple (identical results, O(fleet) per lookup).
_LINEAR_LOOKUP = [0]


@contextlib.contextmanager
def linear_host_lookup():
    """Force the historical O(clients) host scan — with
    ``scalar_transfers`` and ``transport.linear_inbox``, the measurable
    pre-PR hot path for the fig11 engine-speedup gate."""
    _LINEAR_LOOKUP[0] += 1
    try:
        yield
    finally:
        _LINEAR_LOOKUP[0] -= 1


def simulate_transfers(transfers: Sequence[Transfer]) -> Sequence[Transfer]:
    """Event-driven fluid simulation. Sets ``finish`` on each transfer
    (start + latency + contention-aware transmission time).

    Dispatches to the vectorised flow solver for fleet-scale calls
    (``len >= SIM_VECTORIZE_MIN``, matches the scalar path within float
    tolerance); the scalar loop below is the reference semantics."""
    if len(transfers) >= SIM_VECTORIZE_MIN and not _FORCE_SCALAR[0]:
        return _simulate_transfers_np(transfers)
    return _simulate_transfers_scalar(transfers)


def _simulate_transfers_scalar(transfers: Sequence[Transfer]) -> Sequence[Transfer]:
    remaining = {id(t): float(t.nbytes) for t in transfers}
    begin = {id(t): t.start + t.latency() for t in transfers}
    pending = sorted(transfers, key=lambda t: begin[id(t)])
    active: list = []
    now = begin[id(pending[0])] if pending else 0.0
    pi = 0
    while pending[pi:] or active:
        while pi < len(pending) and begin[id(pending[pi])] <= now + 1e-12:
            active.append(pending[pi])
            pi += 1
        if not active:
            now = begin[id(pending[pi])]
            continue
        rates = _fair_rates(active)
        # time to next event: earliest finish or next start
        t_fin = math.inf
        for t in active:
            r = max(rates[id(t)], 1e-9)
            t_fin = min(t_fin, remaining[id(t)] / r)
        t_next = begin[id(pending[pi])] - now if pi < len(pending) else math.inf
        dt = min(t_fin, t_next)
        for t in list(active):
            remaining[id(t)] -= rates[id(t)] * dt
            if remaining[id(t)] <= 1e-6:
                t.finish = now + dt
                active.remove(t)
        now += dt
    return transfers


def _fair_rates_np(caps, src, dst, w, up, dn, ekey=None, ebud=None):
    """Vectorised max-min water-filling over weighted flows.

    Mirrors ``_fair_rates`` exactly: each filling iteration computes
    every unfrozen flow's share from the budgets as they stood at the
    start of the iteration (the scalar loop does the same — it reads
    ``up``/``down`` before applying any increment of the round), then
    applies all increments at once. A flow of weight m stands in for m
    identical scalar transfers: it counts m times in the per-host fair
    split and drains m shares from each budget, which is exactly what
    the m members would have done one by one.

    caps/src/dst/w are per-flow; up/dn are per-host budget arrays
    (mutated). ``ekey``/``ebud`` carry the aggregate-link pools: per-flow
    edge index (-1 = no shared edge) and per-edge budget array (mutated)
    — same progressive-filling treatment as the host budgets, matching
    the scalar solver's ``edge_key`` terms. Returns per-flow member
    rates (not multiplied by w)."""
    m = caps.size
    rates = np.zeros(m)
    unfrozen = np.ones(m, bool)
    nh = up.size
    for _ in range(m + 2):
        act = np.nonzero(unfrozen)[0]
        if act.size == 0:
            break
        wu = np.bincount(src[act], weights=w[act], minlength=nh)
        wd = np.bincount(dst[act], weights=w[act], minlength=nh)
        share = np.minimum(np.minimum(up[src[act]] / wu[src[act]],
                                      dn[dst[act]] / wd[dst[act]]),
                           caps[act] - rates[act])
        if ebud is not None:
            ek = ekey[act]
            on = ek >= 0
            if on.any():
                we = np.bincount(ek[on], weights=w[act][on],
                                 minlength=ebud.size)
                ek0 = np.maximum(ek, 0)
                eshare = np.where(on, ebud[ek0] / np.maximum(we[ek0], 1e-300),
                                  np.inf)
                share = np.minimum(share, eshare)
        share = np.maximum(share, 0.0)
        np.subtract.at(up, src[act], share * w[act])
        np.subtract.at(dn, dst[act], share * w[act])
        if ebud is not None and on.any():
            np.subtract.at(ebud, ek[on], (share * w[act])[on])
        rates[act] += share
        newly = (rates[act] >= caps[act] - 1e-9) | (share <= 1e-9)
        if not newly.any():
            break
        unfrozen[act[newly]] = False
    return rates


def _simulate_transfers_np(transfers: Sequence[Transfer]) -> Sequence[Transfer]:
    """NumPy twin of the scalar fluid loop for fleet-scale fan-in/out.

    Two ideas on top of straight vectorisation:

    * **host factorisation** — per-client Transfer objects reduce to
      integer (src, dst) host indices; the fair split becomes two
      ``bincount``s instead of the scalar loop's O(active^2) host scans.
    * **flow collapsing (aggregate link modeling)** — a broadcast or
      upload wave through one shared bottleneck edge is m transfers that
      differ only in their singleton far end. They are collapsed into
      ONE weighted flow (weight m, synthetic far-end budget m*B), so the
      contended edge is charged once per wave, not once per client. By
      symmetry of max-min fairness the m members always receive equal
      rates and finish together, so the collapse is exact, not an
      approximation.

    Matches ``_simulate_transfers_scalar`` within float tolerance
    (summation order differs); paper-scale calls never route here."""
    n = len(transfers)
    if n == 0:
        return transfers

    host_ix: dict = {}
    up_b: list = []
    dn_b: list = []

    def hid(h):
        i = host_ix.get(h.host_id)
        if i is None:
            i = host_ix[h.host_id] = len(up_b)
            up_b.append(float(h.uplink))
            dn_b.append(float(h.downlink))
        return i

    src = np.fromiter((hid(t.src) for t in transfers), np.int64, n)
    dst = np.fromiter((hid(t.dst) for t in transfers), np.int64, n)
    caps = np.fromiter((t.rate_cap() for t in transfers), float, n)
    begin = np.fromiter((t.start + t.latency() for t in transfers), float, n)
    sizes = np.fromiter((float(t.nbytes) for t in transfers), float, n)

    # aggregate link pools (shared-bottleneck edges): edge_key -> index
    e_ix: dict = {}
    e_bud: list = []

    def eid(t):
        if t.edge_key is None:
            return -1
        i = e_ix.get(t.edge_key)
        if i is None:
            i = e_ix[t.edge_key] = len(e_bud)
            e_bud.append(float(t.edge_cap))
        return i

    ekey = np.fromiter((eid(t) for t in transfers), np.int64, n)

    # ---- collapse singleton-end groups into weighted flows ------------
    # a host is "singleton" when it appears in exactly one transfer: its
    # budget is private to that transfer, so two transfers sharing the
    # OTHER end and all rate-relevant scalars are exchangeable.
    occur = np.bincount(np.concatenate([src, dst]), minlength=len(up_b))
    f_key: dict = {}
    f_members: list = []  # per flow: list of transfer indices
    f_src: list = []
    f_dst: list = []
    f_syn: list = []  # per flow: None | ("up"|"dn", budget B) synthetic end
    for i in range(n):
        si, di = src[i], dst[i]
        if occur[di] == 1:  # fan-out: shared src, private dst
            key = ("out", si, caps[i], begin[i], sizes[i],
                   up_b[di], dn_b[di], ekey[i])
        elif occur[si] == 1:  # fan-in: private src, shared dst
            key = ("in", di, caps[i], begin[i], sizes[i],
                   up_b[si], dn_b[si], ekey[i])
        else:
            key = ("solo", i)
        fi = f_key.get(key)
        if fi is None:
            fi = f_key[key] = len(f_members)
            f_members.append([i])
            f_src.append(si)
            f_dst.append(di)
            f_syn.append(None if key[0] == "solo" else key[0])
        else:
            f_members[fi].append(i)
    nf = len(f_members)

    # synthetic hosts: a collapsed flow's private ends merge into one
    # host with m-times the budget (m members each brought their own B)
    fsrc = np.empty(nf, np.int64)
    fdst = np.empty(nf, np.int64)
    fw = np.empty(nf, float)
    first = np.fromiter((mem[0] for mem in f_members), np.int64, nf)
    for fi, mem in enumerate(f_members):
        m = len(mem)
        fw[fi] = m
        si, di = f_src[fi], f_dst[fi]
        if m > 1:
            if f_syn[fi] == "out":  # private dst hosts merge
                di = len(up_b)
                up_b.append(m * up_b[f_dst[fi]])
                dn_b.append(m * dn_b[f_dst[fi]])
            else:  # "in": private src hosts merge
                si = len(up_b)
                up_b.append(m * up_b[f_src[fi]])
                dn_b.append(m * dn_b[f_src[fi]])
        fsrc[fi] = si
        fdst[fi] = di
    fcaps = caps[first]
    fbegin = begin[first]
    fsizes = sizes[first]
    fekey = ekey[first]
    up0 = np.asarray(up_b, float)
    dn0 = np.asarray(dn_b, float)
    eb0 = np.asarray(e_bud, float) if e_bud else None

    # ---- event loop (same structure as the scalar path) ---------------
    remaining = fsizes.copy()
    finish = np.full(nf, math.inf)
    order = np.argsort(fbegin, kind="stable")
    sb = fbegin[order]
    active = np.zeros(nf, bool)
    now = sb[0]
    pi = 0
    while pi < nf or active.any():
        while pi < nf and sb[pi] <= now + 1e-12:
            active[order[pi]] = True
            pi += 1
        act = np.nonzero(active)[0]
        if act.size == 0:
            now = sb[pi]
            continue
        rates = _fair_rates_np(fcaps[act], fsrc[act], fdst[act], fw[act],
                               up0.copy(), dn0.copy(),
                               fekey[act] if eb0 is not None else None,
                               eb0.copy() if eb0 is not None else None)
        t_fin = np.min(remaining[act] / np.maximum(rates, 1e-9))
        t_next = sb[pi] - now if pi < nf else math.inf
        dt = min(t_fin, t_next)
        remaining[act] -= rates * dt
        done = act[remaining[act] <= 1e-6]
        finish[done] = now + dt
        active[done] = False
        now += dt

    for fi, mem in enumerate(f_members):
        for i in mem:  # collapsed members finish together (symmetry)
            transfers[i].finish = finish[fi]
    return transfers


def transfer_time(nbytes: float, region: Region, conns: int = 1) -> float:
    """Uncontended single-transfer time (latency + bytes / capped bw)."""
    return region.latency + nbytes / region.conn_cap(max(conns, 1))


# ---------------------------------------------------------------------------
# deterministic link fault injection
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return (z ^ (z >> 31)) & _M64


@functools.lru_cache(maxsize=4096)
def _link_hash(src: str, dst: str) -> int:
    return zlib.crc32(f"{src}>{dst}".encode())


@dataclasses.dataclass
class LinkFaultModel:
    """Deterministic per-link fault injector for the transport fabric.

    Two fault classes, both replayable from ``seed`` alone (draws are
    counter-based hashes of (seed, link, transfer id, chunk index,
    attempt) — no mutable RNG state, so concurrent transfers and re-runs
    see identical faults regardless of call order):

    * ``chunk_loss_rate`` — each transmitted chunk (a whole wire counts
      as one chunk when unchunked) is independently lost with this
      probability. Recovery is receiver-driven: the receiver notices the
      sequence gap and NACKs the sender (``detect_delay`` — one RTT of
      the graph edge the transfer rides), which retransmits, up to
      ``max_retries`` times; past that the transfer fails rather than
      retrying forever (backends surface a failed SendHandle; the FL
      scheduler re-issues the send at a higher level).
    * ``blackouts`` — per-host outage windows ``{host_id: [(t0, t1)]}``:
      nothing departs on a link while either end is dark; departures are
      shifted to the window's end (models transient WAN partitions).
    * ``edge_blackouts`` — the per-edge form ``{(src_id, dst_id):
      [(t0, t1)]}``: only the named directed edge goes dark (one flaky
      WAN path, not a whole silo). Declared via
      ``scenario.FaultSpec.blackouts``; with no windows installed the
      ``delay`` path is untouched (bit-for-bit the per-host-only code).
    """

    chunk_loss_rate: float = 0.0
    max_retries: int = 4
    nack_rtts: float = 1.0  # receiver-driven NACK turnaround, in edge RTTs
    blackouts: dict = dataclasses.field(default_factory=dict)
    edge_blackouts: dict = dataclasses.field(default_factory=dict)
    seed: int = 0

    def _uniform(self, src: str, dst: str, transfer_id: int,
                 chunk_index: int, attempt: int) -> float:
        x = (self.seed * 0x9E3779B97F4A7C15) & _M64
        for v in (_link_hash(src, dst), transfer_id, chunk_index, attempt):
            x = _splitmix64(x ^ (int(v) & _M64))
        return x / 2.0 ** 64

    def attempts(self, src: str, dst: str, transfer_id: int,
                 chunk_index: int, *, forced: bool = False) -> Optional[int]:
        """Transmissions until the chunk lands (>= 1). ``None`` when the
        bounded retries are exhausted — the transfer *fails* instead of
        wedging. ``forced=True`` caps at ``max_retries + 1`` but always
        succeeds (reliable-stream paths: concurrent broadcast)."""
        p = self.chunk_loss_rate
        if p <= 0.0:
            return 1
        for a in range(self.max_retries + 1):
            if self._uniform(src, dst, transfer_id, chunk_index, a) >= p:
                return a + 1
        return self.max_retries + 1 if forced else None

    def delay(self, host_ids: Sequence[str], t: float) -> float:
        """Shift a departure time past any blackout window covering it —
        per-host windows on either end of the link, plus per-edge windows
        on the ordered ``(src, dst)`` pair the callers pass."""
        edge_windows = self.edge_blackouts.get(tuple(host_ids), ()) \
            if self.edge_blackouts else ()
        moved = True
        while moved:
            moved = False
            for hid in host_ids:
                for (a, b) in self.blackouts.get(hid, ()):
                    if a <= t < b:
                        t = b
                        moved = True
            for (a, b) in edge_windows:
                if a <= t < b:
                    t = b
                    moved = True
        return t

    def detect_delay(self, edge: Link) -> float:
        """Loss-detection time before a retransmit, derived from the
        graph edge the transfer rides: the receiver notices the sequence
        gap about one edge-latency after the lost chunk should have
        landed and its NACK takes another one-way trip back — one RTT of
        *that edge*, not a fixed multi-RTT constant (receiver-driven
        NACK, vs the old sender-timeout model's ~2 RTTs)."""
        return self.nack_rtts * 2.0 * edge.latency
