"""In-process transport fabric + memory accounting.

The fabric really delivers WireData between endpoints (so tests exercise
true byte movement, checksums and reconstruction) while charging *simulated*
time from the netsim model. ``MemoryMeter`` tracks logical sender-side
buffer allocations — exact for real payloads, identical accounting for
virtual ones — reproducing Fig 2 (bottom) and Fig 4c.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import itertools
from collections import OrderedDict, defaultdict
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.message import FLMessage
from repro.core.netsim import Environment, Transfer, simulate_transfers
from repro.core.serialization import WireData

# ``Endpoint.pop_ready`` baseline switch, mirroring
# ``netsim.scalar_transfers``: >0 forces the historical full-inbox scan
# instead of the heap fast path (identical results, O(inbox) per recv).
_LINEAR_INBOX = [0]


@contextlib.contextmanager
def linear_inbox():
    """Force the pre-heap O(inbox) ``pop_ready`` scan — the measurable
    un-vectorized baseline for the fig11 engine-speedup gate."""
    _LINEAR_INBOX[0] += 1
    try:
        yield
    finally:
        _LINEAR_INBOX[0] -= 1


class MemoryMeter:
    """Logical allocation tracker (bytes). alloc/free pairs bracket buffer
    lifetimes; ``peak`` is what Fig 4c reports.

    Events carry *simulated* timestamps that are routinely issued out of
    call order (a backend allocs at a future serialize-start and frees at
    an even-further-future arrival before the next call allocs at an
    earlier time), so ``peak`` is computed from the time-sorted event
    timeline — a call-order running maximum both overstates sequential
    lifetimes that merely *appear* nested in call order and understates
    genuinely overlapping ones."""

    def __init__(self):
        self.current = 0
        self.events: List = []  # (time, +/- delta bytes) in call order

    def alloc(self, nbytes: int, now: float = 0.0):
        self.current += int(nbytes)
        self.events.append((float(now), int(nbytes)))

    def free(self, nbytes: int, now: float = 0.0):
        self.current -= int(nbytes)
        self.events.append((float(now), -int(nbytes)))

    @property
    def peak(self) -> int:
        """Max concurrent bytes over the time-sorted timeline (stable sort:
        same-timestamp events keep call order)."""
        cur = mx = 0
        for _, delta in sorted(self.events, key=lambda e: e[0]):
            cur += delta
            if cur > mx:
                mx = cur
        return mx

    def reset(self):
        self.current = 0
        self.events.clear()


@dataclasses.dataclass
class Delivery:
    msg: FLMessage
    wire: Optional[WireData]
    arrive_time: float
    # chunk-granular deliveries (ChunkStage wires): (index, total,
    # transfer id). Only the last chunk carries the wire; the endpoint
    # reassembles and releases the message when every chunk has landed.
    # The transfer id — not the msg_id — is the grouping key, so
    # retransmitting the same message never wedges two half-sets together.
    chunk: Optional[tuple] = None


class Inbox:
    """Pending deliveries with a (arrive_time, seq) heap over the
    chunk-free entries, so ``pop_ready`` costs O(ready · log n) instead
    of re-scanning the whole inbox on every recv — the scan was the
    scheduler's top hot spot at 1k+ clients (O(fleet²) overall).

    Keeps the historical list surface (append/extend/clear/iter/len;
    iteration yields insertion order) so fault tests can still inject
    and inspect raw deliveries. Chunked deliveries stay in a plain list:
    they need group-wise reassembly anyway and only exist when
    ``chunk_mb`` is set."""

    def __init__(self):
        self._simple: List[tuple] = []  # heap of (arrive_time, seq, d)
        self._chunks: List[tuple] = []  # [(seq, d)] for chunked entries
        self._seq = itertools.count()

    def append(self, d: Delivery):
        if d.chunk is None:
            heapq.heappush(self._simple, (d.arrive_time, next(self._seq), d))
        else:
            self._chunks.append((next(self._seq), d))

    def extend(self, ds):
        for d in ds:
            self.append(d)

    def clear(self):
        self._simple.clear()
        self._chunks.clear()

    def __len__(self):
        return len(self._simple) + len(self._chunks)

    def __iter__(self):
        entries = [(s, d) for _, s, d in self._simple] + self._chunks
        return iter(d for _, d in sorted(entries, key=lambda e: e[0]))


class Endpoint:
    def __init__(self, host_id: str):
        self.host_id = host_id
        self.inbox = Inbox()
        self.memory = MemoryMeter()
        # transfer ids already released to recv: a duplicate chunk or a
        # late retransmit of a completed/superseded transfer is dropped on
        # arrival instead of starting a phantom half-group that would
        # wedge the inbox forever. Bounded LRU — long runs complete
        # millions of transfers, and a straggling retransmit can only be
        # recent (all of a transfer's deliveries are scheduled together)
        self._done_xids: "OrderedDict[int, None]" = OrderedDict()
        self._done_cap = 4096

    def _chunk_groups(self) -> Dict[int, Dict[int, Delivery]]:
        """Live chunk deliveries, deduplicated: transfer id -> {chunk
        index -> earliest copy}. Duplicates (retransmits that crossed the
        original on the wire) and chunks of completed transfers are
        discarded here — they must never double-deliver."""
        groups: Dict[int, Dict[int, Delivery]] = {}
        for _, d in self.inbox._chunks:
            idx, _, xid = d.chunk
            if xid in self._done_xids:
                continue
            got = groups.setdefault(xid, {})
            prev = got.get(idx)
            # prefer the copy that carries the wire (the reassembled
            # message needs it), then the earliest arrival
            if prev is None \
                    or (d.wire is not None and prev.wire is None) \
                    or ((d.wire is None) == (prev.wire is None)
                        and d.arrive_time < prev.arrive_time):
                got[idx] = d
        return groups

    def pop_ready(self, now: float) -> List[Delivery]:
        # chunk-free fast path: pop the (arrive_time, seq) heap — same
        # (time, insertion-order) release order the historical full-inbox
        # scan + stable sort produced, without touching unready entries
        ready = []
        heap = self.inbox._simple
        if _LINEAR_INBOX[0]:
            keep = []
            for t, _, d in sorted(heap, key=lambda e: e[1]):
                (ready if t <= now + 1e-12 else keep).append(d)
            heap.clear()
            for d in keep:
                heapq.heappush(heap, (d.arrive_time,
                                      next(self.inbox._seq), d))
        else:
            while heap and heap[0][0] <= now + 1e-12:
                ready.append(heapq.heappop(heap)[2])
        if self.inbox._chunks:
            keep: List[Delivery] = []
            for xid, got in self._chunk_groups().items():
                ds = list(got.values())
                n_total = ds[0].chunk[1]
                last = max(d.arrive_time for d in ds)
                if len(ds) == n_total and last <= now + 1e-12:
                    wire = next(d.wire for d in ds if d.wire is not None)
                    ready.append(Delivery(ds[0].msg, wire, last))
                    self._done_xids[xid] = None
                    while len(self._done_xids) > self._done_cap:
                        self._done_xids.popitem(last=False)
                else:
                    keep.extend(ds)
            # rebuild with fresh seqs: matches the historical rebuilt-list
            # order (kept chunk groups follow the surviving simples)
            self.inbox._chunks = [(next(self.inbox._seq), d) for d in keep]
        return sorted(ready, key=lambda d: d.arrive_time)

    def pending_times(self) -> List[float]:
        """Message-complete times of everything still in the inbox (a
        chunked transfer counts once, at its last chunk's arrival;
        completed transfers' stray retransmits count never)."""
        times = [t for t, _, _ in self.inbox._simple]
        for got in self._chunk_groups().values():
            times.append(max(d.arrive_time for d in got.values()))
        return times


class Fabric:
    """Shared in-proc fabric; one per FL deployment."""

    def __init__(self, env: Environment, fault_model=None):
        self.env = env
        self.endpoints: Dict[str, Endpoint] = {}
        self.clock = 0.0
        self.stats = defaultdict(float)
        self._chunk_xfer_ids = itertools.count()
        # optional netsim.LinkFaultModel; None = the exact fault-free
        # timing every benchmark/test has always seen (bit-for-bit)
        self.fault_model = fault_model

    def next_transfer_id(self) -> int:
        """Transfer-id allocator: backends take an id up front so the
        fault model's counter-based draws and the endpoint's reassembly
        groups key on the same identity."""
        return next(self._chunk_xfer_ids)

    def register(self, host_id: str) -> Endpoint:
        ep = Endpoint(host_id)
        self.endpoints[host_id] = ep
        return ep

    def advance_to(self, t: float):
        self.clock = max(self.clock, t)

    # -- point-to-point -----------------------------------------------------
    def account(self, nbytes: float, messages: int = 1) -> None:
        """Wire accounting for delivery paths that bypass ``deliver``
        (concurrent broadcasts, the sync server's gather phase, store
        GET legs): one place owns the stat names, so a new bypassing
        call site cannot silently invent its own."""
        self.stats["messages"] += messages
        self.stats["bytes"] += nbytes

    def deliver(self, msg: FLMessage, wire: Optional[WireData],
                start: float, duration: float):
        """Schedule arrival of a message whose transfer takes ``duration``
        starting at ``start`` (already computed by backend/netsim)."""
        arrive = start + duration
        self.endpoints[msg.receiver].inbox.append(Delivery(msg, wire, arrive))
        self.account(wire.nbytes if wire else 0)
        return arrive

    def deliver_chunked(self, msg: FLMessage, wire: WireData,
                        chunk_arrivals: Sequence[float],
                        xid: Optional[int] = None):
        """Chunk-granular delivery of one wire (ChunkStage): each chunk
        lands independently; the receiving endpoint reassembles and
        releases the message at the last chunk's arrival. Returns it."""
        inbox = self.endpoints[msg.receiver].inbox
        n = len(chunk_arrivals)
        if xid is None:
            xid = self.next_transfer_id()
        for i, t in enumerate(chunk_arrivals):
            inbox.append(Delivery(msg, wire if i == n - 1 else None, t,
                                  chunk=(i, n, xid)))
        self.stats["messages"] += 1
        self.stats["chunks"] += n
        self.stats["bytes"] += wire.nbytes
        return max(chunk_arrivals)

    # -- batched concurrent transfers (fluid model) ---------------------
    def deliver_concurrent(self, sends):
        """sends: list of (msg, wire, start, conns). Contention-aware finish
        times via the fluid solver; delivers each on completion. Returns the
        list of finish times. Transfers ride the topology graph's edge for
        each (sender, receiver) pair (LAN-class edges at their declared
        capacity — policy-level IB-vs-TCP resolution lives in the
        backends, which pass explicit ``link_region``s instead)."""
        transfers = []
        for msg, wire, start, conns in sends:
            src = self.env.host(msg.sender)
            dst = self.env.host(msg.receiver)
            edge = self.env.link(msg.sender, msg.receiver)
            transfers.append(Transfer(start=start, src=src, dst=dst,
                                      nbytes=wire.nbytes if wire else 256,
                                      conns=conns, link_region=edge.region,
                                      tag=f"msg{msg.msg_id}"))
        simulate_transfers(transfers)
        finishes = []
        for (msg, wire, start, conns), tr in zip(sends, transfers):
            self.endpoints[msg.receiver].inbox.append(
                Delivery(msg, wire, tr.finish))
            self.stats["messages"] += 1
            self.stats["bytes"] += wire.nbytes if wire else 0
            finishes.append(tr.finish)
        return finishes
