"""In-process transport fabric + memory accounting.

The fabric really delivers WireData between endpoints (so tests exercise
true byte movement, checksums and reconstruction) while charging *simulated*
time from the netsim model. ``MemoryMeter`` tracks logical sender-side
buffer allocations — exact for real payloads, identical accounting for
virtual ones — reproducing Fig 2 (bottom) and Fig 4c.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Callable, Dict, List, Optional

from repro.core.message import FLMessage
from repro.core.netsim import Environment, Transfer, simulate_transfers
from repro.core.serialization import WireData


class MemoryMeter:
    """Logical allocation tracker (bytes). alloc/free pairs bracket buffer
    lifetimes; ``peak`` is what Fig 4c reports."""

    def __init__(self):
        self.current = 0
        self.peak = 0
        self.events: List = []  # (time, current) timeline when time known

    def alloc(self, nbytes: int, now: float = 0.0):
        self.current += int(nbytes)
        self.peak = max(self.peak, self.current)
        self.events.append((now, self.current))

    def free(self, nbytes: int, now: float = 0.0):
        self.current -= int(nbytes)
        self.events.append((now, self.current))

    def reset(self):
        self.current = 0
        self.peak = 0
        self.events.clear()


@dataclasses.dataclass
class Delivery:
    msg: FLMessage
    wire: Optional[WireData]
    arrive_time: float


class Endpoint:
    def __init__(self, host_id: str):
        self.host_id = host_id
        self.inbox: List[Delivery] = []
        self.memory = MemoryMeter()

    def pop_ready(self, now: float) -> List[Delivery]:
        ready = [d for d in self.inbox if d.arrive_time <= now + 1e-12]
        self.inbox = [d for d in self.inbox if d.arrive_time > now + 1e-12]
        return sorted(ready, key=lambda d: d.arrive_time)


class Fabric:
    """Shared in-proc fabric; one per FL deployment."""

    def __init__(self, env: Environment):
        self.env = env
        self.endpoints: Dict[str, Endpoint] = {}
        self.clock = 0.0
        self.stats = defaultdict(float)

    def register(self, host_id: str) -> Endpoint:
        ep = Endpoint(host_id)
        self.endpoints[host_id] = ep
        return ep

    def advance_to(self, t: float):
        self.clock = max(self.clock, t)

    # -- point-to-point -----------------------------------------------------
    def deliver(self, msg: FLMessage, wire: Optional[WireData],
                start: float, duration: float):
        """Schedule arrival of a message whose transfer takes ``duration``
        starting at ``start`` (already computed by backend/netsim)."""
        arrive = start + duration
        self.endpoints[msg.receiver].inbox.append(Delivery(msg, wire, arrive))
        self.stats["messages"] += 1
        self.stats["bytes"] += wire.nbytes if wire else 0
        return arrive

    # -- batched concurrent transfers (fluid model) ---------------------
    def deliver_concurrent(self, sends):
        """sends: list of (msg, wire, start, conns). Contention-aware finish
        times via the fluid solver; delivers each on completion. Returns the
        list of finish times."""
        transfers = []
        for msg, wire, start, conns in sends:
            src = self.env.host(msg.sender)
            dst = self.env.host(msg.receiver)
            transfers.append(Transfer(start=start, src=src, dst=dst,
                                      nbytes=wire.nbytes if wire else 256,
                                      conns=conns, tag=f"msg{msg.msg_id}"))
        simulate_transfers(transfers)
        finishes = []
        for (msg, wire, start, conns), tr in zip(sends, transfers):
            self.endpoints[msg.receiver].inbox.append(
                Delivery(msg, wire, tr.finish))
            self.stats["messages"] += 1
            self.stats["bytes"] += wire.nbytes if wire else 0
            finishes.append(tr.finish)
        return finishes
