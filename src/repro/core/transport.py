"""In-process transport fabric + memory accounting.

The fabric really delivers WireData between endpoints (so tests exercise
true byte movement, checksums and reconstruction) while charging *simulated*
time from the netsim model. ``MemoryMeter`` tracks logical sender-side
buffer allocations — exact for real payloads, identical accounting for
virtual ones — reproducing Fig 2 (bottom) and Fig 4c.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.message import FLMessage
from repro.core.netsim import Environment, Transfer, simulate_transfers
from repro.core.serialization import WireData


class MemoryMeter:
    """Logical allocation tracker (bytes). alloc/free pairs bracket buffer
    lifetimes; ``peak`` is what Fig 4c reports.

    Events carry *simulated* timestamps that are routinely issued out of
    call order (a backend allocs at a future serialize-start and frees at
    an even-further-future arrival before the next call allocs at an
    earlier time), so ``peak`` is computed from the time-sorted event
    timeline — a call-order running maximum both overstates sequential
    lifetimes that merely *appear* nested in call order and understates
    genuinely overlapping ones."""

    def __init__(self):
        self.current = 0
        self.events: List = []  # (time, +/- delta bytes) in call order

    def alloc(self, nbytes: int, now: float = 0.0):
        self.current += int(nbytes)
        self.events.append((float(now), int(nbytes)))

    def free(self, nbytes: int, now: float = 0.0):
        self.current -= int(nbytes)
        self.events.append((float(now), -int(nbytes)))

    @property
    def peak(self) -> int:
        """Max concurrent bytes over the time-sorted timeline (stable sort:
        same-timestamp events keep call order)."""
        cur = mx = 0
        for _, delta in sorted(self.events, key=lambda e: e[0]):
            cur += delta
            if cur > mx:
                mx = cur
        return mx

    def reset(self):
        self.current = 0
        self.events.clear()


@dataclasses.dataclass
class Delivery:
    msg: FLMessage
    wire: Optional[WireData]
    arrive_time: float
    # chunk-granular deliveries (ChunkStage wires): (index, total,
    # transfer id). Only the last chunk carries the wire; the endpoint
    # reassembles and releases the message when every chunk has landed.
    # The transfer id — not the msg_id — is the grouping key, so
    # retransmitting the same message never wedges two half-sets together.
    chunk: Optional[tuple] = None


class Endpoint:
    def __init__(self, host_id: str):
        self.host_id = host_id
        self.inbox: List[Delivery] = []
        self.memory = MemoryMeter()

    def pop_ready(self, now: float) -> List[Delivery]:
        ready, keep = [], []
        partial: dict = {}  # transfer id -> chunk deliveries
        for d in self.inbox:
            if d.chunk is not None:
                partial.setdefault(d.chunk[2], []).append(d)
            elif d.arrive_time <= now + 1e-12:
                ready.append(d)
            else:
                keep.append(d)
        for ds in partial.values():
            n_total = ds[0].chunk[1]
            last = max(d.arrive_time for d in ds)
            if len(ds) == n_total and last <= now + 1e-12:
                wire = next(d.wire for d in ds if d.wire is not None)
                ready.append(Delivery(ds[0].msg, wire, last))
            else:
                keep.extend(ds)
        self.inbox = keep
        return sorted(ready, key=lambda d: d.arrive_time)

    def pending_times(self) -> List[float]:
        """Message-complete times of everything still in the inbox (a
        chunked transfer counts once, at its last chunk's arrival)."""
        times, last_chunk = [], {}
        for d in self.inbox:
            if d.chunk is None:
                times.append(d.arrive_time)
            else:
                xid = d.chunk[2]
                last_chunk[xid] = max(last_chunk.get(xid, -1e18),
                                      d.arrive_time)
        return times + list(last_chunk.values())


class Fabric:
    """Shared in-proc fabric; one per FL deployment."""

    def __init__(self, env: Environment):
        self.env = env
        self.endpoints: Dict[str, Endpoint] = {}
        self.clock = 0.0
        self.stats = defaultdict(float)
        self._chunk_xfer_ids = itertools.count()

    def register(self, host_id: str) -> Endpoint:
        ep = Endpoint(host_id)
        self.endpoints[host_id] = ep
        return ep

    def advance_to(self, t: float):
        self.clock = max(self.clock, t)

    # -- point-to-point -----------------------------------------------------
    def deliver(self, msg: FLMessage, wire: Optional[WireData],
                start: float, duration: float):
        """Schedule arrival of a message whose transfer takes ``duration``
        starting at ``start`` (already computed by backend/netsim)."""
        arrive = start + duration
        self.endpoints[msg.receiver].inbox.append(Delivery(msg, wire, arrive))
        self.stats["messages"] += 1
        self.stats["bytes"] += wire.nbytes if wire else 0
        return arrive

    def deliver_chunked(self, msg: FLMessage, wire: WireData,
                        chunk_arrivals: Sequence[float]):
        """Chunk-granular delivery of one wire (ChunkStage): each chunk
        lands independently; the receiving endpoint reassembles and
        releases the message at the last chunk's arrival. Returns it."""
        inbox = self.endpoints[msg.receiver].inbox
        n = len(chunk_arrivals)
        xid = next(self._chunk_xfer_ids)
        for i, t in enumerate(chunk_arrivals):
            inbox.append(Delivery(msg, wire if i == n - 1 else None, t,
                                  chunk=(i, n, xid)))
        self.stats["messages"] += 1
        self.stats["chunks"] += n
        self.stats["bytes"] += wire.nbytes
        return max(chunk_arrivals)

    # -- batched concurrent transfers (fluid model) ---------------------
    def deliver_concurrent(self, sends):
        """sends: list of (msg, wire, start, conns). Contention-aware finish
        times via the fluid solver; delivers each on completion. Returns the
        list of finish times."""
        transfers = []
        for msg, wire, start, conns in sends:
            src = self.env.host(msg.sender)
            dst = self.env.host(msg.receiver)
            transfers.append(Transfer(start=start, src=src, dst=dst,
                                      nbytes=wire.nbytes if wire else 256,
                                      conns=conns, tag=f"msg{msg.msg_id}"))
        simulate_transfers(transfers)
        finishes = []
        for (msg, wire, start, conns), tr in zip(sends, transfers):
            self.endpoints[msg.receiver].inbox.append(
                Delivery(msg, wire, tr.finish))
            self.stats["messages"] += 1
            self.stats["bytes"] += wire.nbytes if wire else 0
            finishes.append(tr.finish)
        return finishes
