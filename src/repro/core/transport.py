"""In-process transport fabric + memory accounting.

The fabric really delivers WireData between endpoints (so tests exercise
true byte movement, checksums and reconstruction) while charging *simulated*
time from the netsim model. ``MemoryMeter`` tracks logical sender-side
buffer allocations — exact for real payloads, identical accounting for
virtual ones — reproducing Fig 2 (bottom) and Fig 4c.

Multi-tenancy: the fabric is a shared substrate for N concurrent FL jobs.
``FabricSpec`` declares the admission policy and whether declared edges
are shared contended pipes; ``Fabric.job`` hands out ``JobHandle`` tenant
ids that namespace endpoints, transfer-id allocation and stats. The
default (anonymous) tenant plus ``FabricSpec()`` is bit-identical to the
historical single-job fabric — every legacy call site keeps its exact
keys, ids and timing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import itertools
import math
from collections import OrderedDict, defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.message import FLMessage
from repro.core.netsim import Environment, Transfer, simulate_transfers
from repro.core.serialization import WireData

# Control-plane accounting rule: a metadata-only delivery (no wire)
# still moves a compact record — ~256 B, the same figure the fluid path
# and the backends' meta encodings have always used. Both ``deliver``
# and ``deliver_concurrent`` charge it (historically ``deliver`` charged
# 0 while ``deliver_concurrent`` *timed* 256 but charged 0 — one rule
# now, regression-tested in tests/test_multitenant.py).
CTRL_BYTES = 256

# ``Endpoint.pop_ready`` baseline switch, mirroring
# ``netsim.scalar_transfers``: >0 forces the historical full-inbox scan
# instead of the heap fast path (identical results, O(inbox) per recv).
_LINEAR_INBOX = [0]


@contextlib.contextmanager
def linear_inbox():
    """Force the pre-heap O(inbox) ``pop_ready`` scan — the measurable
    un-vectorized baseline for the fig11 engine-speedup gate."""
    _LINEAR_INBOX[0] += 1
    try:
        yield
    finally:
        _LINEAR_INBOX[0] -= 1


class MemoryMeter:
    """Logical allocation tracker (bytes). alloc/free pairs bracket buffer
    lifetimes; ``peak`` is what Fig 4c reports.

    Events carry *simulated* timestamps that are routinely issued out of
    call order (a backend allocs at a future serialize-start and frees at
    an even-further-future arrival before the next call allocs at an
    earlier time), so ``peak`` is computed from the time-sorted event
    timeline — a call-order running maximum both overstates sequential
    lifetimes that merely *appear* nested in call order and understates
    genuinely overlapping ones."""

    def __init__(self):
        self.current = 0
        self.events: List = []  # (time, +/- delta bytes) in call order

    def alloc(self, nbytes: int, now: float = 0.0):
        self.current += int(nbytes)
        self.events.append((float(now), int(nbytes)))

    def free(self, nbytes: int, now: float = 0.0):
        self.current -= int(nbytes)
        self.events.append((float(now), -int(nbytes)))

    @property
    def peak(self) -> int:
        """Max concurrent bytes over the time-sorted timeline (stable sort:
        same-timestamp events keep call order)."""
        cur = mx = 0
        for _, delta in sorted(self.events, key=lambda e: e[0]):
            cur += delta
            if cur > mx:
                mx = cur
        return mx

    def reset(self):
        self.current = 0
        self.events.clear()


@dataclasses.dataclass
class Delivery:
    msg: FLMessage
    wire: Optional[WireData]
    arrive_time: float
    # chunk-granular deliveries (ChunkStage wires): (index, total,
    # transfer id). Only the last chunk carries the wire; the endpoint
    # reassembles and releases the message when every chunk has landed.
    # The transfer id — not the msg_id — is the grouping key, so
    # retransmitting the same message never wedges two half-sets together.
    chunk: Optional[tuple] = None


class Inbox:
    """Pending deliveries with a (arrive_time, seq) heap over the
    chunk-free entries, so ``pop_ready`` costs O(ready · log n) instead
    of re-scanning the whole inbox on every recv — the scan was the
    scheduler's top hot spot at 1k+ clients (O(fleet²) overall).

    Keeps the historical list surface (append/extend/clear/iter/len;
    iteration yields insertion order) so fault tests can still inject
    and inspect raw deliveries. Chunked deliveries stay in a plain list:
    they need group-wise reassembly anyway and only exist when
    ``chunk_mb`` is set."""

    def __init__(self):
        self._simple: List[tuple] = []  # heap of (arrive_time, seq, d)
        self._chunks: List[tuple] = []  # [(seq, d)] for chunked entries
        self._seq = itertools.count()

    def append(self, d: Delivery):
        if d.chunk is None:
            heapq.heappush(self._simple, (d.arrive_time, next(self._seq), d))
        else:
            self._chunks.append((next(self._seq), d))

    def extend(self, ds):
        for d in ds:
            self.append(d)

    def clear(self):
        self._simple.clear()
        self._chunks.clear()

    def __len__(self):
        return len(self._simple) + len(self._chunks)

    def __iter__(self):
        entries = [(s, d) for _, s, d in self._simple] + self._chunks
        return iter(d for _, d in sorted(entries, key=lambda e: e[0]))


class Endpoint:
    def __init__(self, host_id: str):
        self.host_id = host_id
        self.inbox = Inbox()
        self.memory = MemoryMeter()
        # transfer ids already released to recv: a duplicate chunk or a
        # late retransmit of a completed/superseded transfer is dropped on
        # arrival instead of starting a phantom half-group that would
        # wedge the inbox forever. Bounded LRU — long runs complete
        # millions of transfers, and a straggling retransmit can only be
        # recent (all of a transfer's deliveries are scheduled together)
        self._done_xids: "OrderedDict[int, None]" = OrderedDict()
        self._done_cap = 4096

    def _chunk_groups(self) -> Dict[int, Dict[int, Delivery]]:
        """Live chunk deliveries, deduplicated: transfer id -> {chunk
        index -> earliest copy}. Duplicates (retransmits that crossed the
        original on the wire) and chunks of completed transfers are
        discarded here — they must never double-deliver."""
        groups: Dict[int, Dict[int, Delivery]] = {}
        for _, d in self.inbox._chunks:
            idx, _, xid = d.chunk
            if xid in self._done_xids:
                continue
            got = groups.setdefault(xid, {})
            prev = got.get(idx)
            # prefer the copy that carries the wire (the reassembled
            # message needs it), then the earliest arrival
            if prev is None \
                    or (d.wire is not None and prev.wire is None) \
                    or ((d.wire is None) == (prev.wire is None)
                        and d.arrive_time < prev.arrive_time):
                got[idx] = d
        return groups

    def pop_ready(self, now: float) -> List[Delivery]:
        # chunk-free fast path: pop the (arrive_time, seq) heap — same
        # (time, insertion-order) release order the historical full-inbox
        # scan + stable sort produced, without touching unready entries
        ready = []
        heap = self.inbox._simple
        if _LINEAR_INBOX[0]:
            keep = []
            for t, _, d in sorted(heap, key=lambda e: e[1]):
                (ready if t <= now + 1e-12 else keep).append(d)
            heap.clear()
            for d in keep:
                heapq.heappush(heap, (d.arrive_time,
                                      next(self.inbox._seq), d))
        else:
            while heap and heap[0][0] <= now + 1e-12:
                ready.append(heapq.heappop(heap)[2])
        if self.inbox._chunks:
            keep: List[Delivery] = []
            for xid, got in self._chunk_groups().items():
                ds = list(got.values())
                n_total = ds[0].chunk[1]
                last = max(d.arrive_time for d in ds)
                if len(ds) == n_total and last <= now + 1e-12:
                    wire = next(d.wire for d in ds if d.wire is not None)
                    ready.append(Delivery(ds[0].msg, wire, last))
                    self._done_xids[xid] = None
                    while len(self._done_xids) > self._done_cap:
                        self._done_xids.popitem(last=False)
                else:
                    keep.extend(ds)
            # rebuild with fresh seqs: matches the historical rebuilt-list
            # order (kept chunk groups follow the surviving simples)
            self.inbox._chunks = [(next(self.inbox._seq), d) for d in keep]
        return sorted(ready, key=lambda d: d.arrive_time)

    def pending_times(self) -> List[float]:
        """Message-complete times of everything still in the inbox (a
        chunked transfer counts once, at its last chunk's arrival;
        completed transfers' stray retransmits count never)."""
        times = [t for t, _, _ in self.inbox._simple]
        for got in self._chunk_groups().values():
            times.append(max(d.arrive_time for d in got.values()))
        return times


_POLICIES = ("fifo", "priority", "fair-share")


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """How a multi-tenant fabric arbitrates its shared substrate.

    ``policy`` decides whose transfers get capacity when a shared edge
    saturates (all three are work-conserving fluid approximations over
    the edge's reservation ledger):

    * ``fifo``       — first-come-first-served: earlier reservations
      hold their rate; later arrivals take what is left.
    * ``priority``   — strict priority: a transfer contends only with
      its own job's traffic and foreign traffic of >= its job's
      priority. Already-granted lower-priority reservations keep their
      promised times (no revocation), so a saturated edge can briefly
      overcommit when a high-priority job bursts in — the documented
      fluid approximation.
    * ``fair-share`` — each job i present on the edge is guaranteed
      ``capacity * w_i / Σw`` over the present jobs' admission weights
      (``JobHandle.weight``; all weights 1.0 reduces to capacity/k,
      bit-identically); spare capacity from idle jobs is usable
      (work-conserving).

    ``shared_links=False`` (the default) disables the pipe ledger
    entirely: every dispatch path computes the exact pre-tenancy
    arithmetic, which is what keeps single-tenant runs bit-identical."""
    policy: str = "fifo"
    shared_links: bool = False

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"FabricSpec.policy: unknown policy "
                             f"'{self.policy}'; choose from {_POLICIES}")


@dataclasses.dataclass
class JobHandle:
    """One tenant of a multi-tenant fabric.

    Threading this through a backend namespaces its endpoints, transfer
    ids and stats under ``name``. ``priority`` matters only under the
    ``priority`` admission policy (higher = more important);
    ``weight`` only under ``fair-share`` (a job's guaranteed slice of a
    contended edge is ``capacity * weight / Σweights`` over the jobs
    present on it)."""
    fabric: "Fabric"
    name: str
    priority: int = 0
    weight: float = 1.0

    @property
    def stats(self) -> defaultdict:
        """This job's wire-accounting view (sums across jobs — including
        the default tenant's — equal the fabric's legacy globals)."""
        return self.fabric.stats_for(self.name)


class _EdgePipe:
    """Reservation ledger for one directed shared edge.

    Transfers from *different* fluid calls (different jobs, different
    event times) contend here: each granted transmission appends
    ``(t0, t1, rate, prio, job)`` segments, and later requests walk the
    piecewise-constant residual capacity under the fabric's admission
    policy. This is what makes co-located jobs actually share a
    bottleneck — without it every simulate call rides its own private
    copy of the edge."""

    def __init__(self, capacity: float, policy: str,
                 weight_of: Optional[Callable[[str], float]] = None):
        self.capacity = float(capacity)
        self.policy = policy
        # fair-share admission weights, resolved per job name at query
        # time (the fabric passes its JobHandle table's lookup)
        self.weight_of = weight_of or (lambda job: 1.0)
        self.resv: List[Tuple[float, float, float, int, str]] = []

    # -- queries ---------------------------------------------------------
    def available(self, t: float, prio: int = 0, job: str = "") -> float:
        """Rate grantable to a (prio, job) request at time ``t``."""
        cap = self.capacity
        total = own = visible = 0.0
        others = set()
        for (a, b, r, p, j) in self.resv:
            if a <= t < b:
                total += r
                if j == job:
                    own += r
                else:
                    others.add(j)
                    if p >= prio:
                        visible += r
        if self.policy == "priority":
            return max(cap - visible - own, 0.0)
        if self.policy == "fair-share":
            # weighted fair share over the jobs present on this edge:
            # guaranteed slice = cap * w_own / Σw (all-1.0 weights give
            # exactly cap / k — multiplying by w_own == 1.0 is an IEEE
            # identity, so unweighted runs stay bit-identical)
            w_own = self.weight_of(job)
            w_sum = w_own + sum(self.weight_of(j) for j in others)
            return max(cap - total, cap * w_own / w_sum - own, 0.0)
        return max(cap - total, 0.0)  # fifo

    def _next_boundary(self, t: float) -> float:
        nxt = math.inf
        for (a, b, _, _, _) in self.resv:
            if a > t + 1e-12:
                nxt = min(nxt, a)
            elif b > t + 1e-12:
                nxt = min(nxt, b)
        return nxt

    # -- mutations -------------------------------------------------------
    def reserve(self, t0: float, t1: float, rate: float, prio: int,
                job: str):
        if t1 > t0 and rate > 0.0:
            self.resv.append((float(t0), float(t1), float(rate),
                              int(prio), job))

    def transmit(self, depart: float, nbytes: float, want: float,
                 prio: int, job: str) -> float:
        """Drain ``nbytes`` at up to ``want`` bytes/s starting at
        ``depart``, taking whatever the policy grants per segment;
        returns the finish time and records the granted segments."""
        return self._walk(depart, nbytes, want, prio, job, record=True)

    def drain_rate(self, t: float, nbytes: float, want: float,
                   prio: int, job: str) -> float:
        """Equivalent average rate a queued drain of ``nbytes`` would
        achieve starting at ``t`` — the walk without recording. This is
        what the fluid path hands the solver as the edge's aggregate
        budget: a wave arriving behind another tenant's reservation
        *queues* (finishes when the drain would), it is never starved to
        a zero instantaneous-headroom rate."""
        if nbytes <= 0.0:
            return want
        fin = self._walk(t, nbytes, want, prio, job, record=False)
        return nbytes / max(fin - t, 1e-12)

    def _walk(self, depart: float, nbytes: float, want: float,
              prio: int, job: str, record: bool) -> float:
        t = float(depart)
        remaining = float(nbytes)
        segs: List[Tuple[float, float, float]] = []
        while remaining > 1e-9:
            rate = min(want, self.available(t, prio, job))
            nxt = self._next_boundary(t)
            if rate <= 1e-9:
                if math.isinf(nxt):  # nothing ever frees up: take want
                    rate = want      # (defensive; ledgers are finite)
                else:
                    t = nxt
                    continue
            if math.isinf(nxt) or t + remaining / rate <= nxt + 1e-12:
                dt = remaining / rate
                segs.append((t, t + dt, rate))
                t += dt
                remaining = 0.0
            else:
                segs.append((t, nxt, rate))
                remaining -= rate * (nxt - t)
                t = nxt
        if record:
            for (a, b, r) in segs:
                self.reserve(a, b, r, prio, job)
            # bounded ledger: reservations a sim-hour older than this
            # departure cannot intersect any later walk of consequence
            if len(self.resv) > 512:
                cut = depart - 3600.0
                self.resv = [rv for rv in self.resv if rv[1] > cut]
        return t


class Fabric:
    """Shared in-proc fabric; one per deployment, N tenant jobs."""

    def __init__(self, env: Environment, fault_model=None,
                 spec: Optional[FabricSpec] = None):
        self.env = env
        self.spec = spec or FabricSpec()
        self.endpoints: Dict[str, Endpoint] = {}
        self.clock = 0.0
        self.stats = defaultdict(float)
        self.job_stats: Dict[str, defaultdict] = {}
        self.jobs: Dict[str, JobHandle] = {}
        self._chunk_xfer_ids = itertools.count()
        # per-job transfer-id counters: each tenant's ids start at 0, so
        # a job's counter-based fault draws are identical whether it
        # runs solo or co-scheduled (the "" entry *is* the legacy
        # counter — default-tenant ids are bit-identical)
        self._xids: Dict[str, itertools.count] = {"": self._chunk_xfer_ids}
        self._pipes: Dict[Tuple[str, str], _EdgePipe] = {}
        # optional netsim.LinkFaultModel; None = the exact fault-free
        # timing every benchmark/test has always seen (bit-for-bit)
        self.fault_model = fault_model

    # -- tenancy ------------------------------------------------------------
    def job(self, name: str, priority: int = 0,
            weight: float = 1.0) -> JobHandle:
        """Register (or fetch) a tenant. Job names namespace endpoint
        keys as ``{name}::{host_id}``; the empty name is the implicit
        default tenant every legacy call site already uses."""
        if "::" in name:
            raise ValueError(f"job name {name!r} may not contain '::'")
        if not weight > 0:
            raise ValueError(f"job weight must be > 0 (got {weight})")
        h = self.jobs.get(name)
        if h is None:
            h = self.jobs[name] = JobHandle(self, name, priority, weight)
            self.stats_for(name)  # the per-job stats view exists from birth
        return h

    def _job_weight(self, job: str) -> float:
        """Fair-share admission weight of one tenant (unknown names —
        including the implicit default tenant — weigh 1.0)."""
        h = self.jobs.get(job)
        return h.weight if h is not None else 1.0

    def stats_for(self, job: str = "") -> defaultdict:
        js = self.job_stats.get(job)
        if js is None:
            js = self.job_stats[job] = defaultdict(float)
        return js

    @staticmethod
    def endpoint_key(host_id: str, job: str = "") -> str:
        return host_id if not job else f"{job}::{host_id}"

    def endpoint_for(self, host_id: str, job: str = "") -> Optional[Endpoint]:
        return self.endpoints.get(self.endpoint_key(host_id, job))

    def _ep(self, host_id: str, job: str = "") -> Endpoint:
        """Delivery-side endpoint lookup. The default tenant keeps the
        historical strict ``endpoints[host_id]`` (KeyError on unknown
        hosts); named tenants lazily register — a relay channel spun up
        mid-run by a strategy must not crash its job."""
        if not job:
            return self.endpoints[host_id]
        ep = self.endpoints.get(f"{job}::{host_id}")
        return ep if ep is not None else self.register(host_id, job=job)

    def next_transfer_id(self, job: str = "") -> int:
        """Transfer-id allocator: backends take an id up front so the
        fault model's counter-based draws and the endpoint's reassembly
        groups key on the same identity. Per-job counters — a tenant's
        id stream does not depend on who it is co-scheduled with."""
        c = self._xids.get(job)
        if c is None:
            c = self._xids[job] = itertools.count()
        return next(c)

    def register(self, host_id: str, job: str = "") -> Endpoint:
        ep = Endpoint(host_id)
        self.endpoints[self.endpoint_key(host_id, job)] = ep
        return ep

    def advance_to(self, t: float):
        self.clock = max(self.clock, t)

    # -- shared-bottleneck pipes (FabricSpec.shared_links) -------------------
    def _pipe(self, src_id: str, dst_id: str, capacity: float) -> _EdgePipe:
        key = (src_id, dst_id)
        p = self._pipes.get(key)
        if p is None:
            p = self._pipes[key] = _EdgePipe(capacity, self.spec.policy,
                                             weight_of=self._job_weight)
        return p

    def link_transmit(self, src_id: str, dst_id: str, depart: float,
                      nbytes: float, rate: float, *,
                      capacity: Optional[float] = None, job: str = "",
                      prio: int = 0) -> float:
        """One analytic transmission through the (src, dst) pipe: the
        finish time under whatever other tenants have already reserved.
        With ``shared_links`` off this is exactly ``depart + nbytes /
        rate`` — the pre-tenancy arithmetic, bit for bit."""
        if not self.spec.shared_links:
            return depart + nbytes / rate
        pipe = self._pipe(src_id, dst_id,
                          rate if capacity is None else capacity)
        return pipe.transmit(depart, nbytes, rate, prio, job)

    def link_headroom(self, src_id: str, dst_id: str, t: float, *,
                      capacity: float, job: str = "", prio: int = 0,
                      nbytes: float = 0.0) -> float:
        """Aggregate edge capacity a fluid wave may assume at ``t``
        (full capacity when pipes are off). With ``nbytes`` the answer
        is the *queueing-equivalent* average rate over the drain of that
        many bytes — a flow behind another tenant's reservation waits
        its turn rather than being starved by the instantaneous
        residual; without it, the instantaneous policy headroom."""
        if not self.spec.shared_links:
            return capacity
        pipe = self._pipe(src_id, dst_id, capacity)
        if nbytes > 0.0:
            return min(pipe.drain_rate(t, nbytes, capacity, prio, job),
                       capacity)
        return min(pipe.available(t, prio, job), capacity)

    def link_reserve(self, src_id: str, dst_id: str, t0: float, t1: float,
                     rate: float, *, capacity: float, job: str = "",
                     prio: int = 0) -> None:
        """Publish a fluid-solved transfer's occupancy so later tenants
        see it. No-op when pipes are off."""
        if self.spec.shared_links:
            self._pipe(src_id, dst_id, capacity).reserve(t0, t1, rate,
                                                         prio, job)

    # -- point-to-point -----------------------------------------------------
    def account(self, nbytes: float = 0.0, messages: int = 1, *,
                chunks: int = 0, retransmits: int = 0,
                transfers_failed: int = 0, cross_job_hits: int = 0,
                job: str = "") -> None:
        """Wire accounting — the ONLY place fabric stats are mutated
        (scripts/check_stats_discipline.py enforces this): delivery
        paths, bypassing call sites (concurrent broadcasts, the sync
        server's gather phase, store GET legs) and the backends' fault
        counters all come through here, so per-job views stay an exact
        decomposition of the legacy globals."""
        for target in (self.stats, self.stats_for(job)):
            target["messages"] += messages
            target["bytes"] += nbytes
            if chunks:
                target["chunks"] += chunks
            if retransmits:
                target["retransmits"] += retransmits
            if transfers_failed:
                target["transfers_failed"] += transfers_failed
            if cross_job_hits:
                target["cross_job_hits"] += cross_job_hits

    def deliver(self, msg: FLMessage, wire: Optional[WireData],
                start: float, duration: float, *, job: str = ""):
        """Schedule arrival of a message whose transfer takes ``duration``
        starting at ``start`` (already computed by backend/netsim)."""
        arrive = start + duration
        self._ep(msg.receiver, job).inbox.append(Delivery(msg, wire, arrive))
        self.account(wire.nbytes if wire else CTRL_BYTES, job=job)
        return arrive

    def deliver_chunked(self, msg: FLMessage, wire: WireData,
                        chunk_arrivals: Sequence[float],
                        xid: Optional[int] = None, *, job: str = ""):
        """Chunk-granular delivery of one wire (ChunkStage): each chunk
        lands independently; the receiving endpoint reassembles and
        releases the message at the last chunk's arrival. Returns it."""
        inbox = self._ep(msg.receiver, job).inbox
        n = len(chunk_arrivals)
        if xid is None:
            xid = self.next_transfer_id(job)
        for i, t in enumerate(chunk_arrivals):
            inbox.append(Delivery(msg, wire if i == n - 1 else None, t,
                                  chunk=(i, n, xid)))
        self.account(wire.nbytes, chunks=n, job=job)
        return max(chunk_arrivals)

    # -- batched concurrent transfers (fluid model) ---------------------
    def deliver_concurrent(self, sends, *, job: str = "", prio: int = 0):
        """sends: list of (msg, wire, start, conns). Contention-aware finish
        times via the fluid solver; delivers each on completion. Returns the
        list of finish times. Transfers ride the topology graph's edge for
        each (sender, receiver) pair (LAN-class edges at their declared
        capacity — policy-level IB-vs-TCP resolution lives in the
        backends, which pass explicit ``link_region``s instead). Under
        ``shared_links`` each transfer is clamped to its edge pipe's
        residual capacity and its occupancy is published for later
        tenants."""
        shared = self.spec.shared_links
        transfers = []
        for msg, wire, start, conns in sends:
            src = self.env.host(msg.sender)
            dst = self.env.host(msg.receiver)
            edge = self.env.link(msg.sender, msg.receiver)
            tr = Transfer(start=start, src=src, dst=dst,
                          nbytes=wire.nbytes if wire else CTRL_BYTES,
                          conns=conns, link_region=edge.region,
                          tag=f"msg{msg.msg_id}")
            if shared:
                cap = edge.region.bw_multi
                tr.edge_key = (msg.sender, msg.receiver)
                tr.edge_cap = self.link_headroom(
                    msg.sender, msg.receiver, start + edge.region.latency,
                    capacity=cap, job=job, prio=prio, nbytes=tr.nbytes)
            transfers.append(tr)
        simulate_transfers(transfers)
        finishes = []
        for (msg, wire, start, conns), tr in zip(sends, transfers):
            self._ep(msg.receiver, job).inbox.append(
                Delivery(msg, wire, tr.finish))
            self.account(wire.nbytes if wire else CTRL_BYTES, job=job)
            if shared:
                begin = tr.start + tr.latency()
                span = tr.finish - begin
                if span > 0:
                    self.link_reserve(
                        msg.sender, msg.receiver, begin, tr.finish,
                        tr.nbytes / span,
                        capacity=self.env.link(
                            msg.sender, msg.receiver).region.bw_multi,
                        job=job, prio=prio)
            finishes.append(tr.finish)
        return finishes
