"""FL message model (paper §III-A): every message = small metadata record +
(optionally large) parameter payload.

Payload flavours:
* ``TensorPayload``  — a real JAX/numpy pytree (tests + live FL training).
* ``PackedPayload``  — quantised (int8+scales) pytree from compression/.
* ``VirtualPayload`` — sized-but-unmaterialised stand-in used by the
  paper-scale benchmarks (1.24 GB ViT payloads shouldn't be memcpy'd
  thousands of times on this CPU container; simulated time/memory are
  charged from ``nbytes`` identically either way).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import jax
import numpy as np

_mid = itertools.count()


def tree_nbytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * jax.numpy.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


@dataclasses.dataclass
class TensorPayload:
    tree: Any

    @property
    def nbytes(self) -> int:
        return tree_nbytes(self.tree)

    def fingerprint(self) -> int:
        leaves = jax.tree.leaves(self.tree)
        if not leaves:
            return 0
        first = np.asarray(leaves[0]).reshape(-1)
        return hash((len(leaves), self.nbytes,
                     float(first[0]) if first.size else 0.0))


@dataclasses.dataclass
class PackedPayload:
    """Compressed pytree: either q/scales/block/orig_len (qsgd int8 blocks,
    repro.kernels.ops) or idx/vals/n (top-k sparsification)."""
    packed: dict

    @property
    def nbytes(self) -> int:
        if "idx" in self.packed:  # top-k: int32 indices + f32 values
            return int(np.size(self.packed["idx"])) * 4 + \
                int(np.size(self.packed["vals"])) * 4
        return int(np.size(self.packed["q"])) + \
            int(np.size(self.packed["scales"])) * 4

    def fingerprint(self) -> int:
        orig = self.packed.get("orig_len", self.packed.get("n", 0))
        return hash(("packed", self.nbytes, int(orig)))


@dataclasses.dataclass
class VirtualPayload:
    size: int
    tag: str = ""

    @property
    def nbytes(self) -> int:
        return self.size

    def fingerprint(self) -> int:
        return hash(("virtual", self.size, self.tag))


@dataclasses.dataclass
class FLMessage:
    msg_type: str  # init | model_sync | client_update | control | ack
    sender: str
    receiver: str
    round: int = 0
    payload: Optional[Any] = None  # one of the payload classes
    metadata: dict = dataclasses.field(default_factory=dict)
    msg_id: int = dataclasses.field(default_factory=lambda: next(_mid))

    @property
    def payload_nbytes(self) -> int:
        return 0 if self.payload is None else self.payload.nbytes

    def meta_only(self, extra: Optional[dict] = None) -> "FLMessage":
        md = dict(self.metadata)
        if extra:
            md.update(extra)
        return dataclasses.replace(self, payload=None, metadata=md)
