"""ChannelStack: the composable wire pipeline every backend drives.

A ``Channel`` owns an ordered stack of ``WireStage`` objects and exposes a
single ``encode`` / ``decode`` pair. Backends no longer call serializers
directly — the three formerly copy-pasted serialize paths
(``CommBackend.isend``, ``CommBackend._broadcast_transfers``,
``GrpcS3Backend._upload``) all drive the same stack, which is the
insertion point the repo lacked for gradient compression and chunked
pipelining (paper: compression is orthogonal to backend choice, QSGD /
Alistarh et al. 2017; survey arXiv:2405.20431 frames transport and
compression as separable, composable layers).

Stages and the domains they act on:

* ``CompressStage``   (payload domain) — wraps a ``compression.stages``
  codec (qsgd / topk) with per-peer error-feedback state. Quantisation
  needs tensor semantics (and the EF residual), so it transforms the
  *payload* before serialization. Charges simulated codec time plus the
  materialised compressed buffer's exact bytes.
* ``SerializeStage``  (payload -> wire) — the per-backend serializer
  (copy vs zero-copy view); charges the serializer's calibrated
  throughput on the bytes it actually writes (post-compression).
* ``WireCompressStage`` (wire domain) — a byte codec (zlib-family) over
  the serialized wire itself: lossless, stateless, composable with the
  payload codecs; deflates real buffers for real and scales virtual
  wires by the codec's modelled ratio.
* ``ChunkStage``      (wire domain) — splits large wires into fixed-size
  chunks so encode overlaps the network transfer; the transport delivers
  chunk-granularly (transport.Fabric.deliver_chunked) and backends
  pipeline chunk i's transfer behind chunk i-1's.

Encode applies payload-domain stages, then the serialize stage, then wire
stages; decode inverts the provenance recorded on ``WireData.stages``
right-to-left, so a receiver decodes by *what the wire says was done to
it*, never by its own configuration (AUTO routing, mixed fleets, and the
object store all stay coherent). With the default ``[SerializeStage]``
stack every byte and every simulated second is identical to the
pre-stack code — regression-tested.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.serialization import (BaseSerializer, SERIALIZERS, WireData,
                                      decode_wire)

MB = 1024 ** 2


@dataclasses.dataclass
class Encoded:
    """Result of one ``Channel.encode``: the final wire plus the stack's
    itemised simulated-time / memory charges."""
    wire: WireData
    cost_s: float  # total sender-side encode time (all stages)
    extra_alloc: int = 0  # stage-materialised bytes beyond the policy's own
    # chunk plan: (chunk_nbytes, encode-complete offset from encode start).
    # None when the wire rides whole.
    chunks: Optional[List[Tuple[int, float]]] = None
    charges: List[Tuple[str, float, int]] = dataclasses.field(
        default_factory=list)  # (stage name, seconds, alloc bytes)


class WireStage:
    """One pipeline stage. ``phase`` orders application on encode:
    payload-domain stages (0) run before the serialize stage (1), wire
    stages (2) after. Decode inverts recorded provenance right-to-left."""

    name = "stage"
    phase = 1

    def signature(self) -> str:
        return self.name


class SerializeStage(WireStage):
    """payload -> WireData through a calibrated serializer."""

    name = "serialize"
    phase = 1

    def __init__(self, serializer: BaseSerializer):
        self.serializer = serializer

    def signature(self) -> str:
        return self.serializer.name


class CompressStage(WireStage):
    """Payload-domain compression with per-peer error feedback.

    The residual state is keyed by the destination peer so concurrent
    streams (one per receiver, or one per relay WAN hop) each keep their
    own unbiased feedback loop; ``peer=None`` uses one shared stream
    (broadcast / object-store uploads, where one wire serves everyone)."""

    name = "compress"
    phase = 0

    def __init__(self, codec, *, error_feedback: bool = True):
        from repro.compression.stages import make_codec
        self.codec = make_codec(codec)
        self.error_feedback = error_feedback
        self._state: dict = {}  # peer -> residual QuantState

    def signature(self) -> str:
        return self.codec.signature()

    def resolve_state(self, payload, peer):
        """The pre-compress state rule, factored so the batched path
        applies exactly it: existing residual if it fits, else fresh."""
        state = self._state.get(peer)
        if self.error_feedback and not self.codec.state_matches(state,
                                                                payload):
            state = self.codec.init_state(payload)  # new/shape-changed
        return state

    def store_state(self, peer, new_state) -> None:
        if self.error_feedback and new_state is not None:
            self._state[peer] = new_state

    def compress(self, payload, peer):
        state = self.resolve_state(payload, peer)
        out, new_state, info = self.codec.compress(payload, state)
        self.store_state(peer, new_state)
        return out, info


class WireCompressStage(CompressStage):
    """Byte-domain sibling of CompressStage: transforms the *serialized
    wire* (phase 2) instead of the payload. Carries a wire-domain codec
    (zlib-family); lossless, so no error-feedback state. Decode follows
    the wire's recorded ``wirecodec`` provenance — receivers inflate by
    what the wire says, never their own configuration."""

    name = "wirecodec"
    phase = 2

    def __init__(self, codec):
        super().__init__(codec, error_feedback=False)
        if getattr(self.codec, "domain", "payload") != "wire":
            raise ValueError(
                f"wire_codec must be a wire-domain codec, got "
                f"'{self.codec.name}' (payload-domain codecs like "
                f"qsgd/topk go in `compression`)")

    def compress(self, wire):
        return self.codec.compress_wire(wire)


class ChunkStage(WireStage):
    """Split wires larger than ``chunk_bytes`` into pipelined chunks."""

    name = "chunk"
    phase = 3

    def __init__(self, chunk_bytes: int):
        self.chunk_bytes = int(chunk_bytes)

    def signature(self) -> str:
        return f"chunk({self.chunk_bytes / MB:g}MB)"

    def split(self, nbytes: int) -> Optional[List[int]]:
        if self.chunk_bytes <= 0 or nbytes <= self.chunk_bytes:
            return None
        sizes = [self.chunk_bytes] * (nbytes // self.chunk_bytes)
        if nbytes % self.chunk_bytes:
            sizes.append(nbytes % self.chunk_bytes)
        return sizes


class Channel:
    """One backend's wire pipeline: an ordered WireStage stack driven
    through a single encode/decode pair."""

    def __init__(self, stages: List[WireStage]):
        assert any(isinstance(s, SerializeStage) for s in stages), \
            "a Channel needs a SerializeStage"
        self.stages = list(stages)
        self._order = sorted(self.stages, key=lambda s: s.phase)
        self.serializer = next(s.serializer for s in stages
                               if isinstance(s, SerializeStage))
        # the (at most one) payload-domain compress stage — the part of
        # the stack encode_many can fuse across a batch of encodes
        self.compress_stage: Optional[CompressStage] = next(
            (s for s in self._order if isinstance(s, CompressStage)
             and not isinstance(s, WireCompressStage)), None)

    # ------------------------------------------------------------------
    def signature(self) -> str:
        """Stable stack identity — the object store's content-addressed
        cache keys on (payload fingerprint, this), i.e. the
        post-compression wire."""
        return "|".join(s.signature() for s in self._order)

    # ------------------------------------------------------------------
    def encode(self, payload, peer: Optional[str] = None, *,
               _pre: Optional[Tuple] = None) -> Encoded:
        """Run the stack forward: payload -> wire (+ itemised charges).

        ``_pre`` is a precomputed ``(payload', info)`` for the payload
        compress stage (``encode_many``'s fused dispatch); the charges,
        provenance and wire are identical to computing it here."""
        charges: List[Tuple[str, float, int]] = []
        infos: List[dict] = []
        wire: Optional[WireData] = None
        chunks = None
        for stage in self._order:
            if isinstance(stage, WireCompressStage):
                out, info = stage.compress(wire)
                if info is not None:
                    charges.append((stage.name,
                                    stage.codec.enc_time(info["orig_nbytes"]),
                                    out.nbytes))
                    infos.append(info)
                    wire = out
            elif isinstance(stage, CompressStage):
                orig_nbytes = payload.nbytes
                if _pre is not None:
                    payload, info = _pre
                else:
                    payload, info = stage.compress(payload, peer)
                if info is not None:
                    charges.append((stage.name,
                                    stage.codec.enc_time(orig_nbytes),
                                    payload.nbytes))
                    infos.append(info)
            elif isinstance(stage, SerializeStage):
                wire = stage.serializer.serialize(payload)
                charges.append((stage.name,
                                stage.serializer.ser_time(wire.nbytes), 0))
                infos.append({"stage": "serialize", "codec": wire.codec})
            elif isinstance(stage, ChunkStage):
                sizes = stage.split(wire.nbytes)
                if sizes is not None:
                    chunks = sizes
                    infos.append({"stage": "chunk", "chunks": list(sizes)})
        cost_s = sum(c[1] for c in charges)
        wire.stages = infos
        enc = Encoded(wire=wire, cost_s=cost_s,
                      extra_alloc=sum(c[2] for c in charges),
                      charges=charges)
        if chunks is not None:
            # encode completes proportionally to bytes produced: chunk i
            # is transferable once its share of the encode work is done
            cum, plan = 0, []
            for nb in chunks:
                cum += nb
                plan.append((nb, cost_s * cum / wire.nbytes))
            enc.chunks = plan
        return enc

    # ------------------------------------------------------------------
    @staticmethod
    def _stage_infos(wire: WireData):
        """Recorded provenance; legacy bare wires (none) decode exactly
        as before the stack existed: codec-aware deserialize at the
        receiver's calibrated throughput."""
        return wire.stages or [{"stage": "serialize", "codec": wire.codec}]

    def decode(self, wire: WireData):
        """Invert the wire's recorded stages right-to-left. Wire-domain
        steps (wirecodec) transform the wire before the serialize step
        deserializes it; payload-domain steps invert after. Returns
        (payload, cost_s)."""
        from repro.compression.stages import codec_for
        payload, cur, cost = None, wire, 0.0
        for info in reversed(self._stage_infos(wire)):
            kind = info.get("stage", "compress")
            if kind == "chunk":
                continue  # reassembly is the transport's job (free here)
            if kind == "wirecodec":
                codec = codec_for(info["codec"])
                cur = codec.decompress_wire(cur, info)
                cost += codec.dec_time(info["orig_nbytes"])
            elif kind == "serialize":
                payload = decode_wire(cur, self.serializer)
                cost += self.serializer.deser_time(cur.nbytes)
            else:  # payload-domain compress
                codec = codec_for(info["codec"])
                payload = codec.decompress(payload, info)
                cost += codec.dec_time(info["orig_nbytes"])
        return payload, cost

    def encode_batch(self, items: List[Tuple[object, Optional[str]]]
                     ) -> List[Encoded]:
        """Batched ``encode``: [(payload, peer)] -> [Encoded], with the
        payload-compress work of the whole batch fused into one kernel
        dispatch where the codec supports it. Single-channel shorthand
        for ``encode_many``."""
        return encode_many([(self, p, peer) for p, peer in items])

    def decode_batch(self, wires: List[WireData]
                     ) -> List[Tuple[object, float]]:
        """Batched ``decode``: the per-wire wirecodec + deserialize steps
        run as usual, then every wire's final payload-codec inversion is
        grouped per codec and dispatched through ``codec.decode_batch``
        (one fused dequantize for a round's worth of received updates).
        Charges and payloads are identical to per-wire ``decode``."""
        from repro.compression.stages import codec_for
        results: List[Optional[Tuple[object, float]]] = [None] * len(wires)
        # wire -> payload via the non-payload-codec steps; collect the
        # remaining payload-codec inversions (applied right-to-left)
        tail: dict = {}  # codec name -> [(idx, payload, [info...])]
        for idx, wire in enumerate(wires):
            payload, cur, cost = None, wire, 0.0
            payload_infos = []
            for info in reversed(self._stage_infos(wire)):
                kind = info.get("stage", "compress")
                if kind == "chunk":
                    continue
                if kind == "wirecodec":
                    codec = codec_for(info["codec"])
                    cur = codec.decompress_wire(cur, info)
                    cost += codec.dec_time(info["orig_nbytes"])
                elif kind == "serialize":
                    payload = decode_wire(cur, self.serializer)
                    cost += self.serializer.deser_time(cur.nbytes)
                else:  # payload-domain: defer for the fused dispatch
                    payload_infos.append(info)
                    cost += codec_for(info["codec"]).dec_time(
                        info["orig_nbytes"])
            if payload_infos:
                # group by the outermost deferred codec; a stack rarely
                # nests payload codecs, but apply any extras in order
                tail.setdefault(payload_infos[0]["codec"], []).append(
                    (idx, payload, payload_infos))
            results[idx] = (payload, cost)
        for name, members in tail.items():
            codec = codec_for(name)
            decoded = codec.decode_batch([p for _, p, _ in members],
                                         [infos[0] for _, _, infos in
                                          members])
            for (idx, _, infos), payload in zip(members, decoded):
                for info in infos[1:]:
                    payload = codec_for(info["codec"]).decompress(payload,
                                                                  info)
                results[idx] = (payload, results[idx][1])
        return results

    def decode_time(self, wire: WireData) -> float:
        """Decode cost without materialising (planners/broadcast)."""
        from repro.compression.stages import codec_for
        cost, nbytes = 0.0, wire.nbytes
        for info in reversed(self._stage_infos(wire)):
            kind = info.get("stage", "compress")
            if kind == "chunk":
                continue
            if kind == "wirecodec":
                cost += codec_for(info["codec"]).dec_time(info["orig_nbytes"])
                nbytes = info["orig_nbytes"]  # deserialize sees inflated bytes
            elif kind == "serialize":
                cost += self.serializer.deser_time(nbytes)
            else:
                cost += codec_for(info["codec"]).dec_time(info["orig_nbytes"])
        return cost


def encode_many(items: List[Tuple[Channel, object, Optional[str]]]
                ) -> List[Encoded]:
    """Encode a batch of (channel, payload, peer) triples — possibly
    across *different* channels — with every payload-compress step that
    shares a codec fused into one kernel dispatch.

    The per-item result (wire bytes, provenance, charges, error-feedback
    transitions) is identical to calling ``channel.encode(payload, peer)``
    item by item, by construction: states are resolved through the same
    ``CompressStage.resolve_state`` rule before the fused dispatch and
    written back through ``store_state`` after it, and the rest of each
    stack runs unchanged via ``encode(..., _pre=...)``. Items whose
    (stage, peer) stream appears more than once in the batch are left on
    the sequential path — their residuals chain, so fusing them would
    reorder the feedback loop."""
    pre: List[Optional[Tuple]] = [None] * len(items)
    # count per-stream occurrences: a stream = one EF residual slot
    streams: dict = {}
    for ch, _, peer in items:
        if ch.compress_stage is not None:
            key = (id(ch.compress_stage), peer)
            streams[key] = streams.get(key, 0) + 1
    groups: dict = {}  # (codec type, signature) -> [(idx, stage, peer)]
    for idx, (ch, payload, peer) in enumerate(items):
        stage = ch.compress_stage
        if stage is None or streams[(id(stage), peer)] > 1:
            continue
        groups.setdefault((type(stage.codec), stage.codec.signature()),
                          []).append((idx, stage, peer))
    for (_, _sig), members in groups.items():
        codec = members[0][1].codec
        payloads = [items[i][1] for i, _, _ in members]
        states = [stage.resolve_state(p, peer)
                  for (_, stage, peer), p in zip(members, payloads)]
        for (i, stage, peer), (out, new_state, info) in zip(
                members, codec.encode_batch(payloads, states)):
            stage.store_state(peer, new_state)
            pre[i] = (out, info)
    return [ch.encode(payload, peer, _pre=pre[idx])
            for idx, (ch, payload, peer) in enumerate(items)]


def make_channel(serializer_name: str, *, compression=None, wire_codec=None,
                 chunk_bytes: int = 0,
                 error_feedback: bool = True) -> Channel:
    """Standard stack builder:
    [Compress?] -> Serialize -> [WireCompress?] -> [Chunk?].

    A wire-domain codec named via ``compression`` (e.g. the CLI's
    ``--compression zlib:6``) is routed to its rightful slot after the
    serializer; ``wire_codec`` names it explicitly (ChannelSpec), and the
    two compose: qsgd payload quantisation + zlib on the resulting
    wire bytes is a legal stack."""
    from repro.compression.stages import split_codecs
    stages: List[WireStage] = [SerializeStage(SERIALIZERS[serializer_name])]
    codec, wcodec = split_codecs(compression, wire_codec)
    if codec is not None:
        stages.append(CompressStage(codec, error_feedback=error_feedback))
    if wcodec is not None:
        stages.append(WireCompressStage(wcodec))
    if chunk_bytes and chunk_bytes > 0:
        stages.append(ChunkStage(chunk_bytes))
    return Channel(stages)
