"""CommBackend: the paper's pluggable communication abstraction.

Each backend implements the same API over the shared fabric + netsim:

* ``isend(msg, now)``         -> SendHandle (non-blocking completion path)
* ``send(msg, now)``          -> (sender_free_t, arrive_t)
* ``broadcast(msgs, now)``    -> (sender_free_t, [arrive_t])   (concurrent)
* ``sequential_broadcast``    -> same but one send at a time (Fig 4b baseline)
* ``recv(now)``               -> [(FLMessage with payload, ready_t)]
* ``next_arrival(after)``     -> earliest pending delivery time (peek)
* ``p2p_time(nbytes)``        -> analytic single-message latency (Fig 4a)

``isend`` is the shared completion path: ``send`` and
``sequential_broadcast`` are thin blocking-semantics wrappers over it, and
the event-driven FL scheduler (fl/scheduler.py) issues bare handles so it
can interleave many in-flight sends. Backends whose serializer cannot run
sends in parallel (``ser_parallel=False``) queue overlapping isends on a
sender-side serializer busy-line; non-overlapping calls — the only pattern
the blocking API ever produced — are bit-for-bit unchanged.

What differs between backends is exactly what the paper measures: the
serializer (copy vs zero-copy), connections per transfer, per-send buffer
behaviour (memory ∝ concurrency or not), fixed per-message overheads, and
whether the LAN path can ride InfiniBand verbs or falls back to TCP.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.channel import Encoded, make_channel
from repro.core.message import FLMessage
from repro.core.netsim import LAN_IB, LAN_TCP, Environment, Link, Region, \
    Transfer, simulate_transfers
from repro.core.serialization import SERIALIZERS, WireData
from repro.core.transport import Fabric

MB = 1024 ** 2


@dataclasses.dataclass(frozen=True)
class BackendPolicy:
    name: str
    serializer: str
    conns_per_transfer: int = 1
    per_send_copy: bool = False  # serialized copy per in-flight send
    staging_bytes: int = 4 << 20  # fixed per-active-send staging
    overhead_rtts: float = 1.0  # request/ack handshakes per message
    ser_parallel: bool = False  # can serialize concurrent sends in parallel
    lan_uses_ib: bool = True  # ib verbs (buffer backends) vs TCP fallback
    lan_concurrency_penalty: float = 0.0  # MPI multithreading overhead/send


@dataclasses.dataclass
class SendHandle:
    """One in-flight non-blocking send (``isend``).

    * ``issued``  — when the send was requested;
    * ``start``   — sender-side busy-until (serialization / upload done);
    * ``inbox_t`` — when the delivery lands in the receiver's inbox
                    (``recv`` called at/after this returns the message);
    * ``arrive``  — payload availability at the receiver, pre-deserialize
                    (for object-store backends this includes the GET leg).
    * ``failed``  — the fault model exhausted the bounded chunk
                    retransmits: nothing was delivered (``arrive`` is
                    inf, ``start`` is the sender's give-up time); the
                    caller decides whether to re-issue.
    """
    msg: FLMessage
    issued: float
    start: float
    inbox_t: float
    arrive: float
    nbytes: int = 0
    failed: bool = False

    def done(self, now: float) -> bool:
        return now + 1e-12 >= self.arrive


class CommBackend:
    def __init__(self, policy: BackendPolicy, env: Environment,
                 fabric: Fabric, host_id: str, store=None, *,
                 compression=None, wire_codec=None, chunk_mb: float = 0.0,
                 error_feedback: bool = True, job=None):
        self.policy = policy
        self.env = env
        self.fabric = fabric
        self.host_id = host_id
        self.store = store
        # tenancy: a transport.JobHandle namespaces this backend's
        # endpoint, transfer ids and stats; None = the default tenant
        # (plain host_id keys — the exact legacy fabric surface)
        self.job = job
        self.job_name = job.name if job is not None else ""
        self.job_prio = job.priority if job is not None else 0
        self.endpoint = fabric.endpoint_for(host_id, self.job_name) \
            or fabric.register(host_id, job=self.job_name)
        self.serializer = SERIALIZERS[policy.serializer]
        # the wire pipeline every send/recv path drives (core/channel.py);
        # default stack = [SerializeStage] -> pre-stack behaviour, exactly
        self.channel = make_channel(policy.serializer,
                                    compression=compression,
                                    wire_codec=wire_codec,
                                    chunk_bytes=int(chunk_mb * MB),
                                    error_feedback=error_feedback)
        self._ser_busy_until = 0.0  # sender serializer busy-line (isend)

    def _encode(self, msg: FLMessage) -> Encoded:
        """Stack-encode one message's payload (256 B for metadata-only,
        which still occupies the serializer for its header's worth)."""
        if msg.payload is None:
            return Encoded(wire=WireData(nbytes=256),
                           cost_s=self.serializer.ser_time(256))
        return self.channel.encode(msg.payload, peer=msg.receiver)

    def _encode_batch(self, msgs: Sequence[FLMessage]) -> List[Encoded]:
        """Stack-encode a round's worth of messages with the payload
        compression fused into one kernel dispatch (channel.encode_many).
        Per-message wires/charges are identical to ``_encode`` in a loop."""
        from repro.core.channel import encode_many
        encs: List[Optional[Encoded]] = [
            Encoded(wire=WireData(nbytes=256),
                    cost_s=self.serializer.ser_time(256))
            if m.payload is None else None for m in msgs]
        idx = [i for i, m in enumerate(msgs) if m.payload is not None]
        fused = encode_many([(self.channel, msgs[i].payload,
                              msgs[i].receiver) for i in idx])
        for i, enc in zip(idx, fused):
            encs[i] = enc
        return encs

    # ------------------------------------------------------------------
    @property
    def name(self) -> str:
        return self.policy.name

    def _edge(self, dst_id: str) -> Link:
        """The topology-graph edge this host's transmissions to ``dst_id``
        ride (netsim.Environment.link), with LAN-class edges resolved per
        backend policy: buffer backends ride InfiniBand verbs, serializing
        ones fall back to TCP."""
        link = self.env.link(self.host_id, dst_id)
        if link.lan_class:
            return dataclasses.replace(
                link, region=LAN_IB if self.policy.lan_uses_ib else LAN_TCP)
        return link

    def _link_region(self, dst_id: str) -> Region:
        """Capacity triple of the graph edge to ``dst_id``."""
        return self._edge(dst_id).region

    def _overhead(self, region: Region) -> float:
        return self.policy.overhead_rtts * 2 * region.latency

    def _ser_slot(self, now: float, ser_t: float) -> float:
        """Start time for one serialization on the sender. Serializers that
        cannot run in parallel queue overlapping isends; calls at
        non-decreasing, non-overlapping times see ``now`` unchanged."""
        if self.policy.ser_parallel:
            return now
        start = max(now, self._ser_busy_until)
        self._ser_busy_until = start + ser_t
        return start

    def _link_schedule(self, dst_id: str, depart: float, nbytes: float,
                       rate: float, edge: Link, xid: Optional[int],
                       chunk_index: int):
        """Completion of one link transmission under the fabric's fault
        model: the departure is shifted past blackout windows, each lost
        transmission costs the chunk's wire time plus the receiver-driven
        NACK turnaround on ``edge`` before the retransmit. Returns
        ``(finish, give_up_t)`` — ``finish`` is None when the bounded
        retries are exhausted, with ``give_up_t`` the moment the sender
        abandons the transfer. Each transmission rides the fabric's
        shared edge pipe (``link_transmit``) — with ``shared_links`` off
        and no fault model this is exactly ``depart + nbytes/rate``."""
        fab = self.fabric

        def tx_done(t0: float) -> float:
            return fab.link_transmit(self.host_id, dst_id, t0, nbytes, rate,
                                     capacity=edge.region.bw_multi,
                                     job=self.job_name, prio=self.job_prio)

        fm = fab.fault_model
        if fm is None:
            fin = tx_done(depart)
            return fin, fin
        if xid is None:
            xid = fab.next_transfer_id(self.job_name)
        hosts = (self.host_id, dst_id)
        t = fm.delay(hosts, depart)
        n = fm.attempts(self.host_id, dst_id, xid, chunk_index)
        # lost transmissions each pay their wire time + the NACK
        # turnaround; retransmits are the transmissions beyond the original
        lost_tx = (fm.max_retries + 1) if n is None else (n - 1)
        for _ in range(lost_tx):
            t = fm.delay(hosts, tx_done(t) + fm.detect_delay(edge))
        if n is None:
            fab.account(0.0, 0, retransmits=fm.max_retries,
                        transfers_failed=1, job=self.job_name)
            return None, t
        fab.account(0.0, 0, retransmits=lost_tx, job=self.job_name)
        fin = tx_done(t)
        return fin, fin

    # ------------------------------------------------------------------
    def isend(self, msg: FLMessage, now: float) -> SendHandle:
        """Non-blocking send: schedules delivery, returns a completion
        handle immediately. Multiple in-flight isends interleave (subject
        to the serializer busy-line)."""
        enc = self._encode(msg)
        ser_t = enc.cost_s
        mem = self.endpoint.memory
        alloc = (enc.wire.nbytes if (self.policy.per_send_copy and msg.payload
                                     is not None) else 0) \
            + self.policy.staging_bytes + enc.extra_alloc
        ser_start = self._ser_slot(now, ser_t)
        mem.alloc(alloc, ser_start)
        edge = self._edge(msg.receiver)
        region = edge.region
        start = ser_start + ser_t
        rate = region.conn_cap(self.policy.conns_per_transfer)
        base = self._overhead(region) + region.latency
        failed_at = None
        if enc.chunks:
            # pipelined chunks: chunk i's transfer starts once it is
            # encoded AND the link is free (overlaps encode with network)
            xid = self.fabric.next_transfer_id(self.job_name)
            link_free, arrivals = ser_start, []
            for i, (nb, ready_off) in enumerate(enc.chunks):
                dep = max(ser_start + ready_off, link_free)
                fin, give_up = self._link_schedule(msg.receiver, dep, nb,
                                                   rate, edge, xid, i)
                if fin is None:
                    failed_at = give_up
                    break
                link_free = fin
                arrivals.append(base + fin)
            if failed_at is None:
                arrive = self.fabric.deliver_chunked(msg, enc.wire, arrivals,
                                                     xid=xid,
                                                     job=self.job_name)
        else:
            fin, give_up = self._link_schedule(msg.receiver, start,
                                               enc.wire.nbytes, rate, edge,
                                               None, 0)
            if fin is None:
                failed_at = give_up
            else:
                arrive = self.fabric.deliver(msg, enc.wire, start,
                                             base + fin - start,
                                             job=self.job_name)
        if failed_at is not None:
            # bounded retries exhausted: nothing is delivered; the sender
            # frees its buffers when it gives up and surfaces the failure.
            # ``start`` carries the give-up time — the earliest moment a
            # caller can causally know the send failed and re-issue it
            mem.free(alloc, failed_at)
            return SendHandle(msg=msg, issued=now, start=failed_at,
                              inbox_t=float("inf"), arrive=float("inf"),
                              nbytes=enc.wire.nbytes, failed=True)
        mem.free(alloc, arrive)
        return SendHandle(msg=msg, issued=now, start=start, inbox_t=arrive,
                          arrive=arrive, nbytes=enc.wire.nbytes)

    def send(self, msg: FLMessage, now: float) -> Tuple[float, float]:
        """Blocking-semantics wrapper over ``isend`` (legacy API)."""
        h = self.isend(msg, now)
        return h.start, h.arrive

    # ------------------------------------------------------------------
    def _broadcast_transfers(self, msgs, now, _encs=None) -> Tuple[list, list]:
        """Common prep: stack-encode (sequential or parallel), build
        transfers. Returns ([(Encoded, encode_done_t)], transfers).
        ``_encs`` lets a routing backend (AUTO) hand in message encodings
        it already fused across its sub-backends' channels — the wires
        and charges are identical to ``_encode_batch`` here."""
        encs, ser_done = [], now
        for enc in (self._encode_batch(msgs) if _encs is None else _encs):
            if self.policy.ser_parallel:
                enc_done = now + enc.cost_s
                ser_done = max(ser_done, enc_done)
            else:
                enc_done = ser_done + enc.cost_s
                ser_done = enc_done
            encs.append((enc, enc_done))
        transfers = []
        n_active = len(msgs)
        # MPI-style multithreaded progress engines lose efficiency on LAN
        # (paper Fig 4b: concurrent MPI *declines*): the penalty applies to
        # the shared NIC budget, not just per-transfer caps.
        penalty = 1.0 + self.policy.lan_concurrency_penalty * max(
            n_active - 1, 0) if self.env.name == "lan" else 1.0
        src = self.env.host(self.host_id)
        if penalty > 1.0:
            import dataclasses as _dc
            src = _dc.replace(src, uplink=src.uplink / penalty)
        fm = self.fabric.fault_model
        for msg, (enc, enc_done) in zip(msgs, encs):
            region = self._link_region(msg.receiver)
            eff_region = Region(region.name,
                                region.bw_single / penalty,
                                region.bw_multi / penalty, region.latency)
            start = enc_done + self._overhead(region)
            if fm is not None:
                start = fm.delay((self.host_id, msg.receiver), start)
            # chunk pipelining overlaps encode with transfer on the isend
            # path only: the fluid solver moves whole wires with no
            # inter-chunk dependencies, so dispatching a broadcast at
            # first-chunk-ready could finish a transfer before its encode
            # completes — broadcasts keep whole-wire (encode-complete)
            # dispatch
            tr = Transfer(
                start=start,
                src=src,
                dst=self.env.host(msg.receiver),
                nbytes=enc.wire.nbytes,
                conns=self.policy.conns_per_transfer,
                link_region=eff_region, tag=f"msg{msg.msg_id}")
            if self.fabric.spec.shared_links:
                # shared-bottleneck edge: this wave's flows through the
                # (src, dst) pipe split whatever other tenants left free
                tr.edge_key = (self.host_id, msg.receiver)
                tr.edge_cap = self.fabric.link_headroom(
                    self.host_id, msg.receiver, start + eff_region.latency,
                    capacity=eff_region.bw_multi, job=self.job_name,
                    prio=self.job_prio, nbytes=tr.nbytes)
            transfers.append(tr)
        return encs, transfers

    def broadcast(self, msgs: Sequence[FLMessage], now: float, _encs=None):
        """Concurrent dispatch (the FL server's global-model distribution)."""
        encs, transfers = self._broadcast_transfers(msgs, now, _encs)
        mem = self.endpoint.memory
        allocs = []
        for msg, (enc, start) in zip(msgs, encs):
            a = (enc.wire.nbytes if (self.policy.per_send_copy and msg.payload
                                     is not None) else 0) \
                + self.policy.staging_bytes + enc.extra_alloc
            # buffered from *dispatch*: issuing N concurrent sends
            # materialises N request buffers immediately (memory ∝
            # concurrency, Fig 2 bottom / Fig 4c), even while the
            # serializer busy-line is still draining them onto the wire
            mem.alloc(a, now)
            allocs.append(a)
        simulate_transfers(transfers)
        fm = self.fabric.fault_model
        arrives = []
        for msg, (enc, _), tr, a in zip(msgs, encs, transfers, allocs):
            finish = tr.finish
            if fm is not None:
                # the concurrent-broadcast path models a reliable stream:
                # lost chunks are retransmitted serially after the fluid
                # transfer (capped at max_retries, always delivered —
                # bounded-failure semantics live on the isend path)
                xid = self.fabric.next_transfer_id(self.job_name)
                n = fm.attempts(self.host_id, msg.receiver, xid, 0,
                                forced=True)
                if n > 1:
                    edge = self._edge(msg.receiver)
                    rate = edge.conn_cap(self.policy.conns_per_transfer)
                    finish += (n - 1) * (enc.wire.nbytes / rate
                                         + fm.detect_delay(edge))
                    self.fabric.account(0.0, 0, retransmits=n - 1,
                                        job=self.job_name)
            if self.fabric.spec.shared_links:
                # publish this flow's occupancy so later tenants contend
                begin = tr.start + tr.latency()
                if tr.finish > begin:
                    self.fabric.link_reserve(
                        self.host_id, msg.receiver, begin, tr.finish,
                        tr.nbytes / (tr.finish - begin),
                        capacity=self._link_region(msg.receiver).bw_multi,
                        job=self.job_name, prio=self.job_prio)
            self.fabric._ep(msg.receiver, self.job_name).inbox.append(
                _delivery(msg, enc.wire, finish))
            # broadcast bypasses Fabric.deliver (the fluid solver already
            # owns the timing) — keep the wire accounting consistent
            self.fabric.account(enc.wire.nbytes, job=self.job_name)
            mem.free(a, finish)
            arrives.append(finish)
        return max(e[1] for e in encs), arrives

    def sequential_broadcast(self, msgs: Sequence[FLMessage], now: float):
        """One at a time (Fig 4b baseline): each isend waits for the
        previous handle to complete before being issued. A fault-failed
        send resolves at the sender's give-up time — the chain continues
        from there (its inf arrive in the result marks the loss) instead
        of pushing every later send to t=inf."""
        t = now
        arrives = []
        for msg in msgs:
            h = self.isend(msg, t)
            # blocking: wait for completion (or failure detection)
            t = h.start if h.failed else h.arrive
            arrives.append(h.arrive)
        return t, arrives

    # ------------------------------------------------------------------
    def recv(self, now: float) -> List[Tuple[FLMessage, float]]:
        ready_ds = self.endpoint.pop_ready(now)
        # fuse the wires' payload-codec inversions into one kernel
        # dispatch (channel.decode_batch); identical payloads/charges
        dec_idx = [i for i, d in enumerate(ready_ds)
                   if d.wire is not None and d.wire.nbytes > 256]
        decoded = self.channel.decode_batch([ready_ds[i].wire
                                             for i in dec_idx])
        out = []
        by_idx = dict(zip(dec_idx, decoded))
        for i, d in enumerate(ready_ds):
            ready = d.arrive_time
            msg = d.msg
            if i in by_idx:
                # the channel inverts whatever stages the wire records
                # (codec-aware: AUTO/mixed fleets decode correctly)
                payload, dec_s = by_idx[i]
                ready += dec_s
                if msg.payload is None or d.wire.buffers is not None:
                    msg = dataclasses.replace(msg, payload=payload)
            out.append((msg, ready))
        return out

    def next_arrival(self, after: float = float("-inf")) -> Optional[float]:
        """Non-blocking peek: earliest pending message-complete time
        strictly after ``after`` (event-loop hook; returns None when
        idle). Chunked wires count once, at their last chunk."""
        ts = [t for t in self.endpoint.pending_times() if t > after]
        return min(ts) if ts else None

    # ------------------------------------------------------------------
    def p2p_time(self, nbytes: int, dst_id: str) -> float:
        """Analytic one-message CPU-to-CPU latency (Fig 4a)."""
        region = self._link_region(dst_id)
        return (self.serializer.ser_time(nbytes) + self._overhead(region)
                + region.latency
                + nbytes / region.conn_cap(self.policy.conns_per_transfer)
                + self.serializer.deser_time(nbytes))


def _delivery(msg, wire, t):
    from repro.core.transport import Delivery
    return Delivery(msg, wire, t)
