"""AUTO backend — the paper's §VII deployment guideline as code.

Per message: payloads < 10 MB (or no object store / LAN) ride plain gRPC;
large payloads in untrusted WANs ride gRPC+S3; trusted LAN prefers
MPI_MEM_BUFF for buffer-like payloads.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.backends.base import CommBackend
from repro.core.backends.grpc_s3 import GrpcS3Backend
from repro.core.message import FLMessage

SMALL_PAYLOAD = 10 * 1024 * 1024  # paper: <10 MB -> pure gRPC


class AutoBackend:
    name = "auto"

    def __init__(self, env, fabric, host_id, store=None, *,
                 compression=None, chunk_mb: float = 0.0, **kw):
        from repro.core.backends import POLICIES
        self.env = env
        self.host_id = host_id
        self.store = store
        # every routed backend carries the same wire-stack configuration;
        # decode follows the wire's recorded stages, so mixed routes stay
        # coherent
        self.grpc = CommBackend(POLICIES["grpc"], env, fabric, host_id,
                                compression=compression, chunk_mb=chunk_mb)
        self.membuff = CommBackend(POLICIES["mpi_mem_buff"], env, fabric,
                                   host_id, compression=compression,
                                   chunk_mb=chunk_mb)
        self.s3 = (GrpcS3Backend(env, fabric, host_id, store,
                                 compression=compression, **kw)
                   if store is not None and env.name != "lan" else None)
        self.endpoint = self.grpc.endpoint
        self.decisions: list = []

    def resolve(self, msg: FLMessage):
        """The concrete backend this message would ride (no logging) —
        lets orchestrators (FLServer upload phase) plan with the right
        serializer/policy."""
        nbytes = msg.payload_nbytes
        if nbytes < SMALL_PAYLOAD or self.s3 is None:
            return self.membuff if (self.env.trusted and
                                    self.env.name == "lan") else self.grpc
        return self.s3

    def _route(self, msg: FLMessage):
        nbytes = msg.payload_nbytes
        if nbytes < SMALL_PAYLOAD or self.s3 is None:
            choice = self.membuff if (self.env.trusted and
                                      self.env.name == "lan") else self.grpc
        else:
            choice = self.s3
        self.decisions.append((msg.msg_type, nbytes, choice.name))
        return choice

    def isend(self, msg, now):
        return self._route(msg).isend(msg, now)

    def send(self, msg, now):
        return self._route(msg).send(msg, now)

    def broadcast(self, msgs: Sequence[FLMessage], now):
        return self._route(msgs[0]).broadcast(msgs, now)

    def sequential_broadcast(self, msgs, now):
        return self._route(msgs[0]).sequential_broadcast(msgs, now)

    def recv(self, now):
        # all three share one endpoint; GrpcS3Backend.recv handles both
        # metadata-record and direct-wire deliveries, so route through it
        # when available (it pops the shared inbox exactly once)
        if self.s3 is not None:
            return self.s3.recv(now)
        return self.grpc.recv(now)

    def next_arrival(self, after: float = float("-inf")):
        return self.grpc.next_arrival(after)  # shared endpoint

    def p2p_time(self, nbytes, dst_id):
        if nbytes < SMALL_PAYLOAD or self.s3 is None:
            return self.grpc.p2p_time(nbytes, dst_id)
        return self.s3.p2p_time(nbytes, dst_id)
