"""AUTO backend — the paper's §VII deployment guideline as code.

Per message: payloads whose *wire* footprint is < 10 MB (or no object
store / LAN) ride plain gRPC; large payloads in untrusted WANs ride
gRPC+S3; trusted LAN prefers MPI_MEM_BUFF for buffer-like payloads.

The 10 MB threshold is about bytes on the wire, so routing sees the
channel's post-stack size estimate: a qsgd-compressed 32 MB update
shrinks to ~8 MB and must ride plain gRPC, while the same update
uncompressed rides gRPC+S3. Batched broadcasts route *per message* —
one small control record in a batch of large models must not drag the
models onto gRPC (or vice versa).
"""
from __future__ import annotations

from typing import Sequence

from repro.core.backends.base import CommBackend
from repro.core.backends.grpc_s3 import GrpcS3Backend
from repro.core.message import FLMessage, PackedPayload

SMALL_PAYLOAD = 10 * 1024 * 1024  # paper: <10 MB -> pure gRPC


class AutoBackend:
    name = "auto"

    def __init__(self, env, fabric, host_id, store=None, *,
                 compression=None, wire_codec=None, chunk_mb: float = 0.0,
                 job=None, **kw):
        from repro.core.backends import POLICIES
        self.env = env
        self.fabric = fabric
        self.host_id = host_id
        self.store = store
        self.job = job
        self.job_name = job.name if job is not None else ""
        # every routed backend carries the same wire-stack configuration;
        # decode follows the wire's recorded stages, so mixed routes stay
        # coherent — and the same tenant (one shared namespaced endpoint)
        self.grpc = CommBackend(POLICIES["grpc"], env, fabric, host_id,
                                compression=compression,
                                wire_codec=wire_codec, chunk_mb=chunk_mb,
                                job=job)
        self.membuff = CommBackend(POLICIES["mpi_mem_buff"], env, fabric,
                                   host_id, compression=compression,
                                   wire_codec=wire_codec, chunk_mb=chunk_mb,
                                   job=job)
        self.s3 = (GrpcS3Backend(env, fabric, host_id, store,
                                 compression=compression,
                                 wire_codec=wire_codec, job=job, **kw)
                   if store is not None and env.name != "lan" else None)
        from repro.compression.stages import split_codecs
        self._codec, self._wire_codec = split_codecs(compression, wire_codec)
        self.endpoint = self.grpc.endpoint
        self.decisions: list = []  # (msg_type, wire nbytes estimate, backend)

    # ------------------------------------------------------------------
    def _wire_nbytes(self, nbytes: int, payload=None) -> int:
        """Post-stack wire size estimate: the payload codec's wire ratio
        (already-packed payloads pass the CompressStage untouched, so
        they route on their own size) times the wire codec's byte ratio."""
        est = float(nbytes)
        if self._codec is not None and not isinstance(payload, PackedPayload):
            est *= self._codec.ratio()
        if self._wire_codec is not None:
            est *= self._wire_codec.ratio()
        return int(round(est))

    def _pick(self, wire_nbytes: int):
        if wire_nbytes < SMALL_PAYLOAD or self.s3 is None:
            return self.membuff if (self.env.trusted and
                                    self.env.name == "lan") else self.grpc
        return self.s3

    def resolve(self, msg: FLMessage):
        """The concrete backend this message would ride (no logging) —
        lets orchestrators (FLServer upload phase) plan with the right
        serializer/policy."""
        return self._pick(self._wire_nbytes(msg.payload_nbytes, msg.payload))

    def _route(self, msg: FLMessage):
        wire_nbytes = self._wire_nbytes(msg.payload_nbytes, msg.payload)
        choice = self._pick(wire_nbytes)
        self.decisions.append((msg.msg_type, wire_nbytes, choice.name))
        return choice

    def isend(self, msg, now):
        return self._route(msg).isend(msg, now)

    def send(self, msg, now):
        return self._route(msg).send(msg, now)

    def broadcast(self, msgs: Sequence[FLMessage], now):
        """Per-message routing: each routed subset rides its own
        backend's concurrent dispatch (timing semantics per backend are
        unchanged — grpc's fluid contention, s3's single upload + N
        GETs); arrivals come back in input order.

        The direct subsets' payload encodes are fused into ONE
        cross-channel ``encode_many`` dispatch spanning grpc and membuff
        (their channels share codecs, so one broadcast wave is one
        kernel call); each subset then receives its ready-made encodings
        via ``_encs`` — wire bytes bit-identical to the per-backend
        ``_encode_batch`` path. S3 keeps its own upload-once flow."""
        from repro.core.channel import Encoded, encode_many
        from repro.core.serialization import WireData
        routed: dict = {}
        for i, msg in enumerate(msgs):
            routed.setdefault(id(self._route(msg)), []).append(i)
        backends = {id(b): b for b in (self.grpc, self.membuff, self.s3)
                    if b is not None}
        # one fused dispatch across every direct (non-s3) subset
        direct = [(bid, i) for bid in routed
                  if backends[bid] is not self.s3 for i in routed[bid]]
        payload_items, payload_pos = [], []
        encs: dict = {}  # msg index -> Encoded
        for bid, i in direct:
            m = msgs[i]
            if m.payload is None:
                ser = backends[bid].serializer
                encs[i] = Encoded(wire=WireData(nbytes=256),
                                  cost_s=ser.ser_time(256))
            else:
                payload_items.append((backends[bid].channel, m.payload,
                                      m.receiver))
                payload_pos.append(i)
        for i, enc in zip(payload_pos, encode_many(payload_items)):
            encs[i] = enc
        sender_done = now
        arrives = [0.0] * len(msgs)
        for bid, idxs in routed.items():
            be = backends[bid]
            sub = [msgs[i] for i in idxs]
            if be is self.s3:
                done, arr = be.broadcast(sub, now)
            else:
                done, arr = be.broadcast(sub, now,
                                         _encs=[encs[i] for i in idxs])
            sender_done = max(sender_done, done)
            for i, a in zip(idxs, arr):
                arrives[i] = a
        return sender_done, arrives

    def sequential_broadcast(self, msgs, now):
        """One at a time, each message on its own routed backend (the
        Fig 4b blocking chain crosses backends unchanged: isend, wait,
        next; a fault-failed send resolves at its give-up time)."""
        t = now
        arrives = []
        for msg in msgs:
            h = self._route(msg).isend(msg, t)
            t = h.start if h.failed else h.arrive
            arrives.append(h.arrive)
        return t, arrives

    def recv(self, now):
        # all three share one endpoint; GrpcS3Backend.recv handles both
        # metadata-record and direct-wire deliveries, so route through it
        # when available (it pops the shared inbox exactly once)
        if self.s3 is not None:
            return self.s3.recv(now)
        return self.grpc.recv(now)

    def next_arrival(self, after: float = float("-inf")):
        return self.grpc.next_arrival(after)  # shared endpoint

    def p2p_time(self, nbytes, dst_id):
        return self._pick(self._wire_nbytes(nbytes)).p2p_time(nbytes, dst_id)
