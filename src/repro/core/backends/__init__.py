"""Backend registry: the five personalities the paper benchmarks + AUTO.

* MPI_GENERIC   — lowercase mpi4py send: generic serializer, copies, low
                  per-message overhead, IB on LAN, single connection;
                  concurrent sends pay multithreading overhead on LAN.
* MPI_MEM_BUFF  — uppercase Send: zero-copy buffers, near-C speed, IB verbs.
* GRPC          — protobuf serializer (slowest), TCP fallback on LAN, one
                  HTTP/2 connection per channel; concurrent dispatch = one
                  channel per receiver, each send buffers its own copy
                  (memory ∝ concurrency, Fig 2 bottom).
* TENSOR_RPC    — PyTorch RPC / TensorPipe: tensor-optimised zero-copy
                  serialisation, multi-connection transport.
* GRPC_S3       — the paper's hybrid (grpc_s3.py).
* AUTO          — §VII guideline: <10 MB or no object store -> GRPC;
                  trusted LAN -> MPI_MEM_BUFF; else GRPC_S3.
"""
from __future__ import annotations

from repro.core.backends.base import BackendPolicy, CommBackend
from repro.core.backends.grpc_s3 import GrpcS3Backend
from repro.core.netsim import Environment
from repro.core.transport import Fabric

MPI_GENERIC = BackendPolicy(
    name="mpi_generic", serializer="generic", conns_per_transfer=1,
    per_send_copy=True, staging_bytes=1 << 20, overhead_rtts=0.5,
    ser_parallel=False, lan_uses_ib=True, lan_concurrency_penalty=0.06)

MPI_MEM_BUFF = BackendPolicy(
    name="mpi_mem_buff", serializer="membuff", conns_per_transfer=1,
    per_send_copy=False, staging_bytes=4 << 20, overhead_rtts=0.5,
    ser_parallel=True, lan_uses_ib=True, lan_concurrency_penalty=0.06)

GRPC = BackendPolicy(
    name="grpc", serializer="protobuf", conns_per_transfer=1,
    per_send_copy=True, staging_bytes=2 << 20, overhead_rtts=1.0,
    ser_parallel=False, lan_uses_ib=False)

TENSOR_RPC = BackendPolicy(
    name="torch_rpc", serializer="tensor_rpc", conns_per_transfer=8,
    per_send_copy=False, staging_bytes=8 << 20, overhead_rtts=1.0,
    ser_parallel=True, lan_uses_ib=False)

POLICIES = {p.name: p for p in (MPI_GENERIC, MPI_MEM_BUFF, GRPC, TENSOR_RPC)}
BACKEND_NAMES = ["mpi_generic", "mpi_mem_buff", "grpc", "torch_rpc",
                 "grpc+s3", "auto"]


def make_backend(name: str, env: Environment, fabric: Fabric, host_id: str,
                 store=None, *, compression=None, wire_codec=None,
                 chunk_mb: float = 0.0, job=None, **kw):
    """``compression``/``wire_codec``/``chunk_mb`` configure the
    backend's wire stack (core/channel.py): 'qsgd[:block]' /
    'topk[:frac]' insert a payload CompressStage, 'zlib[:level]' a
    byte-domain WireCompressStage, chunk_mb > 0 a ChunkStage. Defaults
    reproduce the plain [SerializeStage] stack bit-for-bit. ``job`` (a
    ``transport.JobHandle``) binds the backend to one tenant of a
    multi-tenant fabric; None is the default single-job tenant."""
    from repro.compression.stages import split_codecs
    # one shared rule: a byte codec named via `compression` moves to the
    # wire-domain slot; naming two different wire codecs is an error
    compression, wire_codec = split_codecs(compression, wire_codec)
    if name == "grpc+s3":
        return GrpcS3Backend(env, fabric, host_id, store,
                             compression=compression, wire_codec=wire_codec,
                             chunk_mb=chunk_mb, job=job, **kw)
    if name == "auto":
        from repro.core.backends.auto import AutoBackend
        return AutoBackend(env, fabric, host_id, store,
                           compression=compression, wire_codec=wire_codec,
                           chunk_mb=chunk_mb, job=job, **kw)
    if name in POLICIES:
        return CommBackend(POLICIES[name], env, fabric, host_id, store,
                           compression=compression, wire_codec=wire_codec,
                           chunk_mb=chunk_mb, job=job)
    raise KeyError(f"unknown backend '{name}'; options: {BACKEND_NAMES}")


def available_backends(env: Environment, has_store: bool):
    """Which backends are deployable in an environment (paper Table/§VII)."""
    out = ["grpc"]
    if env.trusted:
        out += ["mpi_generic", "mpi_mem_buff", "torch_rpc"]
    else:
        # RPC/MPI need open peer paths / managed clusters; paper deploys
        # them cross-region via VPC peering for benchmarks
        out += ["mpi_generic", "mpi_mem_buff", "torch_rpc"]
    if has_store and env.name != "lan":
        out += ["grpc+s3"]
    return out
