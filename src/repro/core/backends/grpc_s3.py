"""gRPC+S3 — the paper's contribution (§III).

Sender: split message into metadata + payload; upload payload once to the
object store (content-addressed key, cached across repeated sends of the
same model); send compact metadata records over the gRPC control channel.
Receivers: on metadata arrival, fetch the object with multipart parallel
GET (independent connections — this is what beats single-channel gRPC over
WAN) and reconstruct the message.

Properties reproduced here (paper §III-B):
* Efficiency   — bulk data rides S3 multipart, control rides gRPC.
* Scalability  — broadcast = single upload + N downloads; sender memory is
  O(1) in receiver count (one serialized copy during upload).
* Versatility  — ``AutoBackend`` falls back to pure gRPC for <10 MB.
* Reliability  — receivers re-fetch from durable storage (``refetch``);
  GETs retry with backoff on injected faults.
* Security     — metadata leg inherits gRPC TLS; S3 leg uses presigned,
  time-limited scoped URLs (``ObjectStore.presign``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.backends.base import (BackendPolicy, CommBackend, SendHandle,
                                      _delivery)
from repro.core.message import FLMessage
from repro.core.netsim import simulate_transfers
from repro.core.objectstore import S3_MAX_PARTS, ObjectStore
from repro.core.serialization import SERIALIZERS, WireData

GRPC_S3_POLICY = BackendPolicy(
    name="grpc+s3", serializer="generic", conns_per_transfer=S3_MAX_PARTS,
    per_send_copy=False, staging_bytes=1 << 20, overhead_rtts=1.0,
    ser_parallel=False, lan_uses_ib=False)


class GrpcS3Backend(CommBackend):
    def __init__(self, env, fabric, host_id, store: ObjectStore,
                 parts: int = S3_MAX_PARTS, presign: bool = True,
                 compression=None, wire_codec=None, chunk_mb: float = 0.0,
                 job=None):
        # chunk_mb accepted for interface parity but not stacked:
        # multipart PUT/GET *is* this backend's chunk pipelining.
        # Error feedback is off: the content-addressed cache re-serves a
        # stored wire for identical payloads, which is incompatible with
        # a stateful feedback loop (the residual would silently freeze on
        # cache hits while other backends kept refining)
        super().__init__(GRPC_S3_POLICY, env, fabric, host_id, store,
                         compression=compression, wire_codec=wire_codec,
                         error_feedback=False, job=job)
        assert store is not None, "grpc+s3 requires an object store"
        self.parts = parts
        self.presign = presign
        self._key_cache: dict = {}  # fingerprint -> (s3 key, upload done t)
        self.meta_serializer = SERIALIZERS["protobuf"]  # control channel

    # -- helpers ---------------------------------------------------------
    def _fingerprint(self, msg: FLMessage):
        """Content identity of the stored object = payload x wire stack:
        the same model compressed differently is a different wire, so the
        cache keys on the *post-compression* wire it would produce."""
        return (msg.payload.fingerprint(), self.channel.signature())

    def _upload(self, msg: FLMessage, now: float) -> Tuple[str, float]:
        """Stack-encode + upload payload if new; returns (key, done_t).
        Repeated sends of the same model reuse the cached key."""
        fp = self._fingerprint(msg)
        if fp in self._key_cache and self.store.has(self._key_cache[fp][0]):
            key, done = self._key_cache[fp]
            self.store.note_cache_hit()
            # the cached upload may still be in flight (concurrent isends
            # of the same model): readers wait for it to land
            return key, max(now, done)
        # bucket-wide content index: another sender — possibly another
        # tenant — already PUT this exact (payload, stack) wire. Content
        # identity is job-blind on purpose, so two jobs shipping the same
        # base model share one stored object; a foreign-tenant hit is
        # counted as a cross_job_hit in this job's wire stats
        shared = self.store.content_lookup(fp)
        if shared is not None:
            key, up_job, done = shared
            self.store.note_cache_hit()
            if up_job != self.job_name:
                self.fabric.account(0.0, messages=0, cross_job_hits=1,
                                    job=self.job_name)
            self._key_cache[fp] = (key, done)
            return key, max(now, done)
        # one shared compression stream for the store (a single object
        # serves every receiver), hence peer="s3"
        enc = self.channel.encode(msg.payload, peer="s3")
        ser_t = enc.cost_s
        ser_start = self._ser_slot(now, ser_t)
        mem = self.endpoint.memory
        alloc = enc.wire.nbytes + self.policy.staging_bytes + enc.extra_alloc
        mem.alloc(alloc, ser_start)
        key = self.store.content_key(fp, msg.round, msg.sender)
        src = self.env.host(self.host_id)
        up_t = self.store.put_time(enc.wire.nbytes, src, self.parts)
        done = ser_start + ser_t + up_t
        self.store.put(key, enc.wire, enc.wire.nbytes, done)
        self.store.note_content(fp, key, self.job_name, done)
        mem.free(alloc, done)
        self._key_cache[fp] = (key, done)
        return key, done

    def has_cached_upload(self, msg: FLMessage) -> bool:
        """Would sending this payload re-serve the stored object (no
        sender re-upload)? The late-join re-fetch accounting hinges on
        this: a rejoining client only gets the single-upload/multi-
        download deal if the current model is still in the store."""
        if msg.payload is None:
            return False
        fp = self._fingerprint(msg)
        return fp in self._key_cache and self.store.has(self._key_cache[fp][0])

    def _meta_msg(self, msg: FLMessage, key: str) -> FLMessage:
        extra = {"s3_key": key, "payload_nbytes": msg.payload_nbytes}
        if self.presign:
            url = self.store.presign(key, "get", 0.0)
            extra["presigned"] = url.token
        return msg.meta_only(extra)

    def _meta_duration(self, region) -> float:
        return self._overhead(region) + region.latency + 256 / region.bw_single

    # -- api -------------------------------------------------------------
    def isend(self, msg: FLMessage, now: float):
        """Non-blocking hybrid send: payload to the object store once,
        metadata record over gRPC; the receiver pulls on inbox pop."""
        if msg.payload is None:
            return super().isend(msg, now)
        key, up_done = self._upload(msg, now)
        meta = self._meta_msg(msg, key)
        edge = self._edge(msg.receiver)
        region = edge.region
        # the gRPC control leg rides the same faultable link as every
        # direct backend; the payload leg's resilience is the store's
        # (durable object + GET retries), so a failed *meta* record is
        # the only way this send can fail
        fin, give_up = self._link_schedule(msg.receiver, up_done, 256,
                                           region.bw_single, edge, None, 0)
        if fin is None:
            # start = the give-up time (when the sender learns of the loss)
            return SendHandle(msg=msg, issued=now, start=give_up,
                              inbox_t=float("inf"), arrive=float("inf"),
                              nbytes=self.store.size(key), failed=True)
        arrive_meta = self.fabric.deliver(
            meta, WireData(nbytes=256), up_done,
            self._overhead(region) + region.latency + fin - up_done,
            job=self.job_name)
        # receiver pulls from S3 after metadata arrives; what moves is the
        # stored (post-stack, possibly compressed) wire, not the payload
        wire_nbytes = self.store.size(key)
        dst = self.env.host(msg.receiver)
        get_t = self.store.get_time(wire_nbytes, dst, self.parts)
        # the GET leg rides the store, not Fabric.deliver (which counted
        # only the 256 B meta record): account the payload bytes so
        # bytes_on_wire is comparable across backends and modes
        self.fabric.account(wire_nbytes, messages=0, job=self.job_name)
        return SendHandle(msg=msg, issued=now, start=up_done,
                          inbox_t=arrive_meta, arrive=arrive_meta + get_t,
                          nbytes=wire_nbytes)

    def broadcast(self, msgs: Sequence[FLMessage], now: float):
        """Single upload + N concurrent multipart downloads."""
        assert all(m.payload is not None for m in msgs)
        key, up_done = self._upload(msgs[0], now)
        arrives = []
        transfers = []
        metas = []
        fm = self.fabric.fault_model
        for msg in msgs:
            meta = self._meta_msg(msg, key)
            edge = self._edge(msg.receiver)
            region = edge.region
            meta_arrive = up_done + self._meta_duration(region)
            if fm is not None:
                # the meta legs ride the same faultable control links as
                # every direct backend's broadcast: blackout-shifted
                # departure + forced (reliable-stream) retransmits
                dep = fm.delay((self.host_id, msg.receiver), up_done)
                n = fm.attempts(self.host_id, msg.receiver,
                                self.fabric.next_transfer_id(self.job_name),
                                0, forced=True)
                meta_arrive = dep - up_done + meta_arrive + (n - 1) * (
                    256 / region.bw_single + fm.detect_delay(edge))
                if n > 1:
                    self.fabric.account(0.0, 0, retransmits=n - 1,
                                        job=self.job_name)
            dst = self.env.host(msg.receiver)
            tr = self.store.get_transfer(key, dst, meta_arrive, self.parts)
            transfers.append(tr)
            metas.append((msg, meta))
        simulate_transfers(transfers)
        for (msg, meta), tr in zip(metas, transfers):
            obj, _ = self.store.get(meta.metadata["s3_key"])
            d_t = (self.channel.decode_time(obj.wire)
                   if obj.wire is not None
                   else self.serializer.deser_time(obj.nbytes))
            self.fabric._ep(msg.receiver, self.job_name).inbox.append(
                _delivery(msg, obj.wire, tr.finish))
            # as on the direct-backend broadcast path: the store GET
            # bypasses Fabric.deliver, so count the wire bytes here
            self.fabric.account(obj.nbytes, job=self.job_name)
            arrives.append(tr.finish + d_t)
        return up_done, arrives

    def recv(self, now: float) -> List[Tuple[FLMessage, float]]:
        out = []
        for d in self.endpoint.pop_ready(now):
            msg, ready = d.msg, d.arrive_time
            if "s3_key" in msg.metadata and (d.wire is None or
                                             d.wire.nbytes <= 256):
                # metadata record: pull the object (independent connections)
                obj, attempts = self.store.get(msg.metadata["s3_key"])
                dst = self.env.host(self.host_id)
                ready += attempts * self.store.get_time(obj.nbytes, dst,
                                                        self.parts)
                if obj.wire is not None:
                    # decode by the wire's recorded stages, not this
                    # backend's serializer: the object may have been
                    # produced by a different codec (AUTO routing) or
                    # carry a compression stage
                    payload, dec_s = self.channel.decode(obj.wire)
                    ready += dec_s
                    msg = dataclasses.replace(msg, payload=payload)
            elif d.wire is not None and d.wire.nbytes > 256:
                payload, dec_s = self.channel.decode(d.wire)
                ready += dec_s
                msg = dataclasses.replace(msg, payload=payload)
            out.append((msg, ready))
        return out

    def refetch(self, key: str, now: float) -> Tuple[object, float]:
        """Late/failed receiver pulls again — no sender involvement
        (the paper's fault-tolerance claim)."""
        obj, attempts = self.store.get(key)
        dst = self.env.host(self.host_id)
        return obj, now + attempts * self.store.get_time(obj.nbytes, dst,
                                                         self.parts)

    def p2p_time(self, nbytes: int, dst_id: str) -> float:
        src = self.env.host(self.host_id)
        dst = self.env.host(dst_id)
        region = self._link_region(dst_id)
        return (self.serializer.ser_time(nbytes)
                + self.store.put_time(nbytes, src, self.parts)
                + self._meta_duration(region)
                + self.store.get_time(nbytes, dst, self.parts)
                + self.serializer.deser_time(nbytes))
