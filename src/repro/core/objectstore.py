"""S3-model object store (paper §III): durable KV with multipart parallel
GET/PUT, presigned scoped tokens, content-addressed caching (repeated sends
of the same model reuse the cached key), TTL GC, and fault-injected
retries.

Functionally real (bytes stored in memory / spillable to disk); timing is
charged through netsim: each connection sustains ``S3_CONN_BW``; a client
fetching with N parts gets min(N * S3_CONN_BW, its region multi-conn BW).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import pickle
import secrets
import time
from typing import Any, Dict, Optional

from repro.core.netsim import MB, Host, Region, Transfer
from repro.core.serialization import WireData

S3_CONN_BW = 55 * MB  # per-connection GET/PUT throughput
S3_REQ_LATENCY = 0.030  # request handling latency (s)
S3_MAX_PARTS = 16


@dataclasses.dataclass
class S3Object:
    key: str
    nbytes: int
    wire: Optional[WireData]  # None for virtual payloads
    etag: str
    created: float
    version: int


class PresignedURL:
    """Scoped, time-limited token (paper's security story for S3 leg)."""

    def __init__(self, key: str, mode: str, expires_at: float):
        self.key = key
        self.mode = mode  # get | put
        self.expires_at = expires_at
        self.token = secrets.token_hex(8)

    def valid(self, key: str, mode: str, now: float) -> bool:
        return key == self.key and mode == self.mode and now <= self.expires_at


class ObjectStore:
    """One bucket, hub-region hosted."""

    def __init__(self, region: Region, *, fail_rate: float = 0.0, seed: int = 0):
        self.region = region
        self._objects: Dict[str, S3Object] = {}
        self._versions = itertools.count(1)
        self._fail_rate = fail_rate
        self._rng_state = seed
        self.stats = {"puts": 0, "gets": 0, "retries": 0, "bytes_put": 0,
                      "bytes_get": 0, "cache_hits": 0}
        # bucket-wide content index: (payload fingerprint, stack
        # signature) -> (key, uploader job, upload-done time). Keyed
        # WITHOUT a job namespace on purpose — two tenants shipping the
        # same base model through the same wire stack share one PUT
        self._content_index: Dict[Any, tuple] = {}

    # -- content-addressed keys ----------------------------------------
    @staticmethod
    def content_key(fingerprint: int, round_: int, sender: str) -> str:
        h = hashlib.sha1(f"{fingerprint}".encode()).hexdigest()[:16]
        return f"models/{sender}/r{round_}/{h}"

    def has(self, key: str) -> bool:
        return key in self._objects

    def size(self, key: str) -> int:
        """Stored wire bytes (a HEAD request — no data-plane stats)."""
        return self._objects[key].nbytes

    def note_cache_hit(self):
        """A caller reused a content-addressed key instead of re-PUTting.
        Callers must not poke ``store.stats`` directly (see
        scripts/check_stats_discipline.py)."""
        self.stats["cache_hits"] += 1

    # -- bucket-wide content index -------------------------------------
    def note_content(self, fingerprint, key: str, job: str = "",
                     done: float = 0.0):
        """Record that ``key`` holds the wire for ``fingerprint`` (a
        (payload fingerprint, stack signature) pair), uploaded by tenant
        ``job`` and durable from ``done`` on."""
        self._content_index[fingerprint] = (key, job, done)

    def content_lookup(self, fingerprint) -> Optional[tuple]:
        """-> (key, uploader job, upload-done time) if an object with
        this content identity is still stored, else None. This is the
        cross-sender (and cross-job) half of the content-addressed
        cache: senders consult it before encoding a fresh PUT."""
        ent = self._content_index.get(fingerprint)
        if ent is None or ent[0] not in self._objects:
            return None
        return ent

    # -- data plane ------------------------------------------------------
    def _maybe_fail(self) -> bool:
        # deterministic pseudo-randomness (no wall clock)
        self._rng_state = (self._rng_state * 6364136223846793005 + 1) % 2 ** 63
        return (self._rng_state / 2 ** 63) < self._fail_rate

    def put(self, key: str, wire: Optional[WireData], nbytes: int,
            now: float) -> S3Object:
        self.stats["puts"] += 1
        self.stats["bytes_put"] += nbytes
        etag = hashlib.sha1(f"{key}:{nbytes}".encode()).hexdigest()[:12]
        obj = S3Object(key=key, nbytes=nbytes, wire=wire, etag=etag,
                       created=now, version=next(self._versions))
        self._objects[key] = obj
        return obj

    def get(self, key: str, *, max_retries: int = 3):
        """Returns (S3Object, n_attempts). Raises KeyError if missing."""
        attempts = 1
        while self._maybe_fail() and attempts <= max_retries:
            self.stats["retries"] += 1
            attempts += 1
        if key not in self._objects:
            raise KeyError(f"s3: no such key {key}")
        obj = self._objects[key]
        self.stats["gets"] += 1
        self.stats["bytes_get"] += obj.nbytes
        return obj, attempts

    def delete(self, key: str):
        self._objects.pop(key, None)

    def gc(self, now: float, ttl: float):
        dead = [k for k, o in self._objects.items() if now - o.created > ttl]
        for k in dead:
            del self._objects[k]
        return len(dead)

    def presign(self, key: str, mode: str, now: float,
                ttl: float = 3600.0) -> PresignedURL:
        return PresignedURL(key, mode, now + ttl)

    # -- timing model ------------------------------------------------------
    def put_time(self, nbytes: int, src: Host, parts: int = S3_MAX_PARTS) -> float:
        """Multipart upload from src to the bucket region."""
        cap = min(parts * S3_CONN_BW, src.region.bw_multi, src.uplink)
        return S3_REQ_LATENCY + src.region.latency + nbytes / cap

    def get_time(self, nbytes: int, dst: Host, parts: int = S3_MAX_PARTS) -> float:
        cap = min(parts * S3_CONN_BW, dst.region.bw_multi, dst.downlink)
        return S3_REQ_LATENCY + dst.region.latency + nbytes / cap

    def get_transfer(self, key: str, dst: Host, start: float,
                     parts: int = S3_MAX_PARTS) -> Transfer:
        """A Transfer for the fluid solver (S3 side is effectively
        unconstrained: independent per-client download pipes)."""
        obj = self._objects[key]
        s3_host = Host("s3", self.region, float("inf"), float("inf"))
        cap_region = Region(
            f"s3-{dst.region.name}",
            bw_single=S3_CONN_BW,
            bw_multi=min(parts * S3_CONN_BW, dst.region.bw_multi),
            latency=S3_REQ_LATENCY + dst.region.latency)
        return Transfer(start=start, src=s3_host, dst=dst, nbytes=obj.nbytes,
                        conns=parts, link_region=cap_region, tag=f"get:{key}")
