"""The paper's primary contribution: pluggable cross-silo FL communication
backends (MPI_GENERIC / MPI_MEM_BUFF / gRPC / TensorRPC / gRPC+S3 / AUTO)
over a Table-I-calibrated network model + object store."""
from repro.core.backends import BACKEND_NAMES, make_backend
from repro.core.message import (FLMessage, PackedPayload, TensorPayload,
                                VirtualPayload)
from repro.core.netsim import ENVIRONMENTS, Environment
from repro.core.objectstore import ObjectStore
from repro.core.transport import Fabric, MemoryMeter

__all__ = ["make_backend", "BACKEND_NAMES", "FLMessage", "TensorPayload",
           "VirtualPayload", "PackedPayload", "Environment",
           "ENVIRONMENTS", "ObjectStore", "Fabric", "MemoryMeter"]
