from repro.compression.qsgd import (QuantState, qsgd_compress,
                                    qsgd_decompress, qsgd_init)
from repro.compression.topk import topk_compress, topk_decompress

__all__ = ["qsgd_init", "qsgd_compress", "qsgd_decompress", "QuantState",
           "topk_compress", "topk_decompress"]
