"""Payload-level codec adapters for the wire pipeline (core/channel.py).

A codec turns one payload into a smaller one and back, *invertibly*: the
forward pass returns an ``info`` dict carrying everything the receiver
needs to reconstruct the original (tree structure, original byte size),
which the Channel records on the wire as stage provenance. Error-feedback
state (the QSGD/top-k residual) stays on the *sender* — the decode side is
stateless, so any receiver can decode any wire.

Codecs handle all three payload flavours:

* ``TensorPayload``  — real compression through the Pallas kernels
  (qsgd int8 blocks / top-k sparsification), optional error feedback;
* ``VirtualPayload`` — the byte count is scaled by the codec's wire ratio
  (paper-scale benchmark runs: identical accounting, no memcpy);
* ``PackedPayload``  — already compressed: passed through untouched.

Simulated codec throughputs are accelerator-class (the quantize kernel is
bandwidth-bound, far from the protobuf serializer's 0.16 GB/s): they make
compression cheap but not free, so the win on a LAN-class hop can vanish
while the WAN hop win stays large — which is the point of Fig 7.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.compression.qsgd import QuantState, qsgd_compress, qsgd_decompress
from repro.compression.topk import topk_compress, topk_decompress
from repro.core.message import (PackedPayload, TensorPayload, VirtualPayload)
from repro.kernels import ops

GB = 1024 ** 3


def tree_meta(tree):
    """Picklable structure record: (treedef, shapes, dtypes)."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, [np.shape(l) for l in leaves],
            [np.asarray(l).dtype for l in leaves])


def unflatten_from_meta(vec, meta):
    """Inverse of ``ops.flatten_pytree`` driven by a ``tree_meta`` record
    (the closure returned by flatten_pytree cannot travel on a wire)."""
    treedef, shapes, dtypes = meta
    out, off = [], 0
    vec = np.asarray(vec)
    for shape, dt in zip(shapes, dtypes):
        size = int(np.prod(shape)) if shape else 1
        out.append(vec[off:off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree.unflatten(treedef, out)


class BaseCodec:
    """compress(payload, state) -> (payload', new_state, info);
    decompress(payload', info) -> payload. ``info`` is wire provenance.

    ``domain`` marks where in the channel the codec acts: ``payload``
    codecs (qsgd/topk) need tensor semantics and run before the
    serializer; ``wire`` codecs (zlib-family) are byte transforms of the
    serialized wire and run after it (channel.WireCompressStage)."""

    name = "codec"
    domain = "payload"
    enc_bw = 2.0 * GB  # simulated compress throughput (bytes/s of input)
    dec_bw = 4.0 * GB  # simulated decompress throughput

    def signature(self) -> str:
        raise NotImplementedError

    def ratio(self) -> float:
        """Wire bytes per input byte (virtual-payload scaling)."""
        raise NotImplementedError

    def enc_time(self, orig_nbytes: int) -> float:
        return orig_nbytes / self.enc_bw

    def dec_time(self, orig_nbytes: int) -> float:
        return orig_nbytes / self.dec_bw

    # -- shared plumbing -------------------------------------------------
    def compress(self, payload, state=None) -> Tuple[object, object, Optional[dict]]:
        if isinstance(payload, PackedPayload):
            return payload, state, None  # already compressed: skip stage
        if isinstance(payload, VirtualPayload):
            nb = int(round(payload.nbytes * self.ratio()))
            out = VirtualPayload(nb, tag=f"{payload.tag}|{self.name}")
            return out, state, {"codec": self.name, "virtual": True,
                                "orig_nbytes": payload.nbytes,
                                "orig_tag": payload.tag}
        if isinstance(payload, TensorPayload):
            return self._compress_tree(payload, state)
        raise TypeError(f"{self.name}: cannot compress {type(payload)}")

    def decompress(self, payload, info):
        if info is None:
            return payload
        if info.get("virtual"):
            return VirtualPayload(info["orig_nbytes"],
                                  tag=info.get("orig_tag", ""))
        return self._decompress_tree(payload, info)

    def init_state(self, payload):
        """Fresh error-feedback state for a tensor payload (None = EF off
        or payload not a tensor)."""
        if isinstance(payload, TensorPayload):
            flat, _ = ops.flatten_pytree(payload.tree)
            return QuantState(error=np.zeros_like(np.asarray(flat)))
        return None

    def state_matches(self, state, payload) -> bool:
        """Does an existing residual fit this payload? (A peer stream can
        legally carry differently-shaped messages; feedback only composes
        across same-shaped ones.)"""
        if state is None or not isinstance(payload, TensorPayload):
            return False
        elems = sum(int(np.prod(np.shape(l)))
                    for l in jax.tree.leaves(payload.tree))
        return int(np.size(state.error)) == elems


class QsgdCodec(BaseCodec):
    """QSGD int8 block quantisation (Alistarh et al. 2017) behind the
    Pallas quantize kernel. Wire = int8 values + one f32 scale per block."""

    name = "qsgd"

    def __init__(self, block: int = 256):
        self.block = int(block)

    def signature(self) -> str:
        return f"qsgd(b{self.block})"

    def ratio(self) -> float:
        # f32 -> int8 (1/4) plus a 4-byte scale per `block` elements
        return 0.25 * (1.0 + 4.0 / self.block)

    def _compress_tree(self, payload: TensorPayload, state):
        packed, new_state, _ = qsgd_compress(payload.tree, state,
                                             block=self.block)
        packed = jax.tree.map(np.asarray, packed)
        out = PackedPayload(packed)
        info = {"codec": self.name, "orig_nbytes": payload.nbytes,
                "tree_meta": tree_meta(payload.tree)}
        return out, new_state, info

    def _decompress_tree(self, payload: PackedPayload, info):
        flat = ops.dequantize_flat(payload.packed)
        return TensorPayload(unflatten_from_meta(flat, info["tree_meta"]))


class TopkCodec(BaseCodec):
    """Magnitude top-k sparsification (Wangni et al. 2018). Wire = int32
    indices + f32 values of the k largest-|.| coordinates."""

    name = "topk"

    def __init__(self, k_frac: float = 0.05):
        self.k_frac = float(k_frac)

    def signature(self) -> str:
        return f"topk(k{self.k_frac:g})"

    def ratio(self) -> float:
        return 2.0 * self.k_frac  # (4B idx + 4B val) per kept f32 element

    def _compress_tree(self, payload: TensorPayload, state):
        sparse, new_state, _ = topk_compress(payload.tree, self.k_frac, state)
        sparse = jax.tree.map(np.asarray, sparse)
        out = PackedPayload(sparse)
        info = {"codec": self.name, "orig_nbytes": payload.nbytes,
                "tree_meta": tree_meta(payload.tree)}
        return out, new_state, info

    def _decompress_tree(self, payload: PackedPayload, info):
        p = payload.packed
        flat = np.zeros(int(p["n"]), np.asarray(p["vals"]).dtype)
        flat[np.asarray(p["idx"])] = np.asarray(p["vals"])
        return TensorPayload(unflatten_from_meta(flat, info["tree_meta"]))


class ZlibCodec(BaseCodec):
    """DEFLATE byte codec in the *wire* domain (the ROADMAP's zstd-family
    slot): compresses the serialized wire's actual buffers — payload
    semantics untouched, losslessly invertible from the wire's recorded
    provenance like every other stage. Real wires carry real deflated
    bytes; virtual (sized-only) wires scale by ``WIRE_RATIO``, a
    modelling constant for DEFLATE on fp32 weight streams."""

    name = "zlib"
    domain = "wire"
    enc_bw = 0.35 * GB  # single-stream DEFLATE-class throughputs
    dec_bw = 1.10 * GB
    WIRE_RATIO = 0.85

    def __init__(self, level: int = 6):
        self.level = int(level)
        if not 1 <= self.level <= 9:
            raise KeyError(f"zlib level must be in 1..9, got {self.level}")

    def signature(self) -> str:
        return f"zlib(l{self.level})"

    def ratio(self) -> float:
        return self.WIRE_RATIO

    # -- wire-domain API (channel.WireCompressStage) ---------------------
    def compress_wire(self, wire):
        """WireData -> (smaller WireData, provenance info)."""
        import zlib

        from repro.core.serialization import WireData
        if wire.buffers is None:
            nb = int(round(wire.nbytes * self.ratio()))
            info = {"stage": "wirecodec", "codec": self.name,
                    "level": self.level, "orig_nbytes": wire.nbytes,
                    "virtual": True}
            return WireData(nbytes=nb, copied=True, obj=wire.obj,
                            codec=wire.codec), info
        bufs, metas = [], []
        for b in wire.buffers:
            if isinstance(b, (bytes, bytearray, memoryview)):
                raw, meta = bytes(b), None
            else:
                arr = np.ascontiguousarray(b)
                raw, meta = arr.tobytes(), (arr.shape, str(arr.dtype))
            bufs.append(zlib.compress(raw, self.level))
            metas.append(meta)
        out = WireData(nbytes=sum(len(b) for b in bufs), buffers=bufs,
                       copied=True, obj=wire.obj, codec=wire.codec)
        info = {"stage": "wirecodec", "codec": self.name,
                "level": self.level, "orig_nbytes": wire.nbytes,
                "buf_meta": metas}
        return out, info

    def decompress_wire(self, wire, info):
        """Inverse transform: reconstructs the original wire (buffer
        boundaries + array shapes/dtypes ride in the provenance)."""
        import zlib

        from repro.core.serialization import WireData
        if info.get("virtual"):
            return WireData(nbytes=info["orig_nbytes"], obj=wire.obj,
                            codec=wire.codec)
        bufs = []
        for b, meta in zip(wire.buffers, info["buf_meta"]):
            raw = zlib.decompress(b)
            if meta is None:
                bufs.append(raw)
            else:
                shape, dtype = meta
                bufs.append(np.frombuffer(raw, dtype=np.dtype(dtype))
                            .reshape(shape))
        return WireData(nbytes=info["orig_nbytes"], buffers=bufs,
                        copied=True, obj=wire.obj, codec=wire.codec)


def make_codec(spec) -> Optional[BaseCodec]:
    """Parse a compression spec: None/'none' -> None, 'qsgd'/'qsgd:128'
    (block), 'topk'/'topk:0.1' (kept fraction), 'zlib'/'zlib:9' (wire
    domain, DEFLATE level), or a BaseCodec instance."""
    if spec is None or isinstance(spec, BaseCodec):
        return spec
    spec = str(spec).strip().lower()
    if spec in ("", "none"):
        return None
    name, _, arg = spec.partition(":")
    if name == "qsgd":
        return QsgdCodec(block=int(arg)) if arg else QsgdCodec()
    if name == "topk":
        return TopkCodec(k_frac=float(arg)) if arg else TopkCodec()
    if name == "zlib":
        return ZlibCodec(level=int(arg)) if arg else ZlibCodec()
    raise KeyError(f"unknown compression spec '{spec}' "
                   "(use none | qsgd[:block] | topk[:frac] | zlib[:level])")


def split_codecs(compression, wire_codec):
    """Normalise the two channel codec knobs into (payload_codec,
    wire_codec) instances — the ONE place the 'a byte codec named via
    ``compression`` belongs in the wire slot' rule lives (make_channel,
    make_backend and the scenario resolver all route through it).
    Raises ValueError when two *different* wire codecs are named."""
    codec = make_codec(compression)
    wcodec = make_codec(wire_codec)
    if codec is not None and getattr(codec, "domain", "payload") == "wire":
        if wcodec is not None and wcodec.signature() != codec.signature():
            raise ValueError(
                f"two wire codecs requested: compression="
                f"'{codec.signature()}' and wire_codec="
                f"'{wcodec.signature()}'")
        return None, codec
    return codec, wcodec


CODECS = {"qsgd": QsgdCodec, "topk": TopkCodec, "zlib": ZlibCodec}


def codec_for(name: str) -> BaseCodec:
    """Default-parameter codec instance for decode-side inversion (all
    decode parameters ride in the wire's stage info, so defaults are
    fine)."""
    return CODECS[name]()
