"""Payload-level codec adapters for the wire pipeline (core/channel.py).

A codec turns one payload into a smaller one and back, *invertibly*: the
forward pass returns an ``info`` dict carrying everything the receiver
needs to reconstruct the original (tree structure, original byte size),
which the Channel records on the wire as stage provenance. Error-feedback
state (the QSGD/top-k residual) stays on the *sender* — the decode side is
stateless, so any receiver can decode any wire.

Codecs handle all three payload flavours:

* ``TensorPayload``  — real compression through the Pallas kernels
  (qsgd int8 blocks / top-k sparsification), optional error feedback;
* ``VirtualPayload`` — the byte count is scaled by the codec's wire ratio
  (paper-scale benchmark runs: identical accounting, no memcpy);
* ``PackedPayload``  — already compressed: passed through untouched.

Simulated codec throughputs are accelerator-class (the quantize kernel is
bandwidth-bound, far from the protobuf serializer's 0.16 GB/s): they make
compression cheap but not free, so the win on a LAN-class hop can vanish
while the WAN hop win stays large — which is the point of Fig 7.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.compression.qsgd import (QuantState, qsgd_compress,
                                    qsgd_compress_flat_batch,
                                    qsgd_decompress)
from repro.compression.topk import (topk_compress, topk_compress_flat_batch,
                                    topk_decompress)
from repro.core.message import (PackedPayload, TensorPayload, VirtualPayload)
from repro.kernels import ops

GB = 1024 ** 3


def tree_meta(tree):
    """Picklable structure record: (treedef, shapes, dtypes)."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, [np.shape(l) for l in leaves],
            [np.asarray(l).dtype for l in leaves])


def unflatten_from_meta(vec, meta):
    """Inverse of ``ops.flatten_pytree`` driven by a ``tree_meta`` record
    (the closure returned by flatten_pytree cannot travel on a wire)."""
    treedef, shapes, dtypes = meta
    out, off = [], 0
    vec = np.asarray(vec)
    for shape, dt in zip(shapes, dtypes):
        size = int(np.prod(shape)) if shape else 1
        out.append(vec[off:off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree.unflatten(treedef, out)


class BaseCodec:
    """compress(payload, state) -> (payload', new_state, info);
    decompress(payload', info) -> payload. ``info`` is wire provenance.

    ``domain`` marks where in the channel the codec acts: ``payload``
    codecs (qsgd/topk) need tensor semantics and run before the
    serializer; ``wire`` codecs (zlib-family) are byte transforms of the
    serialized wire and run after it (channel.WireCompressStage)."""

    name = "codec"
    domain = "payload"
    enc_bw = 2.0 * GB  # simulated compress throughput (bytes/s of input)
    dec_bw = 4.0 * GB  # simulated decompress throughput

    def signature(self) -> str:
        raise NotImplementedError

    def ratio(self) -> float:
        """Wire bytes per input byte (virtual-payload scaling)."""
        raise NotImplementedError

    def enc_time(self, orig_nbytes: int) -> float:
        return orig_nbytes / self.enc_bw

    def dec_time(self, orig_nbytes: int) -> float:
        return orig_nbytes / self.dec_bw

    # -- shared plumbing -------------------------------------------------
    def compress(self, payload, state=None) -> Tuple[object, object, Optional[dict]]:
        if isinstance(payload, PackedPayload):
            return payload, state, None  # already compressed: skip stage
        if isinstance(payload, VirtualPayload):
            nb = int(round(payload.nbytes * self.ratio()))
            out = VirtualPayload(nb, tag=f"{payload.tag}|{self.name}")
            return out, state, {"codec": self.name, "virtual": True,
                                "orig_nbytes": payload.nbytes,
                                "orig_tag": payload.tag}
        if isinstance(payload, TensorPayload):
            return self._compress_tree(payload, state)
        raise TypeError(f"{self.name}: cannot compress {type(payload)}")

    def decompress(self, payload, info):
        if info is None:
            return payload
        if info.get("virtual"):
            return VirtualPayload(info["orig_nbytes"],
                                  tag=info.get("orig_tag", ""))
        return self._decompress_tree(payload, info)

    def init_state(self, payload):
        """Fresh error-feedback state for a tensor payload (None = EF off
        or payload not a tensor)."""
        if isinstance(payload, TensorPayload):
            flat, _ = ops.flatten_pytree(payload.tree)
            return QuantState(error=np.zeros_like(np.asarray(flat)))
        return None

    def state_matches(self, state, payload) -> bool:
        """Does an existing residual fit this payload? (A peer stream can
        legally carry differently-shaped messages; feedback only composes
        across same-shaped ones.)"""
        if state is None or not isinstance(payload, TensorPayload):
            return False
        elems = sum(int(np.prod(np.shape(l)))
                    for l in jax.tree.leaves(payload.tree))
        return int(np.size(state.error)) == elems

    # -- batched surface -------------------------------------------------
    def encode_batch(self, payloads, states):
        """[payload_i], [state_i] -> [(payload'_i, new_state_i, info_i)].

        The array-native entry point: a channel (or a round's worth of
        channels) hands every outstanding encode over at once and a codec
        that can fuse them into one kernel dispatch does (QsgdCodec).
        The base implementation is the per-message loop, so every codec
        has the surface and ``compress`` is exactly ``encode_batch`` of
        one — same wire bytes, same info, same state transitions."""
        return [self.compress(p, s) for p, s in zip(payloads, states)]

    def decode_batch(self, payloads, infos):
        """[payload'_i], [info_i] -> [payload_i]; inverse of encode_batch
        (stateless, like ``decompress``)."""
        return [self.decompress(p, i) for p, i in zip(payloads, infos)]


class QsgdCodec(BaseCodec):
    """QSGD int8 block quantisation (Alistarh et al. 2017) behind the
    Pallas quantize kernel. Wire = int8 values + one f32 scale per block."""

    name = "qsgd"

    def __init__(self, block: int = 256):
        self.block = int(block)

    def signature(self) -> str:
        return f"qsgd(b{self.block})"

    def ratio(self) -> float:
        # f32 -> int8 (1/4) plus a 4-byte scale per `block` elements
        return 0.25 * (1.0 + 4.0 / self.block)

    def _compress_tree(self, payload: TensorPayload, state):
        packed, new_state, _ = qsgd_compress(payload.tree, state,
                                             block=self.block)
        packed = jax.tree.map(np.asarray, packed)
        out = PackedPayload(packed)
        info = {"codec": self.name, "orig_nbytes": payload.nbytes,
                "tree_meta": tree_meta(payload.tree)}
        return out, new_state, info

    def encode_batch(self, payloads, states):
        """Fused override: every TensorPayload in the batch is flattened
        into one (rows, block) array and quantised in a single kernel
        dispatch (kernels/ops.quantize_flat_batch); per-item wire bytes,
        info and error-feedback transitions are bit-identical to the
        per-message path. Non-tensor payloads fall through to the scalar
        rules in declaration order."""
        tensor_idx = [i for i, p in enumerate(payloads)
                      if isinstance(p, TensorPayload)]
        tensor_set = set(tensor_idx)
        out = [None] * len(payloads)
        for i, (p, s) in enumerate(zip(payloads, states)):
            if i not in tensor_set:
                out[i] = self.compress(p, s)
        if tensor_idx:
            flats = [ops.flatten_pytree(payloads[i].tree)[0]
                     for i in tensor_idx]
            packed, new_states = qsgd_compress_flat_batch(
                flats, [states[i] for i in tensor_idx], block=self.block)
            for i, pk, ns in zip(tensor_idx, packed, new_states):
                pk = jax.tree.map(np.asarray, pk)
                info = {"codec": self.name,
                        "orig_nbytes": payloads[i].nbytes,
                        "tree_meta": tree_meta(payloads[i].tree)}
                out[i] = (PackedPayload(pk), ns, info)
        return out

    def _decompress_tree(self, payload: PackedPayload, info):
        flat = ops.dequantize_flat_batch([payload.packed])[0]
        return TensorPayload(unflatten_from_meta(flat, info["tree_meta"]))

    def decode_batch(self, payloads, infos):
        """Fused inverse: one dequantize dispatch for every packed tensor
        in the batch."""
        packed_idx = [i for i, (p, inf) in enumerate(zip(payloads, infos))
                      if inf is not None and not inf.get("virtual")
                      and isinstance(p, PackedPayload)]
        packed_set = set(packed_idx)
        out = [None] * len(payloads)
        for i, (p, inf) in enumerate(zip(payloads, infos)):
            if i not in packed_set:
                out[i] = self.decompress(p, inf)
        if packed_idx:
            flats = ops.dequantize_flat_batch(
                [payloads[i].packed for i in packed_idx])
            for i, flat in zip(packed_idx, flats):
                out[i] = TensorPayload(unflatten_from_meta(
                    flat, infos[i]["tree_meta"]))
        return out


class TopkCodec(BaseCodec):
    """Magnitude top-k sparsification (Wangni et al. 2018). Wire = int32
    indices + f32 values of the k largest-|.| coordinates."""

    name = "topk"

    def __init__(self, k_frac: float = 0.05):
        self.k_frac = float(k_frac)

    def signature(self) -> str:
        return f"topk(k{self.k_frac:g})"

    def ratio(self) -> float:
        return 2.0 * self.k_frac  # (4B idx + 4B val) per kept f32 element

    def _compress_tree(self, payload: TensorPayload, state):
        sparse, new_state, _ = topk_compress(payload.tree, self.k_frac, state)
        sparse = jax.tree.map(np.asarray, sparse)
        out = PackedPayload(sparse)
        info = {"codec": self.name, "orig_nbytes": payload.nbytes,
                "tree_meta": tree_meta(payload.tree)}
        return out, new_state, info

    def encode_batch(self, payloads, states):
        """Fused override (the QsgdCodec rule applied to sparsification):
        every TensorPayload in the batch routes through one Pallas top-k
        dispatch per (length, k) group (kernels/ops.topk_flat_batch);
        per-item sparse wires, info and error-feedback transitions are
        bit-identical to the per-message path. Non-tensor payloads fall
        through to the scalar rules in declaration order."""
        tensor_idx = [i for i, p in enumerate(payloads)
                      if isinstance(p, TensorPayload)]
        tensor_set = set(tensor_idx)
        out = [None] * len(payloads)
        for i, (p, s) in enumerate(zip(payloads, states)):
            if i not in tensor_set:
                out[i] = self.compress(p, s)
        if tensor_idx:
            flats = [ops.flatten_pytree(payloads[i].tree)[0]
                     for i in tensor_idx]
            sparse, new_states = topk_compress_flat_batch(
                flats, [states[i] for i in tensor_idx], k_frac=self.k_frac)
            for i, sp, ns in zip(tensor_idx, sparse, new_states):
                sp = jax.tree.map(np.asarray, sp)
                info = {"codec": self.name,
                        "orig_nbytes": payloads[i].nbytes,
                        "tree_meta": tree_meta(payloads[i].tree)}
                out[i] = (PackedPayload(sp), ns, info)
        return out

    def _decompress_tree(self, payload: PackedPayload, info):
        p = payload.packed
        flat = np.zeros(int(p["n"]), np.asarray(p["vals"]).dtype)
        flat[np.asarray(p["idx"])] = np.asarray(p["vals"])
        return TensorPayload(unflatten_from_meta(flat, info["tree_meta"]))


class ZlibCodec(BaseCodec):
    """DEFLATE byte codec in the *wire* domain (the ROADMAP's zstd-family
    slot): compresses the serialized wire's actual buffers — payload
    semantics untouched, losslessly invertible from the wire's recorded
    provenance like every other stage. Real wires carry real deflated
    bytes; virtual (sized-only) wires scale by ``WIRE_RATIO``, a
    modelling constant for DEFLATE on fp32 weight streams."""

    name = "zlib"
    domain = "wire"
    enc_bw = 0.35 * GB  # single-stream DEFLATE-class throughputs
    dec_bw = 1.10 * GB
    WIRE_RATIO = 0.85

    def __init__(self, level: int = 6):
        self.level = int(level)
        if not 1 <= self.level <= 9:
            raise KeyError(f"zlib level must be in 1..9, got {self.level}")

    def signature(self) -> str:
        return f"zlib(l{self.level})"

    def ratio(self) -> float:
        return self.WIRE_RATIO

    # -- the byte transform (ZstdCodec overrides) ------------------------
    @property
    def impl(self) -> str:
        """Which byte transform actually runs (recorded as provenance so
        any receiver inverts by what the wire says, not what it has)."""
        return "zlib"

    def _deflate(self, raw: bytes) -> bytes:
        import zlib
        return zlib.compress(raw, self.level)

    @staticmethod
    def _inflate(data: bytes, info: dict) -> bytes:
        impl = info.get("impl", "zlib")
        if impl == "zlib":
            import zlib
            return zlib.decompress(data)
        if impl == "zstd":
            binding = zstd_binding()
            if binding is None:
                raise RuntimeError(
                    "wire records zstd-compressed buffers but neither "
                    "'zstandard' nor 'zstd' is importable here")
            return binding[1](data)
        raise KeyError(f"unknown wire-codec impl '{impl}'")

    # -- wire-domain API (channel.WireCompressStage) ---------------------
    def compress_wire(self, wire):
        """WireData -> (smaller WireData, provenance info)."""
        from repro.core.serialization import WireData
        if wire.buffers is None:
            nb = int(round(wire.nbytes * self.ratio()))
            info = {"stage": "wirecodec", "codec": self.name,
                    "level": self.level, "orig_nbytes": wire.nbytes,
                    "virtual": True}
            return WireData(nbytes=nb, copied=True, obj=wire.obj,
                            codec=wire.codec), info
        bufs, metas = [], []
        for b in wire.buffers:
            if isinstance(b, (bytes, bytearray, memoryview)):
                raw, meta = bytes(b), None
            else:
                arr = np.ascontiguousarray(b)
                raw, meta = arr.tobytes(), (arr.shape, str(arr.dtype))
            bufs.append(self._deflate(raw))
            metas.append(meta)
        out = WireData(nbytes=sum(len(b) for b in bufs), buffers=bufs,
                       copied=True, obj=wire.obj, codec=wire.codec)
        info = {"stage": "wirecodec", "codec": self.name,
                "level": self.level, "impl": self.impl,
                "orig_nbytes": wire.nbytes, "buf_meta": metas}
        return out, info

    def decompress_wire(self, wire, info):
        """Inverse transform: reconstructs the original wire (buffer
        boundaries + array shapes/dtypes + the byte-transform impl ride
        in the provenance)."""
        from repro.core.serialization import WireData
        if info.get("virtual"):
            return WireData(nbytes=info["orig_nbytes"], obj=wire.obj,
                            codec=wire.codec)
        bufs = []
        for b, meta in zip(wire.buffers, info["buf_meta"]):
            raw = self._inflate(b, info)
            if meta is None:
                bufs.append(raw)
            else:
                shape, dtype = meta
                bufs.append(np.frombuffer(raw, dtype=np.dtype(dtype))
                            .reshape(shape))
        return WireData(nbytes=info["orig_nbytes"], buffers=bufs,
                        copied=True, obj=wire.obj, codec=wire.codec)


def zstd_binding():
    """-> (compress(raw, level), decompress(data)) through whichever zstd
    python binding is importable, or None (this container bakes neither;
    the ZstdCodec then deflates with zlib and says so in provenance)."""
    try:
        import zstandard
        return (lambda raw, lvl: zstandard.ZstdCompressor(
                    level=lvl).compress(raw),
                lambda data: zstandard.ZstdDecompressor().decompress(data))
    except ImportError:
        pass
    try:
        import zstd as _zstd
        return (lambda raw, lvl: _zstd.compress(raw, lvl),
                lambda data: _zstd.decompress(data))
    except ImportError:
        return None


class ZstdCodec(ZlibCodec):
    """The ROADMAP's carried-over real-zstd slot: when a zstd binding
    (``zstandard`` or ``zstd``) is importable, real wire buffers are
    zstd frames; otherwise the byte transform gracefully falls back to
    DEFLATE. Provenance records which transform actually ran (``impl``),
    so a receiver with a different environment still inverts correctly.

    Simulated enc/dec throughputs and the virtual wire ratio are fixed
    zstd-class modelling constants — independent of the binding, so
    sized-only (paper-scale) runs are deterministic across machines.
    Real-buffer runs inherit the actual compressed byte count, which is
    the point of the real binding."""

    name = "zstd"
    enc_bw = 1.5 * GB  # zstd-class single-stream throughputs
    dec_bw = 3.5 * GB
    WIRE_RATIO = 0.82

    def __init__(self, level: int = 3):
        self.level = int(level)
        if not 1 <= self.level <= 19:
            raise KeyError(f"zstd level must be in 1..19, got {self.level}")
        self._binding = zstd_binding()

    def signature(self) -> str:
        return f"zstd(l{self.level})"

    @property
    def impl(self) -> str:
        return "zstd" if self._binding is not None else "zlib"

    def _deflate(self, raw: bytes) -> bytes:
        if self._binding is not None:
            return self._binding[0](raw, self.level)
        import zlib
        return zlib.compress(raw, min(self.level, 9))


def make_codec(spec) -> Optional[BaseCodec]:
    """Parse a compression spec: None/'none' -> None, 'qsgd'/'qsgd:128'
    (block), 'topk'/'topk:0.1' (kept fraction), 'zlib'/'zlib:9' or
    'zstd'/'zstd:3' (wire domain, byte-codec level), or a BaseCodec
    instance."""
    if spec is None or isinstance(spec, BaseCodec):
        return spec
    spec = str(spec).strip().lower()
    if spec in ("", "none"):
        return None
    name, _, arg = spec.partition(":")
    if name == "qsgd":
        return QsgdCodec(block=int(arg)) if arg else QsgdCodec()
    if name == "topk":
        return TopkCodec(k_frac=float(arg)) if arg else TopkCodec()
    if name == "zlib":
        return ZlibCodec(level=int(arg)) if arg else ZlibCodec()
    if name == "zstd":
        return ZstdCodec(level=int(arg)) if arg else ZstdCodec()
    raise KeyError(f"unknown compression spec '{spec}' (use none | "
                   "qsgd[:block] | topk[:frac] | zlib[:level] | "
                   "zstd[:level])")


def split_codecs(compression, wire_codec):
    """Normalise the two channel codec knobs into (payload_codec,
    wire_codec) instances — the ONE place the 'a byte codec named via
    ``compression`` belongs in the wire slot' rule lives (make_channel,
    make_backend and the scenario resolver all route through it).
    Raises ValueError when two *different* wire codecs are named."""
    codec = make_codec(compression)
    wcodec = make_codec(wire_codec)
    if codec is not None and getattr(codec, "domain", "payload") == "wire":
        if wcodec is not None and wcodec.signature() != codec.signature():
            raise ValueError(
                f"two wire codecs requested: compression="
                f"'{codec.signature()}' and wire_codec="
                f"'{wcodec.signature()}'")
        return None, codec
    return codec, wcodec


CODECS = {"qsgd": QsgdCodec, "topk": TopkCodec, "zlib": ZlibCodec,
          "zstd": ZstdCodec}


def codec_for(name: str) -> BaseCodec:
    """Default-parameter codec instance for decode-side inversion (all
    decode parameters ride in the wire's stage info, so defaults are
    fine)."""
    return CODECS[name]()
