"""QSGD-style int8 compression with error feedback (Alistarh et al. 2017 —
the paper cites this family as orthogonal to the backend choice; here it
composes with any backend and with the cross-pod sync).

Uses the Pallas quantisation kernel. 4x (f32) / 2x (bf16) wire reduction;
error feedback keeps local-SGD convergence unbiased in practice.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


class QuantState(NamedTuple):
    error: jax.Array  # flat f32 residual carried between rounds


def qsgd_init(example_tree) -> QuantState:
    flat, _ = ops.flatten_pytree(example_tree)
    return QuantState(error=jnp.zeros_like(flat))


def qsgd_compress(tree, state: Optional[QuantState] = None, *,
                  block: int = 256, interpret=None):
    """-> (packed dict, new_state, unflatten). Wire payload = packed."""
    flat, unflatten = ops.flatten_pytree(tree)
    if state is not None:
        flat = flat + state.error
    packed = ops.quantize_flat(flat, block=block, interpret=interpret)
    recon = ops.dequantize_flat(packed, interpret=interpret)
    new_state = QuantState(error=flat - recon) if state is not None else None
    return packed, new_state, unflatten


def qsgd_decompress(packed, unflatten, *, interpret=None):
    return unflatten(ops.dequantize_flat(packed, interpret=interpret))


def packed_nbytes(packed) -> int:
    """Wire size of a packed payload (int8 + f32 scales)."""
    return int(packed["q"].size) + int(packed["scales"].size) * 4
