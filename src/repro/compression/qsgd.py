"""QSGD-style int8 compression with error feedback (Alistarh et al. 2017 —
the paper cites this family as orthogonal to the backend choice; here it
composes with any backend and with the cross-pod sync).

Uses the Pallas quantisation kernel. 4x (f32) / 2x (bf16) wire reduction;
error feedback keeps local-SGD convergence unbiased in practice.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


class QuantState(NamedTuple):
    error: jax.Array  # flat f32 residual carried between rounds


def qsgd_init(example_tree) -> QuantState:
    flat, _ = ops.flatten_pytree(example_tree)
    return QuantState(error=jnp.zeros_like(flat))


def qsgd_compress(tree, state: Optional[QuantState] = None, *,
                  block: int = 256, interpret=None):
    """-> (packed dict, new_state, unflatten). Wire payload = packed."""
    flat, unflatten = ops.flatten_pytree(tree)
    (packed,), (new_state,) = qsgd_compress_flat_batch(
        [flat], [state], block=block, interpret=interpret)
    return packed, new_state, unflatten


def qsgd_compress_flat_batch(flats, states, *, block: int = 256,
                             interpret=None):
    """Batched core: [flat_i], [state_i|None] -> ([packed_i],
    [new_state_i]). One fused quantize dispatch for the whole batch (and
    one fused dequantize for the error-feedback residuals), bit-identical
    per item to ``qsgd_compress`` run message by message."""
    fed = [f if s is None else f + s.error for f, s in zip(flats, states)]
    packed = ops.quantize_flat_batch(fed, block=block, interpret=interpret)
    ef_idx = [i for i, s in enumerate(states) if s is not None]
    new_states = [None] * len(flats)
    if ef_idx:
        recons = ops.dequantize_flat_batch([packed[i] for i in ef_idx],
                                           interpret=interpret)
        for i, recon in zip(ef_idx, recons):
            new_states[i] = QuantState(error=fed[i] - recon)
    return packed, new_states


def qsgd_decompress(packed, unflatten, *, interpret=None):
    return unflatten(ops.dequantize_flat(packed, interpret=interpret))


def packed_nbytes(packed) -> int:
    """Wire size of a packed payload (int8 + f32 scales)."""
    return int(packed["q"].size) + int(packed["scales"].size) * 4
