"""Top-k magnitude sparsification with error feedback (Wangni et al. 2018)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compression.qsgd import QuantState
from repro.kernels import ops


def topk_compress(tree, k_frac: float, state: Optional[QuantState] = None):
    """-> (payload dict {idx, vals, n}, new_state, unflatten)."""
    flat, unflatten = ops.flatten_pytree(tree)
    if state is not None:
        flat = flat + state.error
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    payload = {"idx": idx.astype(jnp.int32), "vals": vals, "n": flat.size}
    if state is not None:
        recon = jnp.zeros_like(flat).at[idx].set(vals)
        state = QuantState(error=flat - recon)
    return payload, state, unflatten


def topk_decompress(payload, unflatten):
    flat = jnp.zeros((payload["n"],), payload["vals"].dtype)
    flat = flat.at[payload["idx"]].set(payload["vals"])
    return unflatten(flat)


def payload_nbytes(payload) -> int:
    return int(payload["idx"].size) * 4 + int(payload["vals"].size) * 4
