"""Top-k magnitude sparsification with error feedback (Wangni et al. 2018).

The selection runs on the Pallas top-k kernel path (kernels/ops
``topk_flat_batch``): messages sharing a (length, k) land in one fused
kernel dispatch, and the sparse wire form — |value|-descending, ties to
the lower index — is bit-identical to the historical per-message
``jax.lax.top_k(|flat|)`` + gather, which remains the jitted reference
the dispatch rule falls back to on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.compression.qsgd import QuantState
from repro.kernels import ops


def topk_compress(tree, k_frac: float, state: Optional[QuantState] = None,
                  *, interpret=None):
    """-> (payload dict {idx, vals, n}, new_state, unflatten)."""
    flat, unflatten = ops.flatten_pytree(tree)
    (payload,), (new_state,) = topk_compress_flat_batch(
        [flat], [state], k_frac=k_frac, interpret=interpret)
    return payload, new_state, unflatten


def topk_compress_flat_batch(flats, states, *, k_frac: float,
                             interpret=None):
    """Batched core: [flat_i], [state_i|None] -> ([payload_i],
    [new_state_i]). Same-shape messages share one fused top-k dispatch;
    per-item payloads and error-feedback transitions are bit-identical
    to ``topk_compress`` run message by message."""
    fed = [f if s is None else f + s.error for f, s in zip(flats, states)]
    payloads = ops.topk_flat_batch(fed, k_frac=k_frac, interpret=interpret)
    new_states = [None] * len(flats)
    for i, s in enumerate(states):
        if s is None:
            continue
        recon = np.zeros(int(payloads[i]["n"]), np.float32)
        recon[np.asarray(payloads[i]["idx"])] = np.asarray(
            payloads[i]["vals"])
        new_states[i] = QuantState(error=jnp.asarray(fed[i]) - recon)
    return payloads, new_states


def topk_decompress(payload, unflatten):
    flat = jnp.zeros((int(payload["n"]),), jnp.float32)
    flat = flat.at[jnp.asarray(payload["idx"])].set(
        jnp.asarray(payload["vals"]))
    return unflatten(flat)


def payload_nbytes(payload) -> int:
    return int(payload["idx"].size) * 4 + int(payload["vals"].size) * 4
