"""Builders for the jit-lowered step functions (train / prefill / decode /
cross-pod FL round) with full sharding annotations.

These are used identically by the real trainer (``launch/train.py``), the
multi-pod dry-run (``launch/dryrun.py``) and the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.models import build_model
from repro.models.layers import abstract_init
from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    opt_state_axes)
from repro.optim.schedules import cosine_warmup
from repro.sharding.rules import MeshPlan, Sharder


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one cell."""
    fn: object  # jit-able callable
    in_specs: tuple  # ShapeDtypeStructs pytree(s) for .lower()
    in_shardings: tuple
    out_shardings: object
    model: object
    plan: MeshPlan
    abstract_state: object  # params/opt/cache shape pytrees (for reports)


def _shardings(mesh, plan: MeshPlan, axes_tree, shapes_tree):
    return plan.tree_shardings(mesh, axes_tree, shapes_tree)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    mesh_cfg: MeshConfig, train_cfg: TrainConfig,
                    *, fl_pods: bool = False):
    """Synchronous data/tensor-parallel train step (one optimizer update).

    ``fl_pods=False``: batch sharded over (pod, data); params FSDP over
    fsdp_axes, TP over model — the standard fully-synchronous baseline.
    """
    plan = MeshPlan(mesh_cfg)
    sharder = Sharder(plan, mesh)
    model = build_model(cfg, sharder)
    p_shapes, p_axes = abstract_init(model.init)
    opt_shapes = jax.eval_shape(lambda p: adamw_init(p, train_cfg), p_shapes)
    o_axes = opt_state_axes(p_axes, train_cfg)

    in_specs, in_axes = model.input_specs(shape)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        if train_cfg.microbatches > 1:
            n = train_cfg.microbatches

            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mb_batch)
            grads = jax.tree.map(lambda g: (g / n).astype(jnp.bfloat16), gsum)
            loss = lsum / n
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr = cosine_warmup(step, base_lr=train_cfg.learning_rate,
                           warmup_steps=train_cfg.warmup_steps,
                           total_steps=train_cfg.total_steps)
        new_params, new_opt, gnorm = adamw_update(grads, opt_state, params,
                                                  lr, train_cfg)
        return new_params, new_opt, {"loss": loss, "gnorm": gnorm, "lr": lr}

    p_shard = _shardings(mesh, plan, p_axes, p_shapes)
    o_shard = _shardings(mesh, plan, o_axes, opt_shapes)
    b_shard = _shardings(mesh, plan, in_axes, in_specs)
    step_shard = NamedSharding(mesh, P())
    in_shardings = (p_shard, o_shard, b_shard, step_shard)
    out_shardings = (p_shard, o_shard,
                     {"loss": step_shard, "gnorm": step_shard,
                      "lr": step_shard})
    lower_args = (p_shapes, opt_shapes, in_specs,
                  jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(train_step, lower_args, in_shardings, out_shardings,
                      model, plan, {"params": p_shapes, "opt": opt_shapes})


# ---------------------------------------------------------------------------
# serve steps (prefill forward / single-token decode)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      mesh_cfg: MeshConfig):
    plan = MeshPlan(mesh_cfg)
    sharder = Sharder(plan, mesh)
    model = build_model(cfg, sharder)
    p_shapes, p_axes = abstract_init(model.init)
    in_specs, in_axes = model.input_specs(shape)

    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        # serving returns only the last-position logits
        return logits[:, -1]

    p_shard = _shardings(mesh, plan, p_axes, p_shapes)
    b_shard = _shardings(mesh, plan, in_axes, in_specs)
    out_sh = NamedSharding(mesh, plan.spec(
        ("batch", "vocab"), (shape.global_batch, cfg.vocab_size)))
    return StepBundle(prefill_step, (p_shapes, in_specs),
                      (p_shard, b_shard), out_sh, model, plan,
                      {"params": p_shapes})


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     mesh_cfg: MeshConfig):
    """One new token against a seq_len KV cache (decode_* cells)."""
    plan = MeshPlan(mesh_cfg)
    sharder = Sharder(plan, mesh)
    model = build_model(cfg, sharder)
    p_shapes, p_axes = abstract_init(model.init)
    in_specs, in_axes = model.input_specs(shape)
    cache_spec, cache_axes = model.cache_spec(shape.global_batch,
                                              shape.seq_len)

    def decode_step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch)
        return logits, new_cache

    p_shard = _shardings(mesh, plan, p_axes, p_shapes)
    c_shard = _shardings(mesh, plan, cache_axes, cache_spec)
    b_shard = _shardings(mesh, plan, in_axes, in_specs)
    logit_sh = NamedSharding(mesh, plan.spec(
        ("batch", None, "vocab"), (shape.global_batch, 1, cfg.vocab_size)))
    return StepBundle(decode_step, (p_shapes, cache_spec, in_specs),
                      (p_shard, c_shard, b_shard), (logit_sh, c_shard),
                      model, plan, {"params": p_shapes, "cache": cache_spec})


# ---------------------------------------------------------------------------
# cross-pod FL round (the paper's technique at pod scale)
# ---------------------------------------------------------------------------

def make_fl_round_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       mesh_cfg: MeshConfig, train_cfg: TrainConfig,
                       *, local_steps: int = 4):
    """DiLoCo-style: each pod trains ``local_steps`` on its own batch
    (params stacked over the pod axis -> vmap = per-pod divergence), then
    pods exchange int8-quantised deltas (cross-pod all-reduce carries
    1-byte traffic + per-block scales instead of f32). Requires the
    multi-pod mesh."""
    assert "pod" in mesh_cfg.axis_names, "fl round needs the pod axis"
    n_pods = mesh_cfg.axis_size("pod")
    # per-pod plan: batch maps to 'data' only (pod handled by stacking)
    pod_mesh_cfg = dataclasses.replace(mesh_cfg, batch_axes=("data",))
    plan = MeshPlan(pod_mesh_cfg)
    sharder = Sharder(plan, mesh)
    model = build_model(cfg, sharder)
    p_shapes, p_axes = abstract_init(model.init)
    opt_shapes = jax.eval_shape(lambda p: adamw_init(p, train_cfg), p_shapes)
    o_axes = opt_state_axes(p_axes, train_cfg)
    in_specs, in_axes = model.input_specs(shape)

    # stack over pods: leading 'pod' logical axis
    stack = lambda tree: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_pods,) + s.shape, s.dtype), tree)
    stack_axes = lambda tree: jax.tree.map(
        lambda a: ("pod_stack",) + tuple(a or ()), tree,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)))
    plan_stacked = MeshPlan(pod_mesh_cfg,
                            extra_rules=(("pod_stack", ("pod",)),))

    ps_shapes, ps_axes = stack(p_shapes), stack_axes(p_axes)
    os_shapes, os_axes = stack(opt_shapes), stack_axes(o_axes)
    # per-pod batch: local batch = global/n_pods, stacked over pods
    bs_specs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            (n_pods, local_steps, s.shape[0] // n_pods) + s.shape[1:],
            s.dtype), in_specs)
    bs_axes = jax.tree.map(
        lambda a: ("pod_stack", None) + tuple(a or ()), in_axes,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)))

    def local_steps_fn(params, opt_state, batches, step):
        def one(carry, mb):
            p, o = carry
            (loss, _), g = jax.value_and_grad(
                lambda pp: model.loss(pp, mb), has_aux=True)(p)
            lr = cosine_warmup(step, base_lr=train_cfg.learning_rate,
                               warmup_steps=train_cfg.warmup_steps,
                               total_steps=train_cfg.total_steps)
            p, o, _ = adamw_update(g, o, p, lr, train_cfg)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(one, (params, opt_state),
                                                   batches)
        return params, opt_state, losses.mean()

    def fl_round(params_stacked, opt_stacked, anchor, batches, step):
        new_p, new_o, loss = jax.vmap(local_steps_fn,
                                      in_axes=(0, 0, 0, None))(
            params_stacked, opt_stacked, batches, step)
        # cross-pod delta exchange. 'int8': deltas quantised with a shared
        # scale; the exchange is forced to carry 1-byte payloads by
        # replicating the int8 tensor across the pod axis (all-gather of
        # int8) and reducing locally in int32 — summing before the
        # collective would silently promote the wire traffic to 4-byte ints.
        def sync(anchor_leaf, stacked_leaf, axes_leaf):
            delta = (stacked_leaf.astype(jnp.float32)
                     - anchor_leaf.astype(jnp.float32)[None])
            if train_cfg.crosspod_compression == "int8":
                scale = jnp.max(jnp.abs(delta)) / 127.0 + 1e-12
                q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(
                    jnp.int8)
                base = plan.spec(tuple(axes_leaf or ()),
                                 tuple(anchor_leaf.shape))
                repl = P(*((None,) + tuple(base)))
                # the barrier pins q as a materialised pod-sharded int8
                # tensor BEFORE the resharding constraint; without it the
                # partitioner all-gathers the f32 delta and requantises per
                # pod (4x the DCN payload, observed in the lowered HLO)
                q = jax.lax.optimization_barrier(q)
                q = jax.lax.with_sharding_constraint(
                    q, NamedSharding(mesh, repl))  # int8 all-gather over pod
                mean = (jnp.sum(q.astype(jnp.int32), axis=0).astype(
                    jnp.float32) * scale / n_pods)
            else:
                mean = jnp.mean(delta, axis=0)
            new_anchor = anchor_leaf.astype(jnp.float32) + mean
            return new_anchor.astype(anchor_leaf.dtype)

        a_leaves, treedef = jax.tree.flatten(anchor)
        s_leaves = treedef.flatten_up_to(new_p)
        ax_leaves = jax.tree.leaves(
            p_axes, is_leaf=lambda x: x is None or (
                isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x)))
        new_anchor = jax.tree.unflatten(
            treedef, [sync(a, s, ax) for a, s, ax
                      in zip(a_leaves, s_leaves, ax_leaves)])
        # reset every pod to the new anchor
        reset = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape),
            new_anchor)
        return reset, new_o, new_anchor, loss.mean()

    ps_shard = plan_stacked.tree_shardings(mesh, ps_axes, ps_shapes)
    os_shard = plan_stacked.tree_shardings(mesh, os_axes, os_shapes)
    a_shard = plan_stacked.tree_shardings(mesh, p_axes, p_shapes)
    b_shard = plan_stacked.tree_shardings(mesh, bs_axes, bs_specs)
    step_sh = NamedSharding(mesh, P())
    in_shardings = (ps_shard, os_shard, a_shard, b_shard, step_sh)
    out_shardings = (ps_shard, os_shard, a_shard, step_sh)
    lower_args = (ps_shapes, os_shapes, p_shapes, bs_specs,
                  jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(fl_round, lower_args, in_shardings, out_shardings,
                      model, plan_stacked,
                      {"params": ps_shapes, "opt": os_shapes})


def bundle_for(kind: str, cfg: ModelConfig, shape: ShapeConfig, mesh,
               mesh_cfg: MeshConfig, train_cfg: Optional[TrainConfig] = None,
               **kw):
    train_cfg = train_cfg or TrainConfig()
    if kind == "train":
        return make_train_step(cfg, shape, mesh, mesh_cfg, train_cfg, **kw)
    if kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, mesh_cfg)
    if kind == "decode":
        return make_decode_step(cfg, shape, mesh, mesh_cfg)
    if kind == "fl_round":
        return make_fl_round_step(cfg, shape, mesh, mesh_cfg, train_cfg, **kw)
    raise ValueError(kind)
