"""Serving driver: batched prefill + decode with a KV/SSM cache.

CPU-runnable on reduced configs; the decode step is the same function the
dry-run lowers for the decode_32k / long_500k cells.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b \
        --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_ORDER, smoke_config
from repro.launch.mesh import make_smoke_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_ORDER)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    if not cfg.causal:
        print(f"[serve] {args.arch} is encoder-only; no decode loop")
        return 0
    from repro.models import build_model
    model = build_model(cfg)
    rng = jax.random.key(0)
    params, _ = model.init(rng)

    b = args.requests
    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(rng, (b, args.prompt_len), 0,
                                 cfg.vocab_size)

    decode = jax.jit(model.decode_step)
    cache = model.init_cache(b, max_seq)

    # prefill via decode steps for recurrent caches (uniform across families)
    t0 = time.time()
    tok = prompts[:, :1]
    logits = None
    for pos in range(args.prompt_len):
        batch = {"tokens": prompts[:, pos:pos + 1], "pos": jnp.int32(pos)}
        logits, cache = decode(params, cache, batch)
    prefill_s = time.time() - t0

    t0 = time.time()
    out_tokens = []
    tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits, axis=-1,
                     keepdims=True).astype(jnp.int32)
    for i in range(args.gen):
        batch = {"tokens": tok, "pos": jnp.int32(args.prompt_len + i)}
        logits, cache = decode(params, cache, batch)
        lg = logits[:, -1] if logits.ndim == 3 else logits
        tok = jnp.argmax(lg, axis=-1, keepdims=True).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    decode_s = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    assert gen.shape == (b, args.gen) and np.all(gen >= 0)
    print(f"[serve] {b} reqs: prefill({args.prompt_len} tok) {prefill_s:.2f}s, "
          f"decode {args.gen} tok in {decode_s:.2f}s "
          f"({b * args.gen / max(decode_s, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generation: {gen[0][:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
