"""Mesh construction. Functions, not constants: importing this module never
touches jax device state."""
from __future__ import annotations

import jax

from repro.configs.base import (MULTI_POD_MESH, SINGLE_POD_MESH, SMOKE_MESH,
                                MeshConfig)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: 16x16 (one v5e pod, 256 chips) or
    2x16x16 (two pods, 512 chips, 'pod' axis over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_smoke_mesh():
    """1x1 mesh over the single local device (smoke tests / examples)."""
    return jax.make_mesh(SMOKE_MESH.shape, SMOKE_MESH.axis_names)


def mesh_config_for(mesh) -> MeshConfig:
    names = tuple(mesh.axis_names)
    if names == ("pod", "data", "model"):
        return MULTI_POD_MESH
    if names == ("data", "model"):
        if tuple(mesh.devices.shape) == (16, 16):
            return SINGLE_POD_MESH
        return MeshConfig(shape=tuple(mesh.devices.shape), axis_names=names)
    return MeshConfig(shape=tuple(mesh.devices.shape), axis_names=names)
