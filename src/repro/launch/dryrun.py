import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the production dry-run needs 512 placeholder
# devices to build the 2x16x16 multi-pod mesh. (Tests/benches see 1 device.)

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell against the production meshes and record memory / cost / roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all                   # single-pod 16x16
    python -m repro.launch.dryrun --all --multi-pod       # 2x16x16
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --fl \
        --multi-pod                                       # cross-pod FL round

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>[__fl].json
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_ORDER, get_config
from repro.configs.base import (MULTI_POD_MESH, SINGLE_POD_MESH, TrainConfig)
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicability
from repro.launch.mesh import make_production_mesh
from repro.launch.step_builders import bundle_for
from repro.roofline.analysis import analyze

# per-arch training knobs that make the big models fit 16 GB v5e HBM
TRAIN_OVERRIDES = {
    "deepseek-67b": dict(microbatches=16),
    "llama4-maverick-400b-a17b": dict(microbatches=16,
                                      moment_dtype="bfloat16"),
    "stablelm-12b": dict(microbatches=8),
    "qwen3-8b": dict(microbatches=8),
    "granite-3-8b": dict(microbatches=8),
    "llama-3.2-vision-11b": dict(microbatches=8),
    "hubert-xlarge": dict(microbatches=4),
    "granite-moe-1b-a400m": dict(microbatches=4),
    "xlstm-1.3b": dict(microbatches=4),
    "zamba2-1.2b": dict(microbatches=4),
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, fl: bool = False,
             out_dir: str = "artifacts/dryrun", mesh=None, overrides=None,
             fl_compress: str = "", tag_suffix: str = "",
             mesh_cfg=None, mesh_label: str = "", train_kw=None,
             fl_local_steps: int = 2, verbose: bool = True):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = applicability(cfg, shape)
    mesh_name = mesh_label or ("pod2x16x16" if multi_pod else "pod16x16")
    tag = f"{arch}__{shape_name}" + ("__fl" if fl else "") + tag_suffix
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "fl": fl,
              "fl_compress": fl_compress}
    if not ok:
        record.update(status="skipped", reason=reason)
        _persist(out_dir, mesh_name, tag, record, verbose)
        return record

    if mesh_cfg is None:
        mesh_cfg = MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
    if mesh is None:
        if tuple(mesh_cfg.shape) in ((16, 16), (2, 16, 16)):
            mesh = make_production_mesh(multi_pod=multi_pod)
        else:
            mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
    tkw = dict(TRAIN_OVERRIDES.get(arch, {}))
    if train_kw:
        tkw.update(train_kw)
    if fl and fl_compress:
        tkw["crosspod_compression"] = fl_compress
    train_cfg = TrainConfig(**tkw)
    kind = "fl_round" if fl else (
        "train" if shape.kind == "train" else shape.kind)
    t0 = time.time()
    try:
        kw = {"local_steps": fl_local_steps} if fl else {}
        bundle = bundle_for(kind, cfg, shape, mesh, mesh_cfg, train_cfg, **kw)
        with mesh:
            lowered = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                              out_shardings=bundle.out_shardings
                              ).lower(*bundle.in_specs)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        pod_size = 256 if multi_pod else 0
        rl = analyze(compiled, arch=arch, shape=shape, kind=kind,
                     mesh_name=mesh_name, chips=mesh.devices.size,
                     pod_size=pod_size, cfg=cfg)
        if fl:
            # an FL round performs local_steps optimizer steps per call
            rl.model_flops *= fl_local_steps
        record.update(
            status="ok", kind=kind,
            compile_s=round(time.time() - t0, 1),
            memory_analysis={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            },
            xla_cost_analysis={k: float(v) for k, v in ca.items()
                               if k in ("flops", "bytes accessed",
                                        "transcendentals")},
            roofline=rl.to_dict(),
            train_overrides=tkw,
        )
        if verbose:
            print(f"[dryrun] {tag} @{mesh_name}: OK ({record['compile_s']}s)")
            print(f"  memory/device: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
            print(f"  roofline: compute={rl.t_compute*1e3:.2f}ms "
                  f"memory={rl.t_memory*1e3:.2f}ms "
                  f"collective={rl.t_collective*1e3:.2f}ms "
                  f"dcn={rl.t_dcn*1e3:.2f}ms -> {rl.dominant}-bound; "
                  f"useful-flops={rl.useful_flops_ratio:.2%} "
                  f"roofline-frac={rl.roofline_fraction:.2%}")
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:],
                      compile_s=round(time.time() - t0, 1))
        if verbose:
            print(f"[dryrun] {tag} @{mesh_name}: FAILED {record['error']}")
    _persist(out_dir, mesh_name, tag, record, verbose)
    return record


def _persist(out_dir, mesh_name, tag, record, verbose):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{tag}.json"), "w") as f:
        json.dump(record, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_ORDER)
    ap.add_argument("--shape", choices=SHAPE_ORDER)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fl", action="store_true",
                    help="lower the cross-pod FL round instead of train_step")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    cells = []
    if args.all:
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        tag = f"{arch}__{shape}" + ("__fl" if args.fl else "")
        path = os.path.join(args.out, mesh_name, f"{tag}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") in ("ok", "skipped"):
                print(f"[dryrun] {tag}: cached ({rec['status']})")
                results.append(rec)
                continue
        results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                fl=args.fl, out_dir=args.out, mesh=mesh))
        jax.clear_caches()
        import gc
        gc.collect()
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
