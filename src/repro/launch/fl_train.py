"""Cross-silo FL driver — the paper's end-to-end system, live.

Server + N silo clients training a real model (default: the paper's Small
tier, ResNet56) over a chosen backend and network environment; payloads
really move through the backend; time is simulated-clock seconds.

    PYTHONPATH=src python -m repro.launch.fl_train --backend grpc+s3 \
        --environment geo_distributed --rounds 3 --tier small
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.paper_tiers import TIERS, build_tier_model
from repro.core import (Fabric, FLMessage, ObjectStore, TensorPayload,
                        make_backend, make_env)
from repro.core.backends import BACKEND_NAMES
from repro.core.netsim import NCAL
from repro.data import make_silo_datasets
from repro.fl import FLClient, FLServer
from repro.fl.fault import FaultPlan, apply_stragglers


def build_deployment(fl_cfg: FLConfig, *, tier: str = "small",
                     reduced: bool = True, local_steps: int = 4,
                     fail_rate: float = 0.0):
    env = make_env(fl_cfg.environment, fl_cfg.num_clients)
    fabric = Fabric(env)
    store = ObjectStore(NCAL, fail_rate=fail_rate)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)

    if reduced:
        # reduced same-family model so CPU rounds take seconds
        from repro.models.vision import ResNet, ResNetConfig
        model = ResNet(ResNetConfig(blocks_per_stage=2, num_classes=8,
                                    image_size=16))
    else:
        model, _ = build_tier_model(tier)
    rng = jax.random.key(fl_cfg.seed)
    params = model.init(rng)

    silos = make_silo_datasets(fl_cfg.num_clients, kind="image",
                               examples_per_silo=64, num_classes=8,
                               image_size=16, seed=fl_cfg.seed)

    def make_train_fn():
        @jax.jit
        def train_fn(params, batch):
            def loss_fn(p):
                loss, _ = model.loss(p, batch)
                return loss
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
            return params2, loss
        return train_fn

    clients = []
    for i, host in enumerate(env.clients):
        cb = make_backend(fl_cfg.backend, env, fabric, host.host_id,
                          store=store)
        clients.append(FLClient(host.host_id, cb, dataset=silos[i],
                                train_fn=make_train_fn(), batch_size=16,
                                seed=fl_cfg.seed + i))
    server_backend = make_backend(fl_cfg.backend, env, fabric, "server",
                                  store=store)
    server = FLServer(server_backend, clients,
                      quorum_fraction=fl_cfg.quorum_fraction,
                      round_deadline_s=fl_cfg.round_deadline_s,
                      local_steps=local_steps)
    return server, params, env, store


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="grpc+s3", choices=BACKEND_NAMES)
    ap.add_argument("--environment", default="geo_distributed",
                    choices=["lan", "geo_proximal", "geo_distributed"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=7)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--quorum", type=float, default=1.0)
    ap.add_argument("--drop-rate", type=float, default=0.0)
    ap.add_argument("--tier", default="small")
    args = ap.parse_args(argv)

    if args.backend == "grpc+s3" and args.environment == "lan":
        print("[fl] note: paper omits grpc+s3 on LAN; switching to auto")
        args.backend = "auto"

    fl_cfg = FLConfig(num_clients=args.clients, backend=args.backend,
                      environment=args.environment, rounds=args.rounds,
                      quorum_fraction=args.quorum)
    server, params, env, store = build_deployment(
        fl_cfg, local_steps=args.local_steps)
    fault = FaultPlan(drop_rate=args.drop_rate, seed=1)

    losses = []
    for r in range(args.rounds):
        dropped, stragglers = fault.for_round(r, [c.client_id for c in
                                                  server.clients])
        apply_stragglers(server.clients, stragglers, fault.straggler_factor)
        report = server.run_round(TensorPayload(params), dropped=dropped)
        if server.global_params is not None:
            params = server.global_params
        losses.append(report.losses)
        print(f"[fl] round {r}: t={report.round_time:8.2f}s sim "
              f"loss={report.losses if report.losses else float('nan'):.4f} "
              f"participants={report.n_participants} "
              f"server_mem={report.peak_server_memory / 2**20:.1f}MB "
              f"{'ABORTED(mpi)' if report.aborted else ''}")
        srv = report.server
        cl = report.clients
        print(f"     server: comm={srv['communication']:.2f} wait={srv['waiting']:.2f} "
              f"agg={srv['aggregation']:.3f} | client: comm={cl['communication']:.2f} "
              f"train={cl['training']:.2f} ser={cl['serialization']:.2f} "
              f"wait={cl['waiting']:.2f}")
    ok = losses[-1] is not None and losses[0] is not None and \
        losses[-1] < losses[0] + 1e-6
    print(f"[fl] losses: {['%.3f' % l if l else 'n/a' for l in losses]} "
          f"({'improving' if ok else 'check'})  s3_stats={store.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
