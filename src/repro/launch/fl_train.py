"""Cross-silo FL driver — the paper's end-to-end system, live.

Server + N silo clients training a real model (default: the paper's Small
tier, ResNet56) over a chosen backend and topology; payloads really move
through the backend; time is simulated-clock seconds.

The whole experiment is one declarative ``Scenario`` (repro/scenario/):
load a spec file and run it, with any individual flag acting as an
override on the resolved spec —

    PYTHONPATH=src python -m repro.launch.fl_train \
        --scenario examples/scenarios/geo_wan_qsgd.json --rounds 5

or describe everything by flags (the classic CLI; flags are simply
overrides layered onto the default scenario):

    PYTHONPATH=src python -m repro.launch.fl_train --backend grpc+s3 \
        --environment geo_distributed --rounds 3 --tier small

``--environment`` accepts the graph presets (star / ring / multi_hub) as
well as the legacy trio. ``--mode fedbuff|semisync|hier`` switches to the
event-driven runtime (fl/scheduler.py): clients run independently and
``--rounds`` counts server aggregations instead of lockstep rounds.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.paper_tiers import TIERS, build_tier_model
from repro.core import FLMessage, TensorPayload
from repro.core.backends import BACKEND_NAMES
from repro.data import make_silo_datasets
from repro.fl import FLClient, FLServer, make_strategy
from repro.fl.fault import FaultPlan, apply_stragglers, make_availability
from repro.scenario import (TOPOLOGY_PRESETS, Scenario, ScenarioError,
                            build_runtime, with_overrides)


def build_deployment(fl_cfg: FLConfig, *, tier: str = "small",
                     reduced: bool = True, local_steps: int = 4,
                     fail_rate: float = 0.0, scenario: Scenario = None):
    """FLConfig/Scenario -> live deployment, through the scenario runtime
    (the same path ``--scenario`` files take).

    Passing *both* ``fl_cfg`` and ``scenario`` is only legal when they
    agree: the scenario's flat projection (``Scenario.fl_config()``)
    must equal ``fl_cfg`` field-for-field, otherwise we raise instead of
    silently preferring one — a disagreement means the caller built the
    two specs independently and one of them is wrong."""
    if scenario is not None:
        back = scenario.fl_config()
        if back != fl_cfg:
            diffs = [f"{f.name}: fl_cfg={getattr(fl_cfg, f.name)!r} "
                     f"scenario={getattr(back, f.name)!r}"
                     for f in dataclasses.fields(FLConfig)
                     if getattr(back, f.name) != getattr(fl_cfg, f.name)]
            raise ValueError(
                "build_deployment got both fl_cfg and scenario but they "
                "disagree (scenario.fl_config() != fl_cfg): "
                + "; ".join(diffs))
        sc = scenario
    else:
        sc = fl_cfg.to_scenario(tier=tier, local_steps=local_steps,
                                store_fail_rate=fail_rate)
    rt = build_runtime(sc)
    env, store = rt.env, rt.store

    if reduced:
        # reduced same-family model so CPU rounds take seconds
        from repro.models.vision import ResNet, ResNetConfig
        model = ResNet(ResNetConfig(blocks_per_stage=2, num_classes=8,
                                    image_size=16))
    else:
        model, _ = build_tier_model(tier)
    rng = jax.random.key(fl_cfg.seed)
    params = model.init(rng)

    silos = make_silo_datasets(fl_cfg.num_clients, kind="image",
                               examples_per_silo=64, num_classes=8,
                               image_size=16, seed=fl_cfg.seed)

    def make_train_fn():
        @jax.jit
        def train_fn(params, batch):
            def loss_fn(p):
                loss, _ = model.loss(p, batch)
                return loss
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
            return params2, loss
        return train_fn

    # event-driven modes charge the tier-calibrated training time instead
    # of measured wall seconds ("live compute, simulated clock"): jit
    # compile jitter must not reorder event arrivals between runs
    sim_train = (0.0 if fl_cfg.mode == "sync"
                 else TIERS[tier].train_s(fl_cfg.environment))
    # the payload codec rides the clients' *update* path (fedbuff /
    # semisync; hier compresses the relay WAN hop inside the strategy
    # instead, and sync rounds aggregate the exact in-proc trees so
    # compression there would charge time it doesn't pay for). The wire
    # codec and chunked pipelining are lossless and ride every backend,
    # incl. the server's broadcast — Runtime.make_backend applies them.
    if fl_cfg.mode == "vertical":
        # vertical traffic is compressed on BOTH directions: activations
        # up on the clients' channels, gradients down on the server's
        client_compression = server_compression = fl_cfg.activation_codec
    else:
        client_compression = (fl_cfg.compression
                              if fl_cfg.mode in ("fedbuff", "semisync")
                              else "none")
        server_compression = "none"
    clients = []
    for i, host in enumerate(env.clients):
        cb = rt.make_backend(host.host_id, compression=client_compression)
        clients.append(FLClient(host.host_id, cb, dataset=silos[i],
                                train_fn=make_train_fn(), batch_size=16,
                                sim_train_s=sim_train,
                                seed=fl_cfg.seed + i))
    server_backend = rt.make_backend("server",
                                     compression=server_compression)
    server = FLServer(server_backend, clients,
                      quorum_fraction=fl_cfg.quorum_fraction,
                      round_deadline_s=fl_cfg.round_deadline_s,
                      local_steps=local_steps)
    server.model = model  # the deployed zoo model (vertical mode splits it)
    return server, params, env, store


def _vertical_strategy(fl_cfg: FLConfig, server: FLServer, params,
                       scenario: Scenario):
    """Live VerticalStrategy over the deployment's model: the split
    parties run real SGD and real activation/gradient tensors ride the
    backends' wire stacks (codec + EF per direction, chunking, faults)."""
    from repro.fl.vertical import (SIM_BATCH_SIZE, SplitPlan, VerticalLive,
                                   VerticalStrategy, bottom_fraction,
                                   sim_activation_nbytes)
    plan = SplitPlan(server.model, fl_cfg.cut_layer)
    bottom, top = plan.split_params(params)
    # each feature party starts from the same bottom (they hold disjoint
    # example sets, not disjoint features, in this single-dataset driver)
    bottoms = {c.client_id: bottom for c in server.clients}
    by_id = {c.client_id: c for c in server.clients}

    def batch_fn(cid, round_, batch):
        c = by_id[cid]
        it = c.dataset.batches(c.batch_size,
                               seed=c.seed + 131 * round_ + batch)
        return {k: jnp.asarray(v) for k, v in next(it).items()}

    tier = TIERS[scenario.fleet.tier]
    return VerticalStrategy(
        cut_layer=fl_cfg.cut_layer,
        batches_per_round=fl_cfg.batches_per_round,
        activation_nbytes=sim_activation_nbytes(
            tier.payload_bytes, SIM_BATCH_SIZE, fl_cfg.cut_layer),
        train_s=tier.train_s(fl_cfg.environment),
        bottom_frac=bottom_fraction(fl_cfg.cut_layer, plan.n_units),
        live=VerticalLive(plan=plan, bottoms=bottoms, top=top,
                          batch_fn=batch_fn))


def run_event_driven(fl_cfg: FLConfig, server: FLServer, params, store,
                     scenario: Scenario) -> int:
    """Async / semi-sync / hierarchical / vertical execution over the
    same deployment."""
    if fl_cfg.mode == "vertical":
        strategy = _vertical_strategy(fl_cfg, server, params, scenario)
        # vertical rounds update the split parties in place — the
        # scheduler's "global payload" is activation-sized bookkeeping,
        # never a model broadcast
        from repro.core.message import VirtualPayload
        global_payload = VirtualPayload(strategy.activation_nbytes,
                                        tag="vertical-global")
    else:
        strategy = make_strategy(fl_cfg, fl_cfg.num_clients)
        global_payload = TensorPayload(params)
    availability = make_availability(
        fl_cfg.availability_trace,
        [c.client_id for c in server.clients],
        horizon_s=scenario.faults.trace_horizon_s, seed=fl_cfg.seed)
    report, sched = server.run_async(global_payload, strategy,
                                     availability=availability,
                                     cohort_k=fl_cfg.cohort_k,
                                     cohort_seed=fl_cfg.seed,
                                     streaming_hub=fl_cfg.streaming_hub,
                                     max_aggregations=fl_cfg.rounds)
    print(f"[fl:{report.mode}] backend={report.backend} "
          f"sim_time={report.sim_time:.2f}s "
          f"aggregations={report.n_aggregations} "
          f"client_updates={report.n_client_updates} "
          f"(effective {report.effective_updates:.2f}, "
          f"mean staleness {report.mean_staleness:.2f}, "
          f"{report.n_discarded} discarded)")
    if availability is not None or fl_cfg.link_loss_rate > 0:
        fabric = server.backend.fabric
        print(f"[fl:{report.mode}] churn: {report.n_departures} departures, "
              f"{report.n_rejoins} rejoins "
              f"({report.n_late_refetches} S3 late re-fetches); faults: "
              f"{report.n_transfer_failures} failed transfers, "
              f"{fabric.stats['retransmits']:.0f} chunk retransmits")
    for ev in sched.agg_log:
        print(f"    v{ev.version}: t={ev.time:8.2f}s n={ev.n_updates} "
              f"staleness={ev.mean_staleness:.2f} "
              f"loss={ev.loss if ev.loss is not None else float('nan'):.4f}")
    losses = [ev.loss for ev in sched.agg_log if ev.loss is not None]
    ok = len(losses) >= 2 and losses[-1] < losses[0] + 1e-6
    print(f"[fl:{report.mode}] throughput={report.aggregations_per_hour:.1f} "
          f"agg/h, {report.client_updates_per_hour:.1f} updates/h "
          f"({'improving' if ok else 'check'})  s3_stats={store.stats}")
    return 0


def _parser() -> argparse.ArgumentParser:
    """Every flag defaults to None: unset flags leave the loaded scenario
    untouched, set ones override it (tests assert this precedence)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default=None,
                    help="scenario JSON (see examples/scenarios/); other "
                         "flags become overrides on the loaded spec")
    ap.add_argument("--sweep", default=None,
                    help="sweep JSON (base scenario + axes, see "
                         "examples/scenarios/sweep_decision_guide.json): "
                         "run every cell through the generic scenario "
                         "runner instead of one training run (equivalent "
                         "to `python -m repro.sweep FILE`)")
    ap.add_argument("--multi", default=None,
                    help="multi-tenant scenario JSON (see examples/"
                         "scenarios/multitenant_pair.json): co-schedule "
                         "every job on one shared fabric + clock under "
                         "the spec's admission policy")
    ap.add_argument("--blackout-trace", default=None,
                    help="JSONL link-outage replay (one {src,dst,t0,t1,"
                         "symmetric} object per line) appended to the "
                         "scenario's inline faults.blackouts")
    ap.add_argument("--sweep-fresh", action="store_true",
                    help="with --sweep: ignore the run store, re-run "
                         "every cell")
    ap.add_argument("--sweep-out-dir", default=None,
                    help="with --sweep: run-store/report root (default: "
                         "the repo's benchmarks/out when importable, "
                         "else ./benchmarks/out)")
    ap.add_argument("--backend", default=None, choices=BACKEND_NAMES)
    ap.add_argument("--environment", default=None,
                    choices=list(TOPOLOGY_PRESETS),
                    help="topology preset: the legacy trio or the graph "
                         "presets star | ring | multi_hub")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--local-steps", type=int, default=None)
    ap.add_argument("--quorum", type=float, default=None)
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="sync-mode per-round client drop rate (FaultPlan)")
    ap.add_argument("--tier", default=None)
    ap.add_argument("--mode", default=None,
                    choices=["sync", "fedbuff", "semisync", "hier",
                             "vertical"])
    ap.add_argument("--cut-layer", type=int, default=None,
                    help="vertical mode: unit boundary of the bottom/top "
                         "split (valid cuts: 1..n_units-1 of the deployed "
                         "model)")
    ap.add_argument("--batches-per-round", type=int, default=None,
                    help="vertical mode: forward-activation / "
                         "backward-gradient exchanges per party per round")
    ap.add_argument("--activation-codec", default=None,
                    help="vertical mode: codec on the activation/gradient "
                         "wires, both directions (none | qsgd[:block] | "
                         "topk[:frac])")
    ap.add_argument("--buffer-k", type=int, default=None,
                    help="fedbuff merge buffer (0 = num_clients // 2)")
    ap.add_argument("--staleness-exponent", type=float, default=None)
    ap.add_argument("--max-staleness", type=int, default=None)
    ap.add_argument("--staleness-adaptive", action="store_true",
                    default=None,
                    help="FedAsync-style: scale the staleness exponent by "
                         "each update's observed-staleness percentile")
    ap.add_argument("--deadline", type=float, default=None,
                    help="semisync round deadline, simulated seconds")
    ap.add_argument("--compression", default=None,
                    help="wire-stack compression: none | qsgd[:block] | "
                         "topk[:frac] (payload domain: client updates in "
                         "fedbuff/semisync, relay WAN hop in hier) | "
                         "zlib[:level] (byte domain: every backend "
                         "channel, all modes)")
    ap.add_argument("--chunk-mb", type=float, default=None,
                    help="split wires into pipelined chunks of this size "
                         "(0 = whole-wire sends)")
    ap.add_argument("--availability-trace", default=None,
                    help="client churn for event-driven modes: "
                         "'auto:MEAN_UP/MEAN_DOWN' (generated exponential "
                         "up/down periods) or explicit "
                         "'client0:leave@120,join@400;client3:leave@50'")
    ap.add_argument("--trace-horizon", type=float, default=None,
                    help="horizon (sim s) for generated availability traces")
    ap.add_argument("--link-loss", type=float, default=None,
                    help="per-chunk loss probability on every graph edge "
                         "(deterministic LinkFaultModel; receivers NACK, "
                         "senders retransmit with bounded retries)")
    ap.add_argument("--region-quorum", type=float, default=None,
                    help="hier mode: min live fraction for a region to "
                         "participate in a round (below it the region is "
                         "skipped, folded back in on rejoin)")
    ap.add_argument("--cohort-k", type=int, default=None,
                    help="fedbuff/semisync: seeded K-of-N cohort sampled "
                         "per round (0 = whole fleet; K=N is bit-for-bit "
                         "the full-fleet run)")
    ap.add_argument("--streaming-hub", action="store_true", default=None,
                    help="fold updates into one O(model) accumulator at "
                         "the hub instead of buffering O(clients) records")
    ap.add_argument("--relay-depth", type=int, default=None,
                    help="hier mode: relay-tree levels (1 = the "
                         "single-tier relay)")
    return ap


def resolve_scenario(args, ap: argparse.ArgumentParser) -> Scenario:
    """--scenario file (or the default spec) + flag overrides -> one
    validated Scenario. Precedence: explicit flag > loaded spec > default."""
    try:
        base = (Scenario.load(args.scenario) if args.scenario
                else Scenario(name="fl_train"))
        sc = with_overrides(base, {
            "channel.backend": args.backend,
            "channel.compression": args.compression,
            "channel.chunk_mb": args.chunk_mb,
            "topology.kind": args.environment,
            "topology.num_clients": args.clients,
            "fleet.tier": args.tier,
            "fleet.local_steps": args.local_steps,
            "strategy.mode": args.mode,
            "strategy.rounds": args.rounds,
            "strategy.buffer_k": args.buffer_k,
            "strategy.staleness_exponent": args.staleness_exponent,
            "strategy.max_staleness": args.max_staleness,
            "strategy.staleness_adaptive": args.staleness_adaptive,
            "strategy.quorum_fraction": args.quorum,
            "strategy.round_deadline_s": args.deadline,
            "split.cut_layer": args.cut_layer,
            "split.batches_per_round": args.batches_per_round,
            "split.activation_codec": args.activation_codec,
            "faults.link_loss": args.link_loss,
            "faults.availability_trace": args.availability_trace,
            "faults.trace_horizon_s": args.trace_horizon,
            "faults.blackouts_file": args.blackout_trace,
            "strategy.region_quorum": args.region_quorum,
            "fleet.cohort_k": args.cohort_k,
            "strategy.streaming_hub": args.streaming_hub,
            "topology.relay_depth": args.relay_depth,
        })
        # a byte-domain --compression spec is really the wire codec;
        # split_codecs owns the rule (and rejects two different wire
        # codecs instead of silently clobbering the spec's)
        from repro.compression.stages import split_codecs
        payload_codec, wire = split_codecs(sc.channel.compression,
                                           sc.channel.wire_codec)
        if payload_codec is None and wire is not None \
                and sc.channel.compression not in ("", "none"):
            sc = with_overrides(sc, {
                "channel.wire_codec": sc.channel.compression,
                "channel.compression": "none"})
        return sc.validate()
    except (ScenarioError, KeyError, OSError, ValueError) as e:
        ap.error(str(e))


def main(argv=None):
    ap = _parser()
    args = ap.parse_args(argv)
    if args.sweep:
        # a sweep file is a whole grid of scenarios, not one training
        # run: expand + execute through the engine's resumable run store
        from repro.scenario import ScenarioError
        from repro.sweep.__main__ import run_sweep_file
        out_dir = args.sweep_out_dir
        if out_dir is None:
            try:
                # anchor on the repo's benchmarks/out (the shared run
                # store) rather than wherever the user happens to stand
                from benchmarks.common import OUT_DIR as out_dir
            except ImportError:
                out_dir = "benchmarks/out"
        try:
            run_sweep_file(args.sweep, out_dir=out_dir,
                           fresh=args.sweep_fresh)
        except (ScenarioError, OSError, ValueError) as e:
            ap.error(str(e))
        return 0
    if args.multi:
        # N co-scheduled tenant jobs on one fabric: the generic
        # multi-tenant runner, not one training run
        from repro.scenario import MultiScenario
        from repro.sweep.runners import run_multi
        try:
            res = run_multi(MultiScenario.load(args.multi))
        except (ScenarioError, OSError, ValueError) as e:
            ap.error(str(e))
        print(f"[multi] '{res['name']}': policy={res['policy']} "
              f"shared_links={res['shared_links']} "
              f"jobs={len(res['jobs'])} "
              f"total_bytes={res['bytes_on_wire']:.3e}")
        for name, j in res["jobs"].items():
            print(f"    {name}: {j['n_rounds']} aggregations in "
                  f"{j['sim_time_s']:.2f}s sim "
                  f"({j['round_s']:.2f}s/round, "
                  f"{j['n_client_updates']} client updates, "
                  f"{j['bytes_on_wire']:.3e} B on wire)")
        return 0
    sc = resolve_scenario(args, ap)

    if sc.channel.backend == "grpc+s3" and sc.topology.kind == "lan":
        print("[fl] note: paper omits grpc+s3 on LAN; switching to auto")
        sc = with_overrides(sc, {"channel.backend": "auto"})
    if sc.channel.compression != "none" and sc.strategy.mode == "sync":
        print("[fl] note: payload compression rides the event-driven "
              "update path; sync rounds aggregate exact in-proc trees, "
              "ignoring")
        sc = with_overrides(sc, {"channel.compression": "none"})

    fl_cfg = sc.fl_config()
    print(f"[fl] scenario '{sc.name}': topology={sc.topology.kind} "
          f"x{sc.topology.num_clients} backend={sc.channel.backend} "
          f"mode={sc.strategy.mode} tier={sc.fleet.tier}")
    server, params, env, store = build_deployment(
        fl_cfg, tier=sc.fleet.tier, local_steps=sc.fleet.local_steps,
        scenario=sc)
    if sc.strategy.mode != "sync":
        return run_event_driven(fl_cfg, server, params, store, sc)
    fault = FaultPlan(drop_rate=args.drop_rate, seed=1)

    losses = []
    for r in range(fl_cfg.rounds):
        dropped, stragglers = fault.for_round(r, [c.client_id for c in
                                                  server.clients])
        apply_stragglers(server.clients, stragglers, fault.straggler_factor)
        report = server.run_round(TensorPayload(params), dropped=dropped)
        if server.global_params is not None:
            params = server.global_params
        losses.append(report.losses)
        print(f"[fl] round {r}: t={report.round_time:8.2f}s sim "
              f"loss={report.losses if report.losses else float('nan'):.4f} "
              f"participants={report.n_participants} "
              f"server_mem={report.peak_server_memory / 2**20:.1f}MB "
              f"{'ABORTED(mpi)' if report.aborted else ''}")
        srv = report.server
        cl = report.clients
        print(f"     server: comm={srv['communication']:.2f} wait={srv['waiting']:.2f} "
              f"agg={srv['aggregation']:.3f} | client: comm={cl['communication']:.2f} "
              f"train={cl['training']:.2f} ser={cl['serialization']:.2f} "
              f"wait={cl['waiting']:.2f}")
    ok = losses[-1] is not None and losses[0] is not None and \
        losses[-1] < losses[0] + 1e-6
    print(f"[fl] losses: {['%.3f' % l if l else 'n/a' for l in losses]} "
          f"({'improving' if ok else 'check'})  s3_stats={store.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
