"""Cross-silo FL driver — the paper's end-to-end system, live.

Server + N silo clients training a real model (default: the paper's Small
tier, ResNet56) over a chosen backend and network environment; payloads
really move through the backend; time is simulated-clock seconds.

    PYTHONPATH=src python -m repro.launch.fl_train --backend grpc+s3 \
        --environment geo_distributed --rounds 3 --tier small

``--mode fedbuff|semisync|hier`` switches to the event-driven runtime
(fl/scheduler.py): clients run independently and ``--rounds`` counts
server aggregations instead of lockstep rounds.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.paper_tiers import TIERS, build_tier_model
from repro.core import (Fabric, FLMessage, ObjectStore, TensorPayload,
                        make_backend, make_env)
from repro.core.backends import BACKEND_NAMES
from repro.core.netsim import NCAL
from repro.data import make_silo_datasets
from repro.fl import FLClient, FLServer, make_strategy
from repro.fl.fault import FaultPlan, apply_stragglers, make_availability


def build_deployment(fl_cfg: FLConfig, *, tier: str = "small",
                     reduced: bool = True, local_steps: int = 4,
                     fail_rate: float = 0.0):
    env = make_env(fl_cfg.environment, fl_cfg.num_clients)
    fabric = Fabric(env)
    if getattr(fl_cfg, "link_loss_rate", 0.0) > 0:
        from repro.core.netsim import LinkFaultModel
        fabric.fault_model = LinkFaultModel(
            chunk_loss_rate=fl_cfg.link_loss_rate, seed=fl_cfg.seed)
    store = ObjectStore(NCAL, fail_rate=fail_rate)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)

    if reduced:
        # reduced same-family model so CPU rounds take seconds
        from repro.models.vision import ResNet, ResNetConfig
        model = ResNet(ResNetConfig(blocks_per_stage=2, num_classes=8,
                                    image_size=16))
    else:
        model, _ = build_tier_model(tier)
    rng = jax.random.key(fl_cfg.seed)
    params = model.init(rng)

    silos = make_silo_datasets(fl_cfg.num_clients, kind="image",
                               examples_per_silo=64, num_classes=8,
                               image_size=16, seed=fl_cfg.seed)

    def make_train_fn():
        @jax.jit
        def train_fn(params, batch):
            def loss_fn(p):
                loss, _ = model.loss(p, batch)
                return loss
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
            return params2, loss
        return train_fn

    # event-driven modes charge the tier-calibrated training time instead
    # of measured wall seconds ("live compute, simulated clock"): jit
    # compile jitter must not reorder event arrivals between runs
    sim_train = (0.0 if fl_cfg.mode == "sync"
                 else TIERS[tier].train_s(fl_cfg.environment))
    # the wire stack: clients compress their *update* path (fedbuff /
    # semisync; hier compresses the relay WAN hop inside the strategy
    # instead, and sync rounds aggregate the exact in-proc trees so
    # compression there would charge time it doesn't pay for); chunked
    # pipelining applies to every backend incl. the server's broadcast
    client_compression = (fl_cfg.compression
                          if fl_cfg.mode in ("fedbuff", "semisync")
                          else None)
    clients = []
    for i, host in enumerate(env.clients):
        cb = make_backend(fl_cfg.backend, env, fabric, host.host_id,
                          store=store, compression=client_compression,
                          chunk_mb=fl_cfg.chunk_mb)
        clients.append(FLClient(host.host_id, cb, dataset=silos[i],
                                train_fn=make_train_fn(), batch_size=16,
                                sim_train_s=sim_train,
                                seed=fl_cfg.seed + i))
    server_backend = make_backend(fl_cfg.backend, env, fabric, "server",
                                  store=store, chunk_mb=fl_cfg.chunk_mb)
    server = FLServer(server_backend, clients,
                      quorum_fraction=fl_cfg.quorum_fraction,
                      round_deadline_s=fl_cfg.round_deadline_s,
                      local_steps=local_steps)
    return server, params, env, store


def run_event_driven(fl_cfg: FLConfig, server: FLServer, params, store,
                     args) -> int:
    """Async / semi-sync / hierarchical execution over the same deployment."""
    strategy = make_strategy(fl_cfg, fl_cfg.num_clients)
    availability = make_availability(
        fl_cfg.availability_trace,
        [c.client_id for c in server.clients],
        horizon_s=args.trace_horizon, seed=fl_cfg.seed)
    report, sched = server.run_async(TensorPayload(params), strategy,
                                     availability=availability,
                                     max_aggregations=args.rounds)
    print(f"[fl:{report.mode}] backend={report.backend} "
          f"sim_time={report.sim_time:.2f}s "
          f"aggregations={report.n_aggregations} "
          f"client_updates={report.n_client_updates} "
          f"(effective {report.effective_updates:.2f}, "
          f"mean staleness {report.mean_staleness:.2f}, "
          f"{report.n_discarded} discarded)")
    if availability is not None or fl_cfg.link_loss_rate > 0:
        fabric = server.backend.fabric
        print(f"[fl:{report.mode}] churn: {report.n_departures} departures, "
              f"{report.n_rejoins} rejoins "
              f"({report.n_late_refetches} S3 late re-fetches); faults: "
              f"{report.n_transfer_failures} failed transfers, "
              f"{fabric.stats['retransmits']:.0f} chunk retransmits")
    for ev in sched.agg_log:
        print(f"    v{ev.version}: t={ev.time:8.2f}s n={ev.n_updates} "
              f"staleness={ev.mean_staleness:.2f} "
              f"loss={ev.loss if ev.loss is not None else float('nan'):.4f}")
    losses = [ev.loss for ev in sched.agg_log if ev.loss is not None]
    ok = len(losses) >= 2 and losses[-1] < losses[0] + 1e-6
    print(f"[fl:{report.mode}] throughput={report.aggregations_per_hour:.1f} "
          f"agg/h, {report.client_updates_per_hour:.1f} updates/h "
          f"({'improving' if ok else 'check'})  s3_stats={store.stats}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="grpc+s3", choices=BACKEND_NAMES)
    ap.add_argument("--environment", default="geo_distributed",
                    choices=["lan", "geo_proximal", "geo_distributed"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=7)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--quorum", type=float, default=1.0)
    ap.add_argument("--drop-rate", type=float, default=0.0)
    ap.add_argument("--tier", default="small")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "fedbuff", "semisync", "hier"])
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="fedbuff merge buffer (0 = num_clients // 2)")
    ap.add_argument("--staleness-exponent", type=float, default=0.5)
    ap.add_argument("--max-staleness", type=int, default=0)
    ap.add_argument("--staleness-adaptive", action="store_true",
                    help="FedAsync-style: scale the staleness exponent by "
                         "each update's observed-staleness percentile")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="semisync round deadline, simulated seconds")
    ap.add_argument("--compression", default="none",
                    help="wire-stack gradient compression: none | "
                         "qsgd[:block] | topk[:frac] (client updates in "
                         "fedbuff/semisync; relay WAN hop in hier)")
    ap.add_argument("--chunk-mb", type=float, default=0.0,
                    help="split wires into pipelined chunks of this size "
                         "(0 = whole-wire sends)")
    ap.add_argument("--availability-trace", default="",
                    help="client churn for event-driven modes: "
                         "'auto:MEAN_UP/MEAN_DOWN' (generated exponential "
                         "up/down periods) or explicit "
                         "'client0:leave@120,join@400;client3:leave@50'")
    ap.add_argument("--trace-horizon", type=float, default=3600.0,
                    help="horizon (sim s) for generated availability traces")
    ap.add_argument("--link-loss", type=float, default=0.0,
                    help="per-chunk loss probability on every direct link "
                         "(deterministic LinkFaultModel; senders retransmit "
                         "with bounded retries)")
    ap.add_argument("--region-quorum", type=float, default=0.5,
                    help="hier mode: min live fraction for a region to "
                         "participate in a round (below it the region is "
                         "skipped, folded back in on rejoin)")
    args = ap.parse_args(argv)

    if not 0.0 <= args.link_loss < 1.0:
        ap.error("--link-loss must be in [0, 1): a rate of 1 means no "
                 "transmission ever succeeds")
    if args.backend == "grpc+s3" and args.environment == "lan":
        print("[fl] note: paper omits grpc+s3 on LAN; switching to auto")
        args.backend = "auto"
    if args.compression != "none" and args.mode == "sync":
        print("[fl] note: --compression rides the event-driven update "
              "path; sync rounds aggregate exact in-proc trees, ignoring")
        args.compression = "none"

    fl_cfg = FLConfig(num_clients=args.clients, backend=args.backend,
                      environment=args.environment, rounds=args.rounds,
                      quorum_fraction=args.quorum,
                      round_deadline_s=args.deadline, mode=args.mode,
                      buffer_k=args.buffer_k,
                      staleness_exponent=args.staleness_exponent,
                      max_staleness=args.max_staleness,
                      staleness_adaptive=args.staleness_adaptive,
                      compression=args.compression,
                      chunk_mb=args.chunk_mb,
                      availability_trace=args.availability_trace,
                      link_loss_rate=args.link_loss,
                      region_quorum=args.region_quorum)
    server, params, env, store = build_deployment(
        fl_cfg, tier=args.tier, local_steps=args.local_steps)
    if args.mode != "sync":
        return run_event_driven(fl_cfg, server, params, store, args)
    fault = FaultPlan(drop_rate=args.drop_rate, seed=1)

    losses = []
    for r in range(args.rounds):
        dropped, stragglers = fault.for_round(r, [c.client_id for c in
                                                  server.clients])
        apply_stragglers(server.clients, stragglers, fault.straggler_factor)
        report = server.run_round(TensorPayload(params), dropped=dropped)
        if server.global_params is not None:
            params = server.global_params
        losses.append(report.losses)
        print(f"[fl] round {r}: t={report.round_time:8.2f}s sim "
              f"loss={report.losses if report.losses else float('nan'):.4f} "
              f"participants={report.n_participants} "
              f"server_mem={report.peak_server_memory / 2**20:.1f}MB "
              f"{'ABORTED(mpi)' if report.aborted else ''}")
        srv = report.server
        cl = report.clients
        print(f"     server: comm={srv['communication']:.2f} wait={srv['waiting']:.2f} "
              f"agg={srv['aggregation']:.3f} | client: comm={cl['communication']:.2f} "
              f"train={cl['training']:.2f} ser={cl['serialization']:.2f} "
              f"wait={cl['waiting']:.2f}")
    ok = losses[-1] is not None and losses[0] is not None and \
        losses[-1] < losses[0] + 1e-6
    print(f"[fl] losses: {['%.3f' % l if l else 'n/a' for l in losses]} "
          f"({'improving' if ok else 'check'})  s3_stats={store.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
