"""Real training driver (CPU-runnable with reduced configs; the same code
lowers onto the production meshes).

Example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 30 --ckpt-dir /tmp/ckpt
Restart behaviour: if --ckpt-dir has a checkpoint, training resumes from it
(fault-tolerance path: kill the process mid-run and rerun the command).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_ORDER, get_config, smoke_config
from repro.configs.base import SMOKE_MESH, ShapeConfig, TrainConfig
from repro.data import lm_batch_iterator
from repro.launch.mesh import make_smoke_mesh
from repro.launch.step_builders import make_train_step
from repro.models.layers import abstract_init
from repro.optim.optimizers import adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_ORDER)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig(name="cli", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    train_cfg = TrainConfig(learning_rate=args.lr, warmup_steps=5,
                            total_steps=args.steps)
    mesh = make_smoke_mesh()
    bundle = make_train_step(cfg, shape, mesh, SMOKE_MESH, train_cfg)
    model = bundle.model

    rng = jax.random.key(0)
    params, _ = model.init(rng)
    opt_state = adamw_init(params, train_cfg)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        (params, opt_state), start_step, meta = ckpt.restore(
            (params, opt_state))
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings)
    data = lm_batch_iterator(0, args.batch, args.seq, cfg.vocab_size)
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            np_batch = next(data)
            batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
            if cfg.external_embeddings:
                batch = {"embeds": jax.random.normal(
                    jax.random.fold_in(rng, step),
                    (args.batch, args.seq, cfg.d_model), jnp.bfloat16),
                    "targets": batch["targets"]}
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_image_tokens, cfg.d_model),
                    jnp.bfloat16)
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 jnp.int32(step))
            losses.append(float(metrics["loss"]))
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['gnorm']):.3f}")
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
    dt = time.time() - t0
    print(f"[train] {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses[-1])
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
