from repro.sharding.rules import MeshPlan, Sharder, batch_spec, bytes_of, constrain

__all__ = ["MeshPlan", "Sharder", "batch_spec", "bytes_of", "constrain"]
