"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation pytree in the framework carries a parallel
"axes" pytree of tuples of *logical* axis names (e.g. ``("layers", "embed",
"heads")``).  A :class:`MeshPlan` resolves each logical axis to zero or more
physical mesh axes, yielding a ``PartitionSpec`` per leaf.  The same model
code therefore runs unsharded on one CPU device and fully sharded on the
512-chip multi-pod mesh purely by swapping the plan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig

# ---------------------------------------------------------------------------
# Logical axis vocabulary
# ---------------------------------------------------------------------------
# layers      scan-stacked layer dim                      -> never sharded
# vocab       embedding-table / lm-head vocab dim         -> tensor axes
# embed       model (residual) dim                        -> fsdp axes
# heads       flattened q-heads*head_dim projection dim   -> tensor axes
# kv_heads    flattened kv-heads*head_dim projection dim  -> tensor axes
# mlp         FFN hidden dim                              -> tensor axes
# expert      MoE expert dim                              -> tensor axes (EP)
# expert_in   per-expert input dim (embed inside experts) -> fsdp axes
# batch       global batch                                -> batch axes (pod+data)
# seq         sequence (activations)                      -> unsharded (SP opt-in)
# seq_kv      KV-cache sequence dim                       -> tensor axes (flash-decode SP)
# ssm_inner   mamba/mlstm inner dim                       -> tensor axes
# ssm_state   SSM state dim                               -> unsharded
# norm,const  tiny vectors                                -> unsharded


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Resolution of logical axes onto a physical mesh."""

    mesh_cfg: MeshConfig
    extra_rules: tuple = ()  # ((logical, (phys, ...)), ...) overrides

    def rules(self) -> dict:
        m = self.mesh_cfg
        fsdp = tuple(a for a in m.fsdp_axes if a in m.axis_names)
        tensor = tuple(a for a in m.tensor_axes if a in m.axis_names)
        batch = tuple(a for a in m.batch_axes if a in m.axis_names)
        base = {
            "layers": (),
            "vocab": tensor,
            "embed": fsdp,
            "heads": tensor,
            "kv_heads": tensor,
            "mlp": tensor,
            "expert": tensor,
            "expert_in": fsdp,
            "batch": batch,
            "seq": (),
            "seq_kv": tensor,
            "ssm_inner": tensor,
            "ssm_state": (),
            "norm": (),
            "const": (),
            None: (),
        }
        base.update(dict(self.extra_rules))
        return base

    # ------------------------------------------------------------------
    def spec(self, axes: Optional[tuple], shape: Optional[tuple] = None) -> P:
        """PartitionSpec for one leaf. If ``shape`` given, drop non-divisible shardings."""
        if axes is None:
            return P()
        rules = self.rules()
        used: set = set()
        dims = []
        for i, a in enumerate(axes):
            phys = tuple(p for p in rules.get(a, ()) if p not in used)
            truncated = False
            if shape is not None and phys:
                total = math.prod(self.mesh_cfg.axis_size(p) for p in phys)
                if shape[i] % total != 0:
                    # try a divisible prefix (e.g. batch=128 on pod*data=32 ok,
                    # batch=1 -> unsharded)
                    keep = []
                    run = 1
                    for p in phys:
                        if shape[i] % (run * self.mesh_cfg.axis_size(p)) == 0:
                            keep.append(p)
                            run *= self.mesh_cfg.axis_size(p)
                        else:
                            break
                    truncated = len(keep) < len(phys)
                    phys = tuple(keep)
            used.update(phys)
            if len(phys) == 0:
                dims.append(None)
            elif len(phys) == 1 and not truncated:
                dims.append(phys[0])
            else:
                # keep the tuple form for a truncated multi-axis rule:
                # P(('pod',)) documents that ('pod', 'data') was requested
                dims.append(phys)
        while dims and dims[-1] is None:
            dims.pop()
        return P(*dims)

    def tree_specs(self, axes_tree, shape_tree=None):
        if shape_tree is None:
            return jax.tree.map(
                lambda ax: self.spec(ax), axes_tree,
                is_leaf=lambda x: x is None or (isinstance(x, tuple) and _is_axes(x)))
        return jax.tree.map(
            lambda ax, sd: self.spec(ax, tuple(sd.shape)), axes_tree, shape_tree,
            is_leaf=lambda x: x is None or (isinstance(x, tuple) and _is_axes(x)))

    def tree_shardings(self, mesh: Mesh, axes_tree, shape_tree=None):
        specs = self.tree_specs(axes_tree, shape_tree)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))


def _is_axes(x) -> bool:
    """A leaf in an axes-tree is a tuple of str/None (or None)."""
    return all(isinstance(e, str) or e is None for e in x)


# ---------------------------------------------------------------------------
# Helpers used across launch / tests
# ---------------------------------------------------------------------------

def constrain(tree, plan: MeshPlan, axes_tree):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    specs = plan.tree_specs(axes_tree)
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, specs)


def batch_spec(plan: MeshPlan, global_batch: int, extra_dims: int = 1) -> P:
    """PartitionSpec for a (batch, ...) input with divisibility fallback."""
    return plan.spec(("batch",) + (None,) * extra_dims,
                     (global_batch,) + (1,) * extra_dims)


def bytes_of(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


class Sharder:
    """Callable applying logical-axis sharding constraints inside jit.

    ``Sharder(None)`` (default in models) is the identity — the same model
    code runs unsharded on CPU and sharded on the production mesh.
    """

    def __init__(self, plan: Optional[MeshPlan] = None, mesh: Optional[Mesh] = None):
        self.plan = plan
        self.mesh = mesh

    def __call__(self, x, axes):
        if self.plan is None or self.mesh is None:
            return x
        spec = self.plan.spec(tuple(axes), tuple(x.shape))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
