"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory, chunkwise-parallel) and
sLSTM (scalar-memory, sequential) blocks.

Layout for xlstm-1.3b: 48 blocks = 6 segments of [7 mLSTM + 1 sLSTM]
(``slstm_every=8``). ``d_ff=0`` in the assigned config means there is no
separate FFN: mLSTM blocks are pre-up-projection (pf=2), the sLSTM block
carries a pf=4/3 gated FFN, per the paper.

Training uses the stabilised chunkwise-parallel mLSTM form (sub-quadratic,
O(T*chunk)); decode uses the O(1)-state recurrent form — which is why this
arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.sharding.rules import Sharder

# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel (training) and recurrent (decode)
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, i_logit, f_logit, chunk: int):
    """Stabilised chunkwise mLSTM.

    q,k,v: (b, T, H, dh); i_logit,f_logit: (b, T, H). Returns h: (b,T,H,dh).
    """
    b, T, H, dh = q.shape
    c = min(chunk, T)
    if T % c:
        c = T
    n_chunks = T // c
    scale = 1.0 / math.sqrt(dh)

    def to_chunks(x):
        return x.reshape(b, n_chunks, c, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q * scale), to_chunks(k), to_chunks(v)
    logf = to_chunks(jax.nn.log_sigmoid(f_logit.astype(jnp.float32)))
    logi = to_chunks(i_logit.astype(jnp.float32))

    def chunk_step(carry, xs):
        C, n, m = carry  # (b,H,dh,dh), (b,H,dh), (b,H)
        qs, ks, vs, lf, li = xs  # (b,c,H,dh), ..., (b,c,H)
        a = jnp.cumsum(lf, axis=1)  # inclusive decay from chunk start
        total = a[:, -1]  # (b,H)
        g = li - a  # (b,c,H)

        # row-stabiliser: m_i = max(intra running max, state path)
        m_loc = jax.lax.cummax(g, axis=1) + a  # (b,c,H)
        m_inter = m[:, None, :] + a
        m_i = jnp.maximum(m_loc, m_inter)  # (b,c,H)

        # intra-chunk (j <= i): w_ij = exp(a_i - a_j + li_j - m_i)
        wa = a[:, :, None, :] - a[:, None, :, :] + li[:, None, :, :] \
            - m_i[:, :, None, :]  # (b, i, j, H)
        mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[None, :, :, None]
        w = jnp.where(mask, jnp.exp(wa), 0.0)
        s = jnp.einsum("bihd,bjhd->bijh", qs.astype(jnp.float32),
                       ks.astype(jnp.float32))
        sw = s * w
        num_intra = jnp.einsum("bijh,bjhd->bihd", sw, vs.astype(jnp.float32))
        den_intra = jnp.sum(sw, axis=2)  # (b,i,H)

        # inter-chunk: state contribution, scaled exp(a_i + m - m_i)
        wi = jnp.exp(a + m[:, None, :] - m_i)  # (b,c,H)
        num_inter = jnp.einsum("bihd,bhde->bihe", qs.astype(jnp.float32),
                               C) * wi[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qs.astype(jnp.float32),
                               n) * wi

        denom = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_i))
        h = (num_intra + num_inter) / denom[..., None]

        # state update to chunk end
        m_new = jnp.maximum(m + total,
                            jnp.max(li + total[:, None, :] - a, axis=1))
        wk = jnp.exp(li + total[:, None, :] - a - m_new[:, None, :])  # (b,c,H)
        C_new = C * jnp.exp(m + total - m_new)[..., None, None] + jnp.einsum(
            "bjhd,bjhe,bjh->bhde", ks.astype(jnp.float32),
            vs.astype(jnp.float32), wk)
        n_new = n * jnp.exp(m + total - m_new)[..., None] + jnp.einsum(
            "bjhd,bjh->bhd", ks.astype(jnp.float32), wk)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((b, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, H, dh), jnp.float32)
    m0 = jnp.full((b, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, logf, logi))
    h = hs.swapaxes(0, 1).reshape(b, T, H, dh)
    return h.astype(v.dtype)


def mlstm_step(state, q, k, v, i_logit, f_logit):
    """Recurrent mLSTM step. state=(C,n,m): (b,H,dh,dh),(b,H,dh),(b,H);
    q,k,v: (b,H,dh); i,f: (b,H). Returns (new_state, h)."""
    C, n, m = state
    dh = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_logit.astype(jnp.float32))
    li = i_logit.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    C = C * fw[..., None, None] + iw[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = n * fw[..., None] + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h.astype(v.dtype)


# ---------------------------------------------------------------------------
# causal conv (kernel 4) used by both block types
# ---------------------------------------------------------------------------

def causal_conv(x, w, state=None):
    """x: (b,T,D), w: (K,D) depthwise. state: (b,K-1,D) or None.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return y, xp[:, -(K - 1):]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_diag_apply(x, w):
    """x: (b,t,H,dh) ; w: (H,dh,dh) -> per-head projection."""
    return jnp.einsum("bthd,hde->bthe", x, w.astype(x.dtype))


def mlstm_block_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = di // H
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)
    b = L.Builder()
    b.add("ln", L.zeros_init((d,), ("norm",), dt))
    b.add("w_up", L.dense_init(ks[0], (d, 2 * di), ("embed", "ssm_inner"), dt))
    b.add("conv", L.dense_init(ks[1], (4, di), (None, "ssm_inner"), dt))
    b.add("wq", L.dense_init(ks[2], (H, dh, dh), (None, None, None), dt))
    b.add("wk", L.dense_init(ks[3], (H, dh, dh), (None, None, None), dt))
    b.add("w_if", L.dense_init(ks[4], (di, 2 * H), ("ssm_inner", None), dt,
                               scale=0.02))
    b.add("b_if", (jnp.concatenate([jnp.zeros((H,), dt),
                                    jnp.full((H,), 3.0, dt)]), ("norm",)))
    b.add("out_norm", L.zeros_init((di,), ("norm",), dt))
    b.add("w_down", L.dense_init(ks[5], (di, d), ("ssm_inner", "embed"), dt))
    return b.build()


def mlstm_block_apply(p, x, cfg: ModelConfig, state=None):
    """state None for training (chunkwise); tuple for decode step."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = di // H
    bsz, T, _ = x.shape
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("btd,df->btf", h, p["w_up"].astype(h.dtype))
    u, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    uc, new_conv = causal_conv(u, p["conv"], conv_state)
    uc = jax.nn.silu(uc)
    uh = uc.reshape(bsz, T, H, dh)
    q = _block_diag_apply(uh, p["wq"])
    k = _block_diag_apply(uh, p["wk"])
    v = u.reshape(bsz, T, H, dh)
    gates = jnp.einsum("btf,fg->btg", uc, p["w_if"].astype(uc.dtype)) \
        + p["b_if"].astype(uc.dtype)
    i_logit, f_logit = jnp.split(gates, 2, axis=-1)  # (b,T,H) each
    if state is None:
        hm = mlstm_chunkwise(q, k, v, i_logit, f_logit, cfg.mlstm_chunk)
        new_state = None
    else:
        cell = (state["C"], state["n"], state["m"])
        cell, hm = mlstm_step(cell, q[:, 0], k[:, 0], v[:, 0],
                              i_logit[:, 0], f_logit[:, 0])
        hm = hm[:, None]
        new_state = {"C": cell[0], "n": cell[1], "m": cell[2],
                     "conv": new_conv}
    hm = hm.reshape(bsz, T, di)
    hm = L.rms_norm(hm, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("btf,fd->btd", hm, p["w_down"].astype(hm.dtype))
    return x + out, new_state


def slstm_block_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)
    ffd = int(d * 4 / 3 // 64 * 64)
    b = L.Builder()
    b.add("ln", L.zeros_init((d,), ("norm",), dt))
    b.add("conv", L.dense_init(ks[0], (4, d), (None, "embed"), dt))
    b.add("w_gates", L.dense_init(ks[1], (d, 4 * d), ("embed", "ssm_inner"), dt))
    b.add("r_gates", L.dense_init(ks[2], (4, H, dh, dh), (None, None, None, None),
                                  dt, scale=1.0 / math.sqrt(dh)))
    b.add("b_gates", (jnp.concatenate(
        [jnp.zeros((2 * d,), dt), jnp.full((d,), 3.0, dt),
         jnp.zeros((d,), dt)]), ("norm",)))
    b.add("out_norm", L.zeros_init((d,), ("norm",), dt))
    b.sub("ffn", L.mlp_init(ks[3], cfg, d_ff=ffd))
    b.add("ln_ffn", L.zeros_init((d,), ("norm",), dt))
    return b.build()


def slstm_block_apply(p, x, cfg: ModelConfig, state=None):
    """Sequential sLSTM. state None -> scan full sequence (training)."""
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    bsz, T, _ = x.shape
    h0 = L.rms_norm(x, p["ln"], cfg.norm_eps)
    conv_state = None if state is None else state["conv"]
    hc, new_conv = causal_conv(h0, p["conv"], conv_state)
    hc = jax.nn.silu(hc)
    wx = jnp.einsum("btd,df->btf", hc, p["w_gates"].astype(hc.dtype)) \
        + p["b_gates"].astype(hc.dtype)  # (b,T,4d)

    r = p["r_gates"]

    def step(carry, wx_t):
        c, n, m, hprev = carry  # (b,H,dh) x3 ... m: (b,H)
        rh = jnp.einsum("bhd,ghde->bghe", hprev, r.astype(hprev.dtype))
        rh = rh.reshape(bsz, 4 * d)
        gates = (wx_t.astype(jnp.float32) + rh.astype(jnp.float32)).reshape(
            bsz, 4, H, dh)
        z_t = jnp.tanh(gates[:, 0])
        i_l = gates[:, 1]
        f_l = gates[:, 2]
        o_t = jax.nn.sigmoid(gates[:, 3])
        lf = jax.nn.log_sigmoid(f_l)
        # per-head stabiliser (shared scale across the head's cells keeps the
        # c/n pair consistent across steps)
        m_new = jnp.max(jnp.maximum(lf + m[..., None], i_l), axis=-1)  # (b,H)
        fw = jnp.exp(lf + m[..., None] - m_new[..., None])
        iw = jnp.exp(i_l - m_new[..., None])
        c_new = fw * c + iw * z_t
        n_new = fw * n + iw
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new.astype(hprev.dtype)), h_new

    if state is None:
        c0 = jnp.zeros((bsz, H, dh), jnp.float32)
        m0 = jnp.full((bsz, H), -1e30, jnp.float32)
        h0i = jnp.zeros((bsz, H, dh), jnp.dtype(cfg.dtype))
        (_, _, _, _), hs = jax.lax.scan(
            step, (c0, c0, m0, h0i), wx.swapaxes(0, 1))
        hseq = hs.swapaxes(0, 1).reshape(bsz, T, d).astype(x.dtype)
        new_state = None
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])
        carry, hs = step(carry, wx[:, 0])
        hseq = hs[:, None].reshape(bsz, 1, d).astype(x.dtype)
        new_state = {"c": carry[0], "n": carry[1], "m": carry[2],
                     "h": carry[3], "conv": new_conv}
    hseq = L.rms_norm(hseq, p["out_norm"], cfg.norm_eps)
    x = x + hseq
    hf = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    x = x + L.mlp_apply(p["ffn"], hf)
    return x, new_state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class XLSTMModel:
    """48 blocks = segments of [slstm_every-1 mLSTM + 1 sLSTM]."""

    def __init__(self, cfg: ModelConfig, sharder: Optional[Sharder] = None):
        self.cfg = cfg
        self.sharder = sharder or Sharder()
        k = cfg.slstm_every or cfg.num_layers
        assert cfg.num_layers % k == 0
        self.n_segments = cfg.num_layers // k
        self.mlstm_per_seg = k - 1
        self.has_slstm = cfg.slstm_every > 0

    # -- params ---------------------------------------------------------
    def init(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        params, axes = {}, {}
        emb_p, emb_a = L.embed_init(ks[0], cfg)
        params["embed"], axes["embed"] = emb_p, emb_a
        n_m = self.n_segments * self.mlstm_per_seg
        mp, ma = L.stack_init(lambda r: mlstm_block_init(r, cfg), ks[1], n_m)
        # reshape stacked (n_m, ...) -> (segments, per_seg, ...)
        mp = jax.tree.map(lambda x: x.reshape(
            (self.n_segments, self.mlstm_per_seg) + x.shape[1:]), mp)
        ma = jax.tree.map(lambda a: ("layers",) + tuple(a), ma,
                          is_leaf=L._is_axes_tuple)
        params["mlstm"], axes["mlstm"] = mp, ma
        if self.has_slstm:
            sp, sa = L.stack_init(lambda r: slstm_block_init(r, cfg), ks[2],
                                  self.n_segments)
            params["slstm"], axes["slstm"] = sp, sa
        return params, axes

    def param_axes(self):
        return L.abstract_init(self.init)[1]

    # -- forward --------------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                           jnp.dtype(cfg.dtype))
        x = self.sharder(x, ("batch", "seq", None))

        def seg_body(x, xs):
            def m_body(x, mp):
                x, _ = mlstm_block_apply(mp, x, cfg)
                return x, None
            if self.has_slstm:
                mp, sp = xs
            else:
                (mp,) = xs
            x, _ = jax.lax.scan(m_body, x, mp)
            if self.has_slstm:
                x, _ = slstm_block_apply(sp, x, cfg)
            return x, None

        body = seg_body if cfg.remat == "none" else jax.checkpoint(seg_body)
        xs = (params["mlstm"], params["slstm"]) if self.has_slstm \
            else (params["mlstm"],)
        x, _ = jax.lax.scan(body, x, xs)
        logits = L.lm_logits(params["embed"], x, cfg)
        return self.sharder(logits, ("batch", "seq", "vocab")), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["targets"])
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    # -- decode ---------------------------------------------------------
    def cache_spec(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        di = cfg.ssm_expand * cfg.d_model
        H = cfg.num_heads
        dh = di // H
        dhs = cfg.d_model // H
        f32 = jnp.float32
        dt = jnp.dtype(cfg.dtype)
        S, M = self.n_segments, self.mlstm_per_seg
        spec = {
            "mlstm": {
                "C": jax.ShapeDtypeStruct((S, M, batch_size, H, dh, dh), f32),
                "n": jax.ShapeDtypeStruct((S, M, batch_size, H, dh), f32),
                "m": jax.ShapeDtypeStruct((S, M, batch_size, H), f32),
                "conv": jax.ShapeDtypeStruct((S, M, batch_size, 3, di), dt),
            }}
        ax = {
            "mlstm": {
                "C": ("layers", "layers", "batch", None, "ssm_inner", None),
                "n": ("layers", "layers", "batch", None, "ssm_inner"),
                "m": ("layers", "layers", "batch", None),
                "conv": ("layers", "layers", "batch", None, "ssm_inner"),
            }}
        if self.has_slstm:
            spec["slstm"] = {
                "c": jax.ShapeDtypeStruct((S, batch_size, H, dhs), f32),
                "n": jax.ShapeDtypeStruct((S, batch_size, H, dhs), f32),
                "m": jax.ShapeDtypeStruct((S, batch_size, H), f32),
                "h": jax.ShapeDtypeStruct((S, batch_size, H, dhs), dt),
                "conv": jax.ShapeDtypeStruct((S, batch_size, 3, cfg.d_model), dt),
            }
            ax["slstm"] = {
                "c": ("layers", "batch", None, None),
                "n": ("layers", "batch", None, None),
                "m": ("layers", "batch", None),
                "h": ("layers", "batch", None, None),
                "conv": ("layers", "batch", None, "embed"),
            }
        return spec, ax

    def init_cache(self, batch_size: int, max_seq: int):
        spec, _ = self.cache_spec(batch_size, max_seq)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        cache["mlstm"]["m"] = jnp.full_like(cache["mlstm"]["m"], -1e30)
        if self.has_slstm:
            cache["slstm"]["m"] = jnp.full_like(cache["slstm"]["m"], -1e30)
        return cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                           jnp.dtype(cfg.dtype))

        def seg_body(x, xs):
            if self.has_slstm:
                mp, mc, sp, sc = xs
            else:
                mp, mc = xs

            def m_body(x, inner):
                lp, lc = inner
                x, new = mlstm_block_apply(lp, x, cfg, state=lc)
                return x, new

            x, new_mc = jax.lax.scan(m_body, x, (mp, mc))
            if self.has_slstm:
                x, new_sc = slstm_block_apply(sp, x, cfg, state=sc)
                return x, (new_mc, new_sc)
            return x, (new_mc,)

        if self.has_slstm:
            xs = (params["mlstm"], cache["mlstm"], params["slstm"],
                  cache["slstm"])
        else:
            xs = (params["mlstm"], cache["mlstm"])
        x, news = jax.lax.scan(seg_body, x, xs)
        new_cache = {"mlstm": news[0]}
        if self.has_slstm:
            new_cache["slstm"] = news[1]
        logits = L.lm_logits(params["embed"], x, cfg)
        return logits, new_cache

    # -- specs ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        i32 = jnp.int32
        b, s = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            axes = {"tokens": ("batch", "seq")}
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["targets"] = ("batch", "seq")
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                     "pos": jax.ShapeDtypeStruct((), i32)}
            axes = {"tokens": ("batch", None), "pos": None}
        return specs, axes
