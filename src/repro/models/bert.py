"""DistilBERT (the paper's Big tier, 66,362,880 base params / 253.19 MB).

Faithful structure: learned positional embeddings, post-LN blocks with
biases, 2-matrix GELU FFN. A classification head (20 Newsgroups) is kept in
a separate subtree so the communicated payload matches the paper's tier.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class BertConfig:
    name: str = "distilbert"
    num_layers: int = 6
    d_model: int = 768
    num_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 30522
    max_pos: int = 512
    num_classes: int = 20  # 20 Newsgroups


def _linear(rng, d_in, d_out):
    w = jax.random.normal(rng, (d_in, d_out), jnp.float32) * (d_in ** -0.5)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _apply_linear(p, x):
    return x @ p["w"] + p["b"]


def _ln_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def _ln(p, x, eps=1e-12):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


class DistilBert:
    def __init__(self, cfg: BertConfig = BertConfig()):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        ks = iter(jax.random.split(rng, 16 + 8 * cfg.num_layers))
        p = {
            "word_emb": jax.random.normal(
                next(ks), (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
            "pos_emb": jax.random.normal(
                next(ks), (cfg.max_pos, cfg.d_model), jnp.float32) * 0.02,
            "emb_ln": _ln_init(cfg.d_model),
            "layers": [],
        }
        for _ in range(cfg.num_layers):
            blk = {
                "q": _linear(next(ks), cfg.d_model, cfg.d_model),
                "k": _linear(next(ks), cfg.d_model, cfg.d_model),
                "v": _linear(next(ks), cfg.d_model, cfg.d_model),
                "o": _linear(next(ks), cfg.d_model, cfg.d_model),
                "ln1": _ln_init(cfg.d_model),
                "ff1": _linear(next(ks), cfg.d_model, cfg.d_ff),
                "ff2": _linear(next(ks), cfg.d_ff, cfg.d_model),
                "ln2": _ln_init(cfg.d_model),
            }
            p["layers"].append(blk)
        return p

    def init_head(self, rng):
        return _linear(rng, self.cfg.d_model, self.cfg.num_classes)

    def forward(self, p, tokens):
        cfg = self.cfg
        b, s = tokens.shape
        x = p["word_emb"][tokens] + p["pos_emb"][:s][None]
        x = _ln(p["emb_ln"], x)
        hd = cfg.d_model // cfg.num_heads
        for blk in p["layers"]:
            q = _apply_linear(blk["q"], x).reshape(b, s, cfg.num_heads, hd)
            k = _apply_linear(blk["k"], x).reshape(b, s, cfg.num_heads, hd)
            v = _apply_linear(blk["v"], x).reshape(b, s, cfg.num_heads, hd)
            o = L.flash_attention(q, k, v, causal=False, q_chunk=256,
                                  kv_chunk=256)
            o = _apply_linear(blk["o"], o.reshape(b, s, cfg.d_model))
            x = _ln(blk["ln1"], x + o)
            h = jax.nn.gelu(_apply_linear(blk["ff1"], x))
            x = _ln(blk["ln2"], x + _apply_linear(blk["ff2"], h))
        return x

    def loss(self, p, head, batch):
        x = self.forward(p, batch["tokens"])
        pooled = x[:, 0]
        logits = _apply_linear(head, pooled)
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}
