"""Shared building blocks for the model zoo.

Conventions
-----------
* Parameters are nested dicts of jnp arrays; every init function returns the
  pair ``(params, axes)`` where ``axes`` is an isomorphic pytree of tuples of
  *logical* axis names (see ``repro.sharding.rules``).
* All forward functions are pure; compute dtype comes from the config, params
  keep their own dtype.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(rng, shape, axes, dtype, scale: Optional[float] = None):
    """Truncated-normal (fan-in) initialised matrix + its logical axes."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    w = (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std)
    return w.astype(dtype), tuple(axes)


def zeros_init(shape, axes, dtype):
    return jnp.zeros(shape, dtype), tuple(axes)


def ones_init(shape, axes, dtype):
    return jnp.ones(shape, dtype), tuple(axes)


class Builder:
    """Collects (param, axes) pairs into parallel pytrees."""

    def __init__(self):
        self.params = {}
        self.axes = {}

    def add(self, name, pair):
        p, a = pair
        self.params[name] = p
        self.axes[name] = a
        return p

    def sub(self, name, builder_or_pair):
        if isinstance(builder_or_pair, Builder):
            self.params[name] = builder_or_pair.params
            self.axes[name] = builder_or_pair.axes
        else:
            p, a = builder_or_pair
            self.params[name] = p
            self.axes[name] = a

    def build(self):
        return self.params, self.axes


def _is_axes_tuple(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def stack_init(init_fn, rng, n: int):
    """vmap an ``init_fn(rng) -> (params, axes)`` over ``n`` layer seeds and
    prepend the 'layers' logical axis. Axes (static strings) are captured by
    side effect since traced functions may only return arrays."""
    rngs = jax.random.split(rng, n)
    side = {}

    def params_only(r):
        p, a = init_fn(r)
        side["axes"] = a
        return p

    params = jax.vmap(params_only)(rngs)
    axes = jax.tree.map(lambda a: ("layers",) + tuple(a), side["axes"],
                        is_leaf=_is_axes_tuple)
    return params, axes


def abstract_init(init_fn, rng=None):
    """eval_shape an ``init_fn(rng) -> (params, axes)``: returns
    (ShapeDtypeStruct pytree, axes) without allocating."""
    import jax as _jax
    rng = rng if rng is not None else _jax.random.key(0)
    side = {}

    def params_only(r):
        p, a = init_fn(r)
        side["axes"] = a
        return p

    shapes = _jax.eval_shape(params_only, rng)
    return shapes, side["axes"]


# ---------------------------------------------------------------------------
# normalisation / rope
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (flash-style chunked, pure jnp — memory O(seq * chunk))
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, mask, scale):
    """q: (b,cq,hkv,g,d)  k/v: (b,ck,hkv,d) -> (scores-stats, out-partial)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)  # (b,h,g,q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def _merge(m1, l1, o1, m2, l2, o2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    l = l1 * a1 + l2 * a2
    # o partials are (b,q,h,g,d); stats (b,h,g,q) -> move q axis
    s1 = jnp.moveaxis(a1, -1, 1)[..., None]
    s2 = jnp.moveaxis(a2, -1, 1)[..., None]
    return m, l, o1 * s1 + o2 * s2


def flash_attention(q, k, v, *, causal: bool, q_chunk: int = 1024,
                    kv_chunk: int = 1024, kv_valid_len=None,
                    block_causal: bool = True):
    """Chunked (flash-style) attention with GQA, O(seq*chunk) live memory.

    q: (b, sq, hq, d); k,v: (b, skv, hkv, d). hq = g * hkv.
    ``block_causal=True`` skips fully-masked KV blocks for causal attention
    (true lower-triangular schedule — ~2x fewer attention FLOPs).
    ``kv_valid_len``: optional scalar — mask kv positions >= this (decode
    with a preallocated cache).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    q = q.reshape(b, sq, hkv, g, d)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = max(sq // q_chunk, 1)
    nk = max(skv // kv_chunk, 1)
    # fall back to single-block if not divisible
    if sq % q_chunk:
        nq, q_chunk = 1, sq
    if skv % kv_chunk:
        nk, kv_chunk = 1, skv

    kb = k.reshape(b, nk, kv_chunk, hkv, d)
    vb = v.reshape(b, nk, kv_chunk, hkv, d)
    kv_pos = jnp.arange(skv).reshape(nk, kv_chunk)

    outs = []
    for qi in range(nq):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m, l, o = carry
            kc, vc, kpos = xs
            mask = None
            if causal:
                mask = q_pos[:, None] >= kpos[None, :]
            if kv_valid_len is not None:
                vm = kpos < kv_valid_len
                mask = vm[None, :] if mask is None else (mask & vm[None, :])
            if mask is not None:
                mask = mask[None, None, None]  # (1,1,1,q,k) vs (b,h,g,q,k)
            m2, l2, o2 = _attn_block(qc, kc, vc, mask, scale)
            return _merge(m, l, o, m2, l2, o2), None

        m0 = jnp.full((b, hkv, g, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((b, q_chunk, hkv, g, d), jnp.float32)

        if causal and block_causal and nq == nk and sq == skv:
            hi = qi + 1  # blocks [0, qi] can contribute
            xs = (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
                  kv_pos[:hi])
            (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), xs)
        else:
            xs = (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kv_pos)
            (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), xs)

        l = jnp.moveaxis(l, -1, 1)[..., None]  # (b,q,h,g,1)
        outs.append((o / jnp.maximum(l, 1e-30)).astype(v.dtype))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, sq, hq, d)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token attention against a preallocated cache.

    q: (b, 1, hq, d); caches: (b, smax, hkv, d); cache_len: scalar int
    (number of valid positions, including the token just written).
    """
    b, _, hq, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qh = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(smax)[None, None, None, :] < cache_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, d).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# attention module (params + apply)
# ---------------------------------------------------------------------------

def attn_init(rng, cfg: ModelConfig, d_in: Optional[int] = None,
              lora_rank: int = 0):
    d = d_in or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)
    b = Builder()
    b.add("wq", dense_init(ks[0], (d, hq * hd), ("embed", "heads"), dt))
    b.add("wk", dense_init(ks[1], (d, hkv * hd), ("embed", "kv_heads"), dt))
    b.add("wv", dense_init(ks[2], (d, hkv * hd), ("embed", "kv_heads"), dt))
    b.add("wo", dense_init(ks[3], (hq * hd, d), ("heads", "embed"), dt))
    if cfg.qk_norm:
        b.add("q_norm", zeros_init((hd,), ("norm",), dt))
        b.add("k_norm", zeros_init((hd,), ("norm",), dt))
    if lora_rank:
        for i, nm in enumerate(("wq", "wk", "wv")):
            out = hq * hd if nm == "wq" else hkv * hd
            b.add(f"{nm}_lora_a", dense_init(ks[4 + i], (d, lora_rank),
                                             ("embed", "norm"), dt))
            b.add(f"{nm}_lora_b", zeros_init((lora_rank, out), ("norm", "heads"), dt))
    return b.build()


def _proj_qkv(p, x, cfg: ModelConfig, lora_scope=None):
    def mm(name, w):
        y = jnp.einsum("bsd,df->bsf", x, w.astype(x.dtype))
        if lora_scope is not None and f"{name}_lora_a" in p:
            a = lora_scope(p[f"{name}_lora_a"]).astype(x.dtype)
            bb = lora_scope(p[f"{name}_lora_b"]).astype(x.dtype)
            y = y + jnp.einsum("bsd,dr,rf->bsf", x, a, bb)
        return y

    b, s, _ = x.shape
    hd = cfg.head_dim
    q = mm("wq", p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = mm("wk", p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = mm("wv", p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, *, positions, causal=None,
               block_causal=True, lora_scope=None):
    causal = cfg.causal if causal is None else causal
    q, k, v = _proj_qkv(p, x, cfg, lora_scope)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, q_chunk=cfg.attn_chunk,
                        kv_chunk=cfg.attn_chunk, block_causal=block_causal)
    b, s, _, _ = o.shape
    o = o.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(x.dtype))


def attn_prefill(p, x, cfg: ModelConfig, *, positions, smax,
                 lora_scope=None):
    """Forward + return kv to seed a decode cache padded to smax."""
    q, k, v = _proj_qkv(p, x, cfg, lora_scope)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=cfg.causal, q_chunk=cfg.attn_chunk,
                        kv_chunk=cfg.attn_chunk)
    b, s, _, _ = o.shape
    pad = [(0, 0), (0, smax - s), (0, 0), (0, 0)]
    k_cache = jnp.pad(k, pad)
    v_cache = jnp.pad(v, pad)
    o = o.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(x.dtype)), (k_cache, v_cache)


def attn_decode(p, x, cache, cfg: ModelConfig, *, pos, lora_scope=None):
    """x: (b,1,d); cache: dict(k,v) of (b,smax,hkv,hd); pos: scalar index."""
    q, k, v = _proj_qkv(p, x, cfg, lora_scope)
    positions = pos[None, None] if jnp.ndim(pos) == 0 else pos
    q = apply_rope(q, positions.astype(jnp.int32), cfg.rope_theta)
    k = apply_rope(k, positions.astype(jnp.int32), cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    b = x.shape[0]
    o = o.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    out = jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache}


def cross_attn_apply(p, x, kv_embeds, cfg: ModelConfig):
    """Cross attention onto (b, n_img, d) context (no rope)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(x.dtype)).reshape(
        b, s, cfg.num_heads, hd)
    k = jnp.einsum("bnd,df->bnf", kv_embeds, p["wk"].astype(x.dtype)).reshape(
        b, -1, cfg.num_kv_heads, hd)
    v = jnp.einsum("bnd,df->bnf", kv_embeds, p["wv"].astype(x.dtype)).reshape(
        b, -1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    o = flash_attention(q, k, v, causal=False, q_chunk=cfg.attn_chunk,
                        kv_chunk=cfg.attn_chunk)
    o = o.reshape(b, s, cfg.num_heads * hd)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 3)
    b = Builder()
    if not cfg.mlp_gelu:
        b.add("w_gate", dense_init(ks[0], (d, ff), ("embed", "mlp"), dt))
    b.add("w_up", dense_init(ks[1], (d, ff), ("embed", "mlp"), dt))
    b.add("w_down", dense_init(ks[2], (ff, d), ("mlp", "embed"), dt))
    return b.build()


def mlp_apply(p, x):
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


def moe_init(rng, cfg: ModelConfig):
    E, ff, d = cfg.num_experts, cfg.d_ff, cfg.d_model
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 5)
    b = Builder()
    b.add("router", dense_init(ks[0], (d, E), ("embed", "expert"), dt,
                               scale=0.02))
    b.add("w_gate", dense_init(ks[1], (E, d, ff), ("expert", "expert_in", "mlp"), dt))
    b.add("w_up", dense_init(ks[2], (E, d, ff), ("expert", "expert_in", "mlp"), dt))
    b.add("w_down", dense_init(ks[3], (E, ff, d), ("expert", "mlp", "expert_in"), dt))
    if cfg.num_shared_experts:
        b.sub("shared", mlp_init(ks[4], cfg, d_ff=ff * cfg.num_shared_experts))
    return b.build()


def moe_apply(p, x, cfg: ModelConfig, *, group_size: int = 2048,
              capacity_factor: float = 1.25):
    """GShard-style grouped top-k dispatch (einsum-only, MXU-friendly).

    Tokens are split into groups; each group dispatches into per-expert
    capacity slots via one-hot matmuls. Over-capacity tokens are dropped
    (residual passes through), standard for capacity-based TPU MoE.
    """
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    tokens = x.reshape(-1, d)
    T = tokens.shape[0]
    g = min(group_size, T)
    if T % g:
        g = T  # single group fallback
    n_groups = T // g
    cap = max(int(g * k * capacity_factor / E), 1)

    xt = tokens.reshape(n_groups, g, d)
    logits = jnp.einsum("ngd,de->nge", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    # top-k gating
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (n, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (n,g,k,E)
    flat = onehot.reshape(n_groups, g * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, g, k, E)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)  # (n,g,k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch/combine one-hots: (n, g, k, E, cap) reduced over k
    cap_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("ngke,ngkc->ngec", onehot, cap_oh)
    combine = jnp.einsum("ngke,ngkc,ngk->ngec", onehot, cap_oh, gate_vals)

    xe = jnp.einsum("ngec,ngd->necd", dispatch.astype(x.dtype), xt)
    xe = xe.transpose(1, 0, 2, 3).reshape(E, n_groups * cap, d)  # (E, n*cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    ye = ye.reshape(E, n_groups, cap, d).transpose(1, 0, 2, 3)  # (n,E,cap,d)
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), ye)
    y = y.reshape(b, s, d)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(flat, axis=1) * E  # fraction routed per expert * E
    pe = jnp.mean(probs, axis=1) * E
    aux = jnp.mean(jnp.sum(me * pe, axis=-1)) / E

    if cfg.num_shared_experts and "shared" in p:
        y = y + mlp_apply(p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_init(rng, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    b = Builder()
    ks = jax.random.split(rng, 2)
    if not cfg.external_embeddings:
        b.add("embedding", dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                      ("vocab", "embed"), dt, scale=1.0))
    if not cfg.tie_embeddings:
        b.add("lm_head", dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                    ("embed", "vocab"), dt))
    b.add("final_norm", zeros_init((cfg.d_model,), ("norm",), dt))
    return b.build()


def embed_lookup(p, tokens, cfg: ModelConfig, compute_dtype):
    emb = jnp.take(p["embedding"], tokens, axis=0).astype(compute_dtype)
    return emb * math.sqrt(cfg.d_model) if cfg.tie_embeddings else emb


def lm_logits(p, x, cfg: ModelConfig):
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def cross_entropy(logits, targets, *, z_loss: float = 1e-4):
    """Mean token cross-entropy in fp32 with z-loss regulariser."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
