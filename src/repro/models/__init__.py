from repro.models.registry import (active_param_count, build_model,
                                   model_flops_per_token, param_count,
                                   param_shapes_and_axes)

__all__ = ["build_model", "param_count", "active_param_count",
           "model_flops_per_token", "param_shapes_and_axes"]
