"""The paper's image-model payload tiers as real JAX models.

ResNet56 (Small, ~2.4 MB fp32) / MobileNetV3-style (Medium, ~20 MB) /
ViT-Large (Large, ~1.24 GB).  Used by the FL end-to-end path (clients train
these locally, the comm backends move their parameter pytrees).

These are CIFAR/GLD-style classifiers; exact reference param counts are in
``repro.configs.paper_tiers``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L


def conv_init(rng, k, c_in, c_out, dtype=jnp.float32, groups=1):
    fan_in = k * k * c_in // groups
    std = math.sqrt(2.0 / fan_in)
    w = jax.random.normal(rng, (k, k, c_in // groups, c_out), jnp.float32) * std
    return w.astype(dtype)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn_init(c, dtype=jnp.float32):
    # inference-style affine norm (FL payloads include scale/bias)
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def norm_apply(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return x * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# ResNet-56 (CIFAR-style: 3 stages x 9 basic blocks, widths 16/32/64)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet56"
    widths: Sequence[int] = (16, 32, 64)
    blocks_per_stage: int = 9
    num_classes: int = 203  # GLD-23k-ish label space (paper uses GLD-23K)
    image_size: int = 32


class ResNet:
    def __init__(self, cfg: ResNetConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        ks = iter(jax.random.split(rng, 200))
        p = {"stem": {"w": conv_init(next(ks), 3, 3, cfg.widths[0]),
                      "bn": bn_init(cfg.widths[0])}}
        c_in = cfg.widths[0]
        for si, width in enumerate(cfg.widths):
            stage = []
            for bi in range(cfg.blocks_per_stage):
                stride = 2 if (si > 0 and bi == 0) else 1
                blk = {"c1": conv_init(next(ks), 3, c_in, width),
                       "bn1": bn_init(width),
                       "c2": conv_init(next(ks), 3, width, width),
                       "bn2": bn_init(width)}
                if stride != 1 or c_in != width:
                    blk["proj"] = conv_init(next(ks), 1, c_in, width)
                stage.append(blk)
                c_in = width
            p[f"stage{si}"] = stage
        p["head"] = {
            "w": jax.random.normal(next(ks), (c_in, cfg.num_classes),
                                   jnp.float32) * 0.01,
            "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
        return p

    def forward(self, p, images):
        cfg = self.cfg
        x = norm_apply(p["stem"]["bn"], conv(images, p["stem"]["w"]))
        x = jax.nn.relu(x)
        for si in range(len(cfg.widths)):
            for bi, blk in enumerate(p[f"stage{si}"]):
                stride = 2 if (si > 0 and bi == 0) else 1
                h = jax.nn.relu(norm_apply(blk["bn1"],
                                           conv(x, blk["c1"], stride)))
                h = norm_apply(blk["bn2"], conv(h, blk["c2"]))
                sc = conv(x, blk["proj"], stride) if "proj" in blk else x
                x = jax.nn.relu(h + sc)
        x = jnp.mean(x, axis=(1, 2))
        return x @ p["head"]["w"] + p["head"]["b"]

    def loss(self, p, batch):
        logits = self.forward(p, batch["images"])
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}


# ---------------------------------------------------------------------------
# MobileNetV3-style (inverted residuals + SE), Medium tier
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MobileNetConfig:
    name: str = "mobilenetv3"
    # (expand, out_channels, stride, use_se) per block
    blocks: tuple = ((1, 16, 1, False), (4, 24, 2, False), (3, 24, 1, False),
                     (3, 40, 2, True), (3, 40, 1, True), (3, 40, 1, True),
                     (6, 80, 2, False), (2.5, 80, 1, False), (2.3, 80, 1, False),
                     (6, 112, 1, True), (6, 112, 1, True),
                     (6, 160, 2, True), (6, 160, 1, True), (6, 160, 1, True))
    stem: int = 16
    head: int = 960
    classifier: int = 1280
    num_classes: int = 203
    image_size: int = 64


class MobileNetV3:
    def __init__(self, cfg: MobileNetConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        ks = iter(jax.random.split(rng, 400))
        p = {"stem": {"w": conv_init(next(ks), 3, 3, cfg.stem),
                      "bn": bn_init(cfg.stem)}}
        c_in = cfg.stem
        blocks = []
        for (exp, out, stride, se) in cfg.blocks:
            c_mid = int(c_in * exp + 0.5)
            blk = {"expand": conv_init(next(ks), 1, c_in, c_mid),
                   "bn_e": bn_init(c_mid),
                   "dw": conv_init(next(ks), 3, c_mid, c_mid, groups=c_mid),
                   "bn_d": bn_init(c_mid),
                   "project": conv_init(next(ks), 1, c_mid, out),
                   "bn_p": bn_init(out)}
            if se:
                c_se = max(c_mid // 4, 8)
                blk["se_down"] = conv_init(next(ks), 1, c_mid, c_se)
                blk["se_up"] = conv_init(next(ks), 1, c_se, c_mid)
            blocks.append(blk)
            c_in = out
        p["blocks"] = blocks
        p["head"] = {"w": conv_init(next(ks), 1, c_in, cfg.head),
                     "bn": bn_init(cfg.head),
                     "fc1": jax.random.normal(next(ks), (cfg.head, cfg.classifier),
                                              jnp.float32) * 0.01,
                     "fc2": jax.random.normal(next(ks),
                                              (cfg.classifier, cfg.num_classes),
                                              jnp.float32) * 0.01,
                     "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
        return p

    def forward(self, p, images):
        x = jax.nn.hard_swish(norm_apply(p["stem"]["bn"],
                                         conv(images, p["stem"]["w"], 2)))
        for (_, _, stride, _), blk in zip(self.cfg.blocks, p["blocks"]):
            h = jax.nn.hard_swish(norm_apply(blk["bn_e"],
                                             conv(x, blk["expand"])))
            c_mid = h.shape[-1]
            h = jax.nn.hard_swish(norm_apply(
                blk["bn_d"], conv(h, blk["dw"], stride, groups=c_mid)))
            if "se_down" in blk:
                s = jnp.mean(h, axis=(1, 2), keepdims=True)
                s = jax.nn.relu(conv(s, blk["se_down"]))
                s = jax.nn.sigmoid(conv(s, blk["se_up"]))
                h = h * s
            h = norm_apply(blk["bn_p"], conv(h, blk["project"]))
            if stride == 1 and h.shape[-1] == x.shape[-1]:
                h = h + x
            x = h
        x = jax.nn.hard_swish(norm_apply(p["head"]["bn"],
                                         conv(x, p["head"]["w"])))
        x = jnp.mean(x, axis=(1, 2))
        x = jax.nn.hard_swish(x @ p["head"]["fc1"])
        return x @ p["head"]["fc2"] + p["head"]["b"]

    def loss(self, p, batch):
        logits = self.forward(p, batch["images"])
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}


# ---------------------------------------------------------------------------
# ViT-Large (Large tier, 307M params)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str = "vit-large"
    num_layers: int = 24
    d_model: int = 1024
    num_heads: int = 16
    d_ff: int = 4096
    patch: int = 16
    image_size: int = 224
    num_classes: int = 203


class ViT:
    """Encoder-only transformer over patch embeddings (classification)."""

    def __init__(self, cfg: ViTConfig):
        self.cfg = cfg
        from repro.configs.base import ModelConfig
        self.lm_cfg = ModelConfig(
            name=cfg.name, family="audio", num_layers=cfg.num_layers,
            d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_heads, d_ff=cfg.d_ff,
            vocab_size=cfg.num_classes, causal=False,
            external_embeddings=True, dtype="float32",
            param_dtype="float32", remat="none", attn_chunk=256,
            mlp_gelu=True)

    def init(self, rng):
        from repro.models.transformer import TransformerLM
        cfg = self.cfg
        ks = jax.random.split(rng, 3)
        self._tf = TransformerLM(self.lm_cfg)
        tf_params, _ = self._tf.init(ks[0])
        n_patches = (cfg.image_size // cfg.patch) ** 2
        p = {"tf": tf_params,
             "patch_w": jax.random.normal(
                 ks[1], (cfg.patch * cfg.patch * 3, cfg.d_model),
                 jnp.float32) * 0.02,
             "patch_b": jnp.zeros((cfg.d_model,), jnp.float32),
             "pos": jax.random.normal(ks[2], (n_patches, cfg.d_model),
                                      jnp.float32) * 0.02}
        return p

    def _patchify(self, images):
        cfg = self.cfg
        b, h, w, c = images.shape
        ph = h // cfg.patch
        x = images.reshape(b, ph, cfg.patch, ph, cfg.patch, c)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, ph * ph, -1)
        return x

    def forward(self, p, images):
        from repro.models.transformer import TransformerLM
        tf = getattr(self, "_tf", None) or TransformerLM(self.lm_cfg)
        x = self._patchify(images) @ p["patch_w"] + p["patch_b"] + p["pos"]
        logits, _ = tf.forward(p["tf"], {"embeds": x})
        return jnp.mean(logits, axis=1)  # mean-pool classification

    def loss(self, p, batch):
        logits = self.forward(p, batch["images"])
        return L.cross_entropy(logits[:, None, :], batch["labels"][:, None],
                               z_loss=0.0), {}
