"""Zamba2-style hybrid: Mamba2 (SSD) backbone + a shared attention block
applied every ``attn_every`` mamba blocks with per-application LoRA
(arXiv:2411.15242).

Training uses the chunked SSD scan (sub-quadratic); decode keeps O(1) SSM
state per block plus a KV cache only for the handful of shared-attention
applications — which is why this arch runs ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models.xlstm import causal_conv
from repro.sharding.rules import Sharder

# ---------------------------------------------------------------------------
# Mamba2 SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD scan (Mamba2).

    x: (b,T,H,dh); dt: (b,T,H) (post-softplus); A: (H,) negative;
    B,C: (b,T,N); D: (H,). Returns y: (b,T,H,dh).
    """
    b, T, H, dh = x.shape
    N = B.shape[-1]
    c = min(chunk, T)
    if T % c:
        c = T
    n_chunks = T // c

    def to_chunks(z):
        return z.reshape(b, n_chunks, c, *z.shape[2:]).swapaxes(0, 1)

    xc = to_chunks(x)
    dtc = to_chunks(dt.astype(jnp.float32))
    Bc = to_chunks(B.astype(jnp.float32))
    Cc = to_chunks(C.astype(jnp.float32))
    a = dtc * A.astype(jnp.float32)  # (n,b,c,H) decay log-coefficients (<=0)

    def chunk_step(S, xs):
        xk, dtk, Bk, Ck, ak = xs
        cum = jnp.cumsum(ak, axis=1)  # (b,c,H) inclusive
        total = cum[:, -1]  # (b,H)
        # intra-chunk: L_ij = exp(cum_i - cum_j) for j<=i
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (b,i,j,H)
        mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[None, :, :, None]
        Lm = jnp.where(mask, jnp.exp(diff), 0.0)
        CB = jnp.einsum("bin,bjn->bij", Ck, Bk)  # (b,i,j)
        W = CB[..., None] * Lm * dtk[:, None, :, :]  # (b,i,j,H)
        y_intra = jnp.einsum("bijh,bjhd->bihd", W, xk.astype(jnp.float32))
        # inter-chunk: y_i += C_i . S * exp(cum_i)
        y_inter = jnp.einsum("bin,bhnd->bihd", Ck, S) * jnp.exp(cum)[..., None]
        # state update: S' = exp(total) S + sum_j exp(total - cum_j) dt_j B_j x_j
        wj = jnp.exp(total[:, None, :] - cum) * dtk  # (b,c,H)
        S_new = S * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjn,bjh,bjhd->bhnd", Bk, wj, xk.astype(jnp.float32))
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((b, H, N, dh), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, S0, (xc, dtc, Bc, Cc, a))
    y = ys.swapaxes(0, 1).reshape(b, T, H, dh)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype)


def ssd_step(S, x, dt, A, B, C, D):
    """Recurrent SSD step. S: (b,H,N,dh); x: (b,H,dh); dt: (b,H);
    B,C: (b,N). Returns (S', y)."""
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))  # (b,H)
    xf = x.astype(jnp.float32)
    S = S * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhd->bhnd", B.astype(jnp.float32), dtf, xf)
    y = jnp.einsum("bn,bhnd->bhd", C.astype(jnp.float32), S)
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return S, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_block_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    b = L.Builder()
    b.add("ln", L.zeros_init((d,), ("norm",), dt))
    # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
    b.add("w_in", L.dense_init(ks[0], (d, 2 * di + 2 * N + H),
                               ("embed", "ssm_inner"), dt))
    b.add("conv", L.dense_init(ks[1], (4, di + 2 * N), (None, "ssm_inner"), dt))
    b.add("A_log", (jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
                    ("norm",)))
    b.add("D", L.ones_init((H,), ("norm",), jnp.float32))
    b.add("dt_bias", (jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(
        jnp.float32), ("norm",)))
    b.add("out_norm", L.zeros_init((di,), ("norm",), dt))
    b.add("w_out", L.dense_init(ks[2], (di, d), ("ssm_inner", "embed"), dt))
    return b.build()


def mamba_block_apply(p, x, cfg: ModelConfig, state=None):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    dh = cfg.ssm_head_dim
    bsz, T, _ = x.shape
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("btd,df->btf", h, p["w_in"].astype(h.dtype))
    z, xin, Bv, Cv, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bv, Cv = jnp.split(conv_out, [di, di + N], axis=-1)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(bsz, T, H, dh)
    if state is None:
        y = ssd_chunked(xh, dtv, A, Bv, Cv, p["D"], cfg.ssm_chunk)
        new_state = None
    else:
        S, y1 = ssd_step(state["S"], xh[:, 0], dtv[:, 0], A, Bv[:, 0],
                         Cv[:, 0], p["D"])
        y = y1[:, None]
        new_state = {"S": S, "conv": new_conv}
    y = y.reshape(bsz, T, di)
    y = L.rms_norm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("btf,fd->btd", y, p["w_out"].astype(y.dtype))
    return x + out, new_state


# ---------------------------------------------------------------------------
# shared attention block (Zamba2): input = concat(x, x0) -> d
# ---------------------------------------------------------------------------

def shared_attn_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 4)
    b = L.Builder()
    b.add("ln", L.zeros_init((2 * d,), ("norm",), dt))
    b.add("w_in", L.dense_init(ks[0], (2 * d, d), ("embed", None), dt))
    b.sub("attn", L.attn_init(ks[1], cfg,
                              lora_rank=cfg.shared_attn_lora_rank))
    b.add("ln2", L.zeros_init((d,), ("norm",), dt))
    b.sub("mlp", L.mlp_init(ks[2], cfg, d_ff=cfg.d_ff))
    return b.build()


def shared_lora_init(rng, cfg: ModelConfig):
    """Per-application LoRA deltas for the shared block's qkv."""
    if not cfg.shared_attn_lora_rank:
        return {}, {}
    d, r = cfg.d_model, cfg.shared_attn_lora_rank
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    b = L.Builder()
    for i, (nm, out) in enumerate((("wq", hq * hd), ("wk", hkv * hd),
                                   ("wv", hkv * hd))):
        b.add(f"{nm}_a", L.dense_init(ks[2 * i], (d, r), ("embed", None), dt))
        b.add(f"{nm}_b", L.zeros_init((r, out), (None, "heads"), dt))
    return b.build()


def _lora_adjusted(attn_p, lora_p):
    """Merge per-application lora into attention weights view."""
    if not lora_p:
        return attn_p
    p = dict(attn_p)
    for nm in ("wq", "wk", "wv"):
        p[nm] = attn_p[nm] + (lora_p[f"{nm}_a"] @ lora_p[f"{nm}_b"]).astype(
            attn_p[nm].dtype)
    return p


def shared_attn_apply(p, lora_p, x, x0, cfg: ModelConfig, *, positions):
    h = L.rms_norm(jnp.concatenate([x, x0], axis=-1), p["ln"], cfg.norm_eps)
    h = jnp.einsum("btf,fd->btd", h, p["w_in"].astype(h.dtype))
    ap = _lora_adjusted(p["attn"], lora_p)
    a = L.attn_apply(ap, h, cfg, positions=positions,
                     block_causal=cfg.block_causal)
    x = x + a
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp_apply(p["mlp"], h2)


def shared_attn_decode(p, lora_p, x, x0, kv_cache, cfg: ModelConfig, *, pos):
    h = L.rms_norm(jnp.concatenate([x, x0], axis=-1), p["ln"], cfg.norm_eps)
    h = jnp.einsum("btf,fd->btd", h, p["w_in"].astype(h.dtype))
    ap = _lora_adjusted(p["attn"], lora_p)
    o, new_kv = L.attn_decode(ap, h, kv_cache, cfg, pos=pos)
    x = x + o
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp_apply(p["mlp"], h2), new_kv


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class ZambaModel:
    """``n_apps`` groups of [shared-attn + attn_every mamba] + trailing
    mamba blocks; one set of shared attention weights + per-app LoRA."""

    def __init__(self, cfg: ModelConfig, sharder: Optional[Sharder] = None):
        self.cfg = cfg
        self.sharder = sharder or Sharder()
        k = cfg.attn_every
        self.n_apps = cfg.num_layers // k
        self.per_group = k
        self.trailing = cfg.num_layers - self.n_apps * k

    def init(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        params, axes = {}, {}
        emb_p, emb_a = L.embed_init(ks[0], cfg)
        params["embed"], axes["embed"] = emb_p, emb_a
        n_m = self.n_apps * self.per_group
        mp, ma = L.stack_init(lambda r: mamba_block_init(r, cfg), ks[1], n_m)
        mp = jax.tree.map(lambda x: x.reshape(
            (self.n_apps, self.per_group) + x.shape[1:]), mp)
        ma = jax.tree.map(lambda a: ("layers",) + tuple(a), ma,
                          is_leaf=L._is_axes_tuple)
        params["mamba"], axes["mamba"] = mp, ma
        sp, sa = shared_attn_init(ks[2], cfg)
        params["shared"], axes["shared"] = sp, sa
        lp, la = L.stack_init(lambda r: shared_lora_init(r, cfg), ks[3],
                              self.n_apps)
        params["lora"], axes["lora"] = lp, la
        if self.trailing:
            tp, ta = L.stack_init(lambda r: mamba_block_init(r, cfg), ks[4],
                                  self.trailing)
            params["tail"], axes["tail"] = tp, ta
        return params, axes

    def param_axes(self):
        return L.abstract_init(self.init)[1]

    # -- forward --------------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                           jnp.dtype(cfg.dtype))
        x = self.sharder(x, ("batch", "seq", None))
        x0 = x
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        shared = params["shared"]

        def group_body(x, xs):
            mp, lp = xs
            x = shared_attn_apply(shared, lp, x, x0, cfg, positions=positions)

            def m_body(x, layer_p):
                x, _ = mamba_block_apply(layer_p, x, cfg)
                return x, None

            x, _ = jax.lax.scan(m_body, x, mp)
            return x, None

        body = group_body if cfg.remat == "none" else jax.checkpoint(group_body)
        x, _ = jax.lax.scan(body, x, (params["mamba"], params["lora"]))
        if self.trailing:
            def t_body(x, layer_p):
                x, _ = mamba_block_apply(layer_p, x, cfg)
                return x, None
            t_body = t_body if cfg.remat == "none" else jax.checkpoint(t_body)
            x, _ = jax.lax.scan(t_body, x, params["tail"])
        logits = L.lm_logits(params["embed"], x, cfg)
        return self.sharder(logits, ("batch", "seq", "vocab")), \
            jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["targets"])
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    # -- decode ---------------------------------------------------------
    def cache_spec(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        di = cfg.ssm_expand * cfg.d_model
        N = cfg.ssm_state
        H = di // cfg.ssm_head_dim
        dh = cfg.ssm_head_dim
        f32, dtc = jnp.float32, jnp.dtype(cfg.dtype)
        A, G = self.n_apps, self.per_group
        spec = {
            "mamba": {
                "S": jax.ShapeDtypeStruct((A, G, batch_size, H, N, dh), f32),
                "conv": jax.ShapeDtypeStruct((A, G, batch_size, 3, di + 2 * N), dtc),
            },
            "attn_kv": {
                "k": jax.ShapeDtypeStruct(
                    (A, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtc),
                "v": jax.ShapeDtypeStruct(
                    (A, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtc),
            },
        }
        ax = {
            "mamba": {
                "S": ("layers", "layers", "batch", "ssm_inner", None, None),
                "conv": ("layers", "layers", "batch", None, "ssm_inner"),
            },
            "attn_kv": {
                "k": ("layers", "batch", "seq_kv", None, None),
                "v": ("layers", "batch", "seq_kv", None, None),
            },
        }
        if self.trailing:
            spec["tail"] = {
                "S": jax.ShapeDtypeStruct((self.trailing, batch_size, H, N, dh), f32),
                "conv": jax.ShapeDtypeStruct(
                    (self.trailing, batch_size, 3, di + 2 * N), dtc),
            }
            ax["tail"] = {
                "S": ("layers", "batch", "ssm_inner", None, None),
                "conv": ("layers", "batch", None, "ssm_inner"),
            }
        return spec, ax

    def init_cache(self, batch_size: int, max_seq: int):
        spec, _ = self.cache_spec(batch_size, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        pos = batch["pos"]
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                           jnp.dtype(cfg.dtype))
        x0 = x
        shared = params["shared"]

        def group_body(x, xs):
            mp, lp, mc, kvc = xs
            x, new_kv = shared_attn_decode(shared, lp, x, x0, kvc, cfg,
                                           pos=pos)

            def m_body(x, inner):
                layer_p, layer_c = inner
                x, new = mamba_block_apply(layer_p, x, cfg, state=layer_c)
                return x, new

            x, new_mc = jax.lax.scan(m_body, x, (mp, mc))
            return x, (new_mc, new_kv)

        x, (new_mamba, new_kv) = jax.lax.scan(
            group_body, x,
            (params["mamba"], params["lora"], cache["mamba"],
             cache["attn_kv"]))
        new_cache = {"mamba": new_mamba, "attn_kv": new_kv}
        if self.trailing:
            def t_body(x, inner):
                layer_p, layer_c = inner
                x, new = mamba_block_apply(layer_p, x, cfg, state=layer_c)
                return x, new
            x, new_tail = jax.lax.scan(t_body, x,
                                       (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
        logits = L.lm_logits(params["embed"], x, cfg)
        return logits, new_cache

    # -- specs ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        i32 = jnp.int32
        b, s = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            axes = {"tokens": ("batch", "seq")}
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["targets"] = ("batch", "seq")
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                     "pos": jax.ShapeDtypeStruct((), i32)}
            axes = {"tokens": ("batch", None), "pos": None}
        return specs, axes
