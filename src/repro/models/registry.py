"""Model registry: family -> implementation, plus analytic param counting."""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import Sharder


def build_model(cfg: ModelConfig, sharder: Optional[Sharder] = None):
    from repro.models.transformer import TransformerLM
    from repro.models.xlstm import XLSTMModel
    from repro.models.zamba import ZambaModel

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return TransformerLM(cfg, sharder)
    if cfg.family == "ssm":
        return XLSTMModel(cfg, sharder)
    if cfg.family == "hybrid":
        return ZambaModel(cfg, sharder)
    raise ValueError(f"unknown family {cfg.family}")


def param_shapes_and_axes(cfg: ModelConfig):
    from repro.models.layers import abstract_init

    model = build_model(cfg)
    return abstract_init(model.init)


def param_count(cfg: ModelConfig) -> int:
    shapes, _ = param_shapes_and_axes(cfg)
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top-k of E experts + everything else)."""
    if cfg.num_experts == 0:
        return param_count(cfg)
    shapes, axes = param_shapes_and_axes(cfg)
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)))
    total = 0
    frac = cfg.experts_per_token / cfg.num_experts
    for (path, leaf), ax in zip(flat_s, flat_a):
        n = int(np.prod(leaf.shape))
        ax = ax or ()
        if "expert" in ax and "expert_in" in ax:  # per-expert weight
            n = int(n * frac)
        total += n
    return total


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS = 6*N(_active)*D convention (per token, fwd+bwd)."""
    return 6.0 * active_param_count(cfg)
