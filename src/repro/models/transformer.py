"""TransformerLM: one substrate for the dense / moe / audio / vlm families.

Layer stacks are organised into *segments*: a segment is a fixed sequence of
block kinds repeated N times, scanned with ``jax.lax.scan`` over stacked
parameters.  This keeps the HLO small for 95-layer models while supporting
interleave patterns (MoE every k-th layer, cross-attention every 5th layer).

Block kinds: ``self`` (attn+mlp), ``moe`` (attn+moe-ffn), ``cross``
(gated cross-attn + mlp, llama-3.2-vision style).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.sharding.rules import Sharder


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


class TransformerLM:
    """Decoder-only (or encoder-only) transformer with GQA."""

    def __init__(self, cfg: ModelConfig, sharder: Optional[Sharder] = None):
        self.cfg = cfg
        self.sharder = sharder or Sharder()
        self.segments = self._plan_segments()

    # ------------------------------------------------------------------
    def _plan_segments(self):
        cfg = self.cfg
        Ln = cfg.num_layers
        if cfg.family == "vlm" and cfg.cross_attn_every:
            k = cfg.cross_attn_every
            assert Ln % k == 0
            kinds = tuple(["cross"] + ["self"] * (k - 1))
            return [(kinds, Ln // k)]
        if cfg.num_experts and cfg.moe_interleave > 1:
            k = cfg.moe_interleave
            assert Ln % k == 0
            kinds = tuple(["self"] * (k - 1) + ["moe"])
            return [(kinds, Ln // k)]
        if cfg.num_experts:
            return [(("moe",), Ln)]
        return [(("self",), Ln)]

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _block_init(self, rng, kind: str):
        cfg = self.cfg
        b = L.Builder()
        ks = jax.random.split(rng, 4)
        dt = jnp.dtype(cfg.param_dtype)
        b.add("ln1", L.zeros_init((cfg.d_model,), ("norm",), dt))
        b.add("ln2", L.zeros_init((cfg.d_model,), ("norm",), dt))
        if kind == "cross":
            b.sub("xattn", L.attn_init(ks[0], cfg))
            b.add("xgate", L.zeros_init((), (), dt))
            b.sub("mlp", L.mlp_init(ks[1], cfg,
                                    d_ff=cfg.d_ff_dense or cfg.d_ff))
        else:
            b.sub("attn", L.attn_init(ks[0], cfg))
            if kind == "moe":
                b.sub("moe", L.moe_init(ks[1], cfg))
            else:
                b.sub("mlp", L.mlp_init(ks[1], cfg,
                                        d_ff=cfg.d_ff_dense or cfg.d_ff))
        return b.build()

    def init(self, rng):
        """Returns (params, axes)."""
        cfg = self.cfg
        ks = jax.random.split(rng, len(self.segments) + 1)
        params, axes = {}, {}
        emb_p, emb_a = L.embed_init(ks[0], cfg)
        params["embed"], axes["embed"] = emb_p, emb_a
        for si, (kinds, repeat) in enumerate(self.segments):
            seg_p, seg_a = {}, {}
            for bi, kind in enumerate(kinds):
                def one(r, _kind=kind):
                    return self._block_init(r, _kind)
                p, a = L.stack_init(one, jax.random.fold_in(ks[si + 1], bi), repeat)
                seg_p[f"b{bi}_{kind}"] = p
                seg_a[f"b{bi}_{kind}"] = a
            params[f"seg{si}"] = seg_p
            axes[f"seg{si}"] = seg_a
        return params, axes

    def param_axes(self):
        return L.abstract_init(self.init)[1]

    def param_shapes(self):
        return L.abstract_init(self.init)[0]

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _block_apply(self, kind, p, x, *, positions, image_embeds=None,
                     causal=None):
        cfg = self.cfg
        shard = self.sharder
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if kind == "cross":
            a = L.cross_attn_apply(p["xattn"], h, image_embeds, cfg)
            x = x + jnp.tanh(p["xgate"].astype(a.dtype)) * a
        else:
            a = L.attn_apply(p["attn"], h, cfg, positions=positions,
                             causal=causal, block_causal=cfg.block_causal)
            x = x + a
        x = shard(x, ("batch", "seq", None))
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y, aux = L.moe_apply(p["moe"], h, cfg,
                                 group_size=cfg.moe_group_size,
                                 capacity_factor=cfg.capacity_factor)
        else:
            y = L.mlp_apply(p["mlp"], h)
        x = x + y
        return shard(x, ("batch", "seq", None)), aux

    def _stack_apply(self, params, x, *, positions, image_embeds=None):
        cfg = self.cfg

        for si, (kinds, repeat) in enumerate(self.segments):
            seg = params[f"seg{si}"]

            def body(carry, layer_p):
                x, aux = carry
                for bi, kind in enumerate(kinds):
                    x, a = self._block_apply(
                        kind, layer_p[f"b{bi}_{kind}"], x,
                        positions=positions, image_embeds=image_embeds)
                    aux = aux + a
                return (x, aux), None

            body = _remat(body, cfg.remat)
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), seg)
        return x, aux

    def forward(self, params, batch):
        """-> logits (b, s, vocab)."""
        cfg = self.cfg
        if cfg.external_embeddings:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        else:
            x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                               jnp.dtype(cfg.dtype))
        x = self.sharder(x, ("batch", "seq", None))
        s = x.shape[1]
        positions = batch.get("positions", jnp.arange(s, dtype=jnp.int32))
        img = batch.get("image_embeds")
        if img is not None:
            img = img.astype(x.dtype)
        x, aux = self._stack_apply(params, x, positions=positions,
                                   image_embeds=img)
        logits = L.lm_logits(params["embed"], x, cfg)
        logits = self.sharder(logits, ("batch", "seq", "vocab"))
        return logits, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["targets"])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # decode path
    # ------------------------------------------------------------------
    def cache_spec(self, batch_size: int, max_seq: int):
        """ShapeDtypeStructs (+ axes) for a decode cache."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        kv_shape = (batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim)
        kv_axes = ("batch", "seq_kv", None, None)
        cache, axes = {}, {}
        for si, (kinds, repeat) in enumerate(self.segments):
            seg_c, seg_a = {}, {}
            for bi, kind in enumerate(kinds):
                if kind == "cross":
                    n_img = cfg.num_image_tokens
                    xshape = (repeat, batch_size, n_img, cfg.num_kv_heads,
                              cfg.head_dim)
                    seg_c[f"b{bi}_{kind}"] = {
                        "xk": jax.ShapeDtypeStruct(xshape, dt),
                        "xv": jax.ShapeDtypeStruct(xshape, dt)}
                    seg_a[f"b{bi}_{kind}"] = {
                        "xk": ("layers", "batch", None, None, None),
                        "xv": ("layers", "batch", None, None, None)}
                else:
                    shape = (repeat,) + kv_shape
                    seg_c[f"b{bi}_{kind}"] = {
                        "k": jax.ShapeDtypeStruct(shape, dt),
                        "v": jax.ShapeDtypeStruct(shape, dt)}
                    seg_a[f"b{bi}_{kind}"] = {
                        "k": ("layers",) + kv_axes, "v": ("layers",) + kv_axes}
            cache[f"seg{si}"] = seg_c
            axes[f"seg{si}"] = seg_a
        return cache, axes

    def init_cache(self, batch_size: int, max_seq: int):
        spec, _ = self.cache_spec(batch_size, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def decode_step(self, params, cache, batch):
        """One token: batch = {tokens: (b,1), pos: scalar int32,
        image_embeds?}. Returns (logits, new_cache)."""
        cfg = self.cfg
        pos = batch["pos"]
        if cfg.external_embeddings:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        else:
            x = L.embed_lookup(params["embed"], batch["tokens"], cfg,
                               jnp.dtype(cfg.dtype))
        new_cache = {}
        for si, (kinds, repeat) in enumerate(self.segments):
            seg = params[f"seg{si}"]
            seg_cache = cache[f"seg{si}"]

            def body(x, xs):
                layer_p, layer_c = xs
                new_c = {}
                for bi, kind in enumerate(kinds):
                    key = f"b{bi}_{kind}"
                    p = layer_p[key]
                    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
                    if kind == "cross":
                        # static image kv — attend, no cache update
                        o = _cross_decode(p["xattn"], h, layer_c[key], cfg)
                        x = x + jnp.tanh(p["xgate"].astype(o.dtype)) * o
                        new_c[key] = layer_c[key]
                    else:
                        o, kv = L.attn_decode(p["attn"], h, layer_c[key], cfg,
                                              pos=pos)
                        x = x + o
                        new_c[key] = kv
                    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
                    if kind == "moe":
                        y, _ = L.moe_apply(p["moe"], h, cfg,
                                           group_size=cfg.moe_group_size,
                                           capacity_factor=cfg.capacity_factor)
                    else:
                        y = L.mlp_apply(p["mlp"], h)
                    x = x + y
                return x, new_c

            x, new_seg = jax.lax.scan(body, x, (seg, seg_cache))
            new_cache[f"seg{si}"] = new_seg
        logits = L.lm_logits(params["embed"], x, cfg)
        return logits, new_cache

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins + logical axes for every model input."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        specs, axes = {}, {}
        if shape.kind in ("train", "prefill"):
            if cfg.external_embeddings:
                specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
                axes["embeds"] = ("batch", "seq", None)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["tokens"] = ("batch", "seq")
            if cfg.family == "vlm":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_image_tokens, cfg.d_model), dt)
                axes["image_embeds"] = ("batch", None, None)
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["targets"] = ("batch", "seq")
        else:  # decode
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
            axes["tokens"] = ("batch", None)
            specs["pos"] = jax.ShapeDtypeStruct((), i32)
            axes["pos"] = None
        return specs, axes


def _cross_decode(p, x, xcache, cfg: ModelConfig):
    """Cross-attention for a single token against static image kv."""
    b, _, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(x.dtype)).reshape(
        b, 1, cfg.num_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = xcache["xk"], xcache["xv"]
    o = L.decode_attention(q, k, v, jnp.int32(k.shape[1]))
    o = o.reshape(b, 1, cfg.num_heads * hd)
    return jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(x.dtype))
