"""Declarative experiment engine: a Sweep (base Scenario + axes) expanded
into fingerprinted cells, executed with a resumable JSONL run store, and
reduced to reports by Study hooks (see spec.py / engine.py / runners.py;
the §VII decision-guideline study rides this in
benchmarks/fig10_decision_guide.py)."""
from repro.sweep.engine import (Engine, RunStore, Study, StudyRunStats,
                                fingerprint)
from repro.sweep.result import CellResult
from repro.sweep.runners import make_clients, run_scenario, wire_stats
from repro.sweep.spec import Axis, Cell, Sweep, SweepError

__all__ = ["Axis", "Sweep", "Cell", "SweepError", "CellResult",
           "Engine", "RunStore", "Study", "StudyRunStats", "fingerprint",
           "run_scenario", "make_clients", "wire_stats"]
