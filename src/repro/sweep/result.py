"""CellResult: the one record schema every study's cells emit.

Whatever a study measures — a p2p latency, a broadcast memory peak, a
full event-driven FL run — its cell lands in the same shape: identity
(study / cell name / spec fingerprint / axis values) plus the unified
wire-level block (simulated time, bytes on wire, per-stage charges,
retransmits, round reports) plus study-specific ``metrics``. The run
store (engine.RunStore) persists these as JSONL, and ``from_metrics``
canonicalises every value through JSON at creation time so a freshly-run
cell compares equal to its cached replay (tuples become lists, floats
survive exactly — important for the bit-for-bit trace comparisons the
fault studies make across cells).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

# metric keys run_cell may emit that are lifted into typed fields
_LIFTED = ("sim_time_s", "bytes_on_wire", "retransmits",
           "transfers_failed", "n_rounds", "stage_charges",
           "round_reports")


@dataclasses.dataclass(frozen=True)
class CellResult:
    """One executed (or cache-replayed) sweep cell."""
    study: str
    cell: str                     # human-readable cell name (row name)
    fingerprint: str              # spec fingerprint (engine.fingerprint)
    overrides: Dict[str, Any]     # scenario axis values (dotted field ->)
    params: Dict[str, Any]        # non-scenario axis values + constants
    # -- the unified wire-level block -----------------------------------
    sim_time_s: float = 0.0       # simulated span of the cell's run
    bytes_on_wire: float = 0.0    # fabric bytes actually transmitted
    retransmits: float = 0.0      # fault-model chunk retransmissions
    transfers_failed: float = 0.0  # bounded-retry give-ups
    n_rounds: int = 0             # rounds / aggregations completed
    stage_charges: Dict[str, float] = dataclasses.field(
        default_factory=dict)     # per-stage/state simulated seconds
    round_reports: List[Any] = dataclasses.field(default_factory=list)
    # -- study-specific extras ------------------------------------------
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_metrics(cls, study: str, cell: str, fingerprint: str,
                     overrides: Dict[str, Any], params: Dict[str, Any],
                     metrics: Dict[str, Any]) -> "CellResult":
        """Lift the reserved keys of a run_cell metrics dict into the
        typed fields; the rest is study-specific. Everything is pushed
        through one JSON round-trip so fresh == cached, always."""
        metrics = dict(metrics)
        lifted = {k: metrics.pop(k) for k in _LIFTED if k in metrics}
        rec = cls(study=study, cell=cell, fingerprint=fingerprint,
                  overrides=dict(overrides), params=dict(params),
                  metrics=metrics, **lifted)
        return cls.from_dict(json.loads(json.dumps(rec.to_dict())))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "CellResult":
        if not isinstance(data, dict):
            raise ValueError(
                f"CellResult: expected an object, got {type(data).__name__}")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - fields)
        if unknown:
            raise ValueError(f"CellResult: unknown key(s) {unknown}; "
                             f"valid keys: {sorted(fields)}")
        return cls(**data)

    def row(self) -> dict:
        """The benchmarks/run.py CSV row: name + every scalar metric."""
        out = {"name": self.cell}
        for k, v in self.metrics.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[k] = v
        return out

    def get(self, key: str, default: Any = None) -> Any:
        """Convenience lookup across the typed block and metrics."""
        if key in _LIFTED:
            return getattr(self, key)
        return self.metrics.get(key, default)
