"""``python -m repro.sweep sweep.json``: run a declarative sweep file.

Every cell goes through the generic scenario runner
(``repro.sweep.runners.run_scenario``) — the same ``build_runtime`` path
``fl_train --scenario`` takes — with completed cells replayed from the
resumable run store. Prints one summary row per cell and optionally
writes the full CellResult list as a JSON report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.scenario import ScenarioError
from repro.sweep.engine import Engine, Study
from repro.sweep.runners import run_scenario_cell
from repro.sweep.spec import Sweep


def run_sweep_file(path: str, *, out_dir: str = "benchmarks/out",
                   fresh: bool = False, verbose: bool = True,
                   report_path: str = None, workers: int = 0) -> list:
    """Load + expand + execute one sweep file; returns the CellResults."""
    sweep = Sweep.load(path)
    study = Study(name=sweep.name, sweeps=lambda quick: (sweep,),
                  cell=run_scenario_cell,  # module-level: --workers pickles it
                  title=f"ad-hoc sweep {sweep.name} ({path})")
    engine = Engine(out_dir)
    cells = sweep.expand()
    results = engine.run_cells(study, cells, fresh=fresh, verbose=verbose,
                               workers=workers)
    if verbose:
        print(f"{'cell':44s} {'sim_time_s':>11s} {'round_s':>9s} "
              f"{'wire_MB':>9s} {'retx':>5s}")
        for r in results:
            print(f"{r.cell:44s} {r.sim_time_s:11.2f} "
                  f"{r.metrics.get('round_s', 0.0):9.2f} "
                  f"{r.bytes_on_wire / 2**20:9.1f} {r.retransmits:5.0f}")
    if report_path:
        os.makedirs(os.path.dirname(os.path.abspath(report_path)),
                    exist_ok=True)
        with open(report_path, "w") as f:
            json.dump([r.to_dict() for r in results], f, indent=2)
        if verbose:
            print(f"[sweep] JSON report -> {report_path}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description="run a declarative Sweep file (base scenario + axes) "
                    "through the generic scenario runner")
    ap.add_argument("sweep", help="sweep JSON file (see "
                                  "examples/scenarios/*.json)")
    ap.add_argument("--out-dir", default="benchmarks/out",
                    help="run-store / report root (default benchmarks/out)")
    ap.add_argument("--report", default=None,
                    help="write the full CellResult list to this JSON file")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore the run store; re-run every cell")
    ap.add_argument("--workers", type=int, default=0,
                    help="run missing cells on N worker processes")
    args = ap.parse_args(argv)
    try:
        run_sweep_file(args.sweep, out_dir=args.out_dir, fresh=args.fresh,
                       report_path=args.report, workers=args.workers)
    except (ScenarioError, OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
