"""The sweep engine: expand axes, dedupe by spec fingerprint, run, store.

One ``Engine`` owns an output directory (``benchmarks/out`` for the
paper studies). ``run_study`` expands every ``Sweep`` a study declares,
fingerprints each cell (sha256 over the canonical JSON of
``(study, version, scenario, params)``), replays completed cells from
the study's JSONL run store (``<out>/runstore/<study>.jsonl``) and runs
only the missing ones — so an interrupted grid resumes where it stopped
and a re-run of an unchanged study touches zero cells. Results come back
as the unified ``CellResult`` records; the study's ``finalize`` hook
reduces them to its legacy JSON report + CSV rows (and runs its
assertions), and the engine — not the study — writes the report file.

A ``Study`` is what a refactored ``benchmarks/fig*.py`` module declares
instead of hand-rolled grid loops: sweeps (quick-aware), a per-cell
measurement, a cell namer, and the finalize/validate hook.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.sweep.result import CellResult
from repro.sweep.spec import Cell, Sweep


def fingerprint(study: str, version: int, cell: Cell) -> str:
    """Content address of one cell: the study identity + the *complete*
    cell spec (frozen scenario + params). Bumping ``Study.version``
    invalidates every cached cell of that study."""
    blob = json.dumps(
        {"study": study, "version": version,
         "scenario": cell.scenario.to_dict(), "params": cell.params},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class RunStore:
    """Append-only JSONL store of completed cells, keyed by fingerprint.

    One line per CellResult; loading tolerates a truncated final line
    (an interrupted run resumes from the last complete record)."""

    def __init__(self, path: str):
        self.path = path
        self._index: Dict[str, CellResult] = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = CellResult.from_dict(json.loads(line))
                    except (ValueError, TypeError, KeyError):
                        continue  # truncated / stale-schema line
                    self._index[rec.fingerprint] = rec

    def __len__(self) -> int:
        return len(self._index)

    def get(self, fp: str) -> Optional[CellResult]:
        return self._index.get(fp)

    def put(self, result: CellResult) -> None:
        """Append one record. Multiprocess-safe: the line is written in
        one O_APPEND write under an exclusive flock, so concurrent
        writers (parallel engines sharing a store, or a crashed worker's
        partial line) never interleave records — loading tolerates the
        one truncated tail a hard kill can still leave."""
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        line = json.dumps(result.to_dict(), separators=(",", ":")) + "\n"
        with open(self.path, "a") as f:
            try:
                import fcntl
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            except (ImportError, OSError):
                pass  # no flock here (non-POSIX): O_APPEND still holds
            f.write(line)
            f.flush()
        self._index[result.fingerprint] = result


def _default_finalize(results, quick, verbose):
    return None, [r.row() for r in results]


def _run_cell_task(cell_fn, study_name, cell_name, fp, cell) -> CellResult:
    """One worker-side cell execution (module-level so the spawn-context
    pool can pickle it; carries only the cell fn + the cell, never the
    whole Study — finalize hooks and closures stay in the parent)."""
    metrics = cell_fn(cell)
    return CellResult.from_metrics(study_name, cell_name, fp,
                                   cell.overrides, cell.params, metrics)


@dataclasses.dataclass
class Study:
    """One registered benchmark study: sweeps + cell runner + reducer."""
    name: str
    sweeps: Callable[[bool], Tuple[Sweep, ...]]  # quick -> sweeps
    cell: Callable[[Cell], Dict[str, Any]]       # one cell -> metrics
    cell_name: Optional[Callable[[Cell], str]] = None
    # (results, quick, verbose) -> (report dict | None, CSV rows);
    # runs the study's assertions
    finalize: Callable[..., Tuple[Optional[dict], List[dict]]] = \
        _default_finalize
    out: Optional[str] = None  # report JSON filename under the out dir
    title: str = ""
    version: int = 1           # bump to invalidate cached cells
    order: int = 100           # benchmarks/run.py ordering
    in_quick: bool = True      # part of the --quick CI gate

    def name_of(self, cell: Cell) -> str:
        if self.cell_name is not None:
            return self.cell_name(cell)
        return f"{self.name}/{cell.label()}"


@dataclasses.dataclass
class StudyRunStats:
    n_cells: int = 0
    n_cached: int = 0
    n_ran: int = 0


class Engine:
    """Executes studies (and ad-hoc sweeps) against one output dir."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.last_stats: Optional[StudyRunStats] = None

    # ------------------------------------------------------------------
    def store_path(self, study_name: str) -> str:
        return os.path.join(self.out_dir, "runstore", f"{study_name}.jsonl")

    def run_cells(self, study: Study, cells: List[Cell], *,
                  fresh: bool = False, verbose: bool = True,
                  workers: int = 0) -> List[CellResult]:
        """The dedupe/cache/execute core. Duplicate fingerprints inside
        one expansion run once; completed cells replay from the store.

        ``workers > 1`` executes the missing cells on a spawn-context
        process pool (spawn, not fork: the cells run JAX). The parent
        collects worker results *in submission order* and is the only
        store writer, so the store file is bit-for-bit identical to a
        serial run of the same grid — cells must be (and the studies
        are) deterministic, which ``--workers`` therefore preserves."""
        store = RunStore(self.store_path(study.name))
        stats = StudyRunStats(n_cells=len(cells))
        fps = [fingerprint(study.name, study.version, cell)
               for cell in cells]
        recs: Dict[str, CellResult] = {}
        todo: List[Tuple[str, Cell]] = []  # first-occurrence order
        todo_fps = set()
        for cell, fp in zip(cells, fps):
            if fp in recs or fp in todo_fps:
                continue
            rec = None if fresh else store.get(fp)
            if rec is not None:
                stats.n_cached += 1
                recs[fp] = rec
            else:
                todo.append((fp, cell))
                todo_fps.add(fp)
        if workers > 1 and len(todo) > 1:
            import multiprocessing as mp
            from concurrent.futures import ProcessPoolExecutor
            ctx = mp.get_context("spawn")
            with ProcessPoolExecutor(max_workers=min(workers, len(todo)),
                                     mp_context=ctx) as pool:
                futs = [pool.submit(_run_cell_task, study.cell, study.name,
                                    study.name_of(cell), fp, cell)
                        for fp, cell in todo]
                for (fp, _), fut in zip(todo, futs):
                    rec = fut.result()  # submission order == serial order
                    store.put(rec)
                    recs[fp] = rec
                    stats.n_ran += 1
        else:
            for fp, cell in todo:
                rec = _run_cell_task(study.cell, study.name,
                                     study.name_of(cell), fp, cell)
                store.put(rec)
                recs[fp] = rec
                stats.n_ran += 1
        results = [recs[fp] for fp in fps]
        self.last_stats = stats
        if verbose:
            par = f", {workers} workers" if workers > 1 else ""
            print(f"[{study.name}] {stats.n_cells} cells: {stats.n_ran} "
                  f"run, {stats.n_cached} cached{par} "
                  f"(store: {os.path.relpath(store.path)})")
        return results

    def run_study(self, study: Study, *, quick: bool = False,
                  verbose: bool = True, fresh: bool = False,
                  workers: int = 0) -> List[dict]:
        """Expand -> run/replay -> finalize -> write the report JSON.
        Returns the CSV rows benchmarks/run.py prints."""
        cells = [c for sw in study.sweeps(quick) for c in sw.expand()]
        results = self.run_cells(study, cells, fresh=fresh, verbose=verbose,
                                 workers=workers)
        report, rows = study.finalize(results, quick, verbose)
        if report is not None and study.out:
            path = os.path.join(self.out_dir, study.out)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                json.dump(report, f, indent=2)
            if verbose:
                print(f"[{study.name}] JSON report -> {path}")
        return rows

    # ------------------------------------------------------------------
    def runner(self, study: Study) -> Callable[..., List[dict]]:
        """The legacy ``run(verbose=True, quick=False)`` module surface
        (+ ``fresh=`` so run.py --fresh invalidates per study, not by
        deleting the whole run store)."""
        def run(verbose: bool = True, quick: bool = False,
                fresh: bool = False, workers: int = 0) -> List[dict]:
            return self.run_study(study, quick=quick, verbose=verbose,
                                  fresh=fresh, workers=workers)
        run.__doc__ = study.title or study.name
        return run

    def main(self, study: Study, argv=None) -> None:
        """``python -m benchmarks.figX [--quick] [--fresh] [--workers N]``."""
        ap = argparse.ArgumentParser(description=study.title or study.name)
        ap.add_argument("--quick", action="store_true",
                        help="reduced grid (the CI smoke)")
        ap.add_argument("--fresh", action="store_true",
                        help="ignore the run store; re-run every cell")
        ap.add_argument("--workers", type=int, default=0,
                        help="run missing cells on N worker processes")
        args = ap.parse_args(argv)
        self.run_study(study, quick=args.quick, fresh=args.fresh,
                       workers=args.workers)
