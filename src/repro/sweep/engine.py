"""The sweep engine: expand axes, dedupe by spec fingerprint, run, store.

One ``Engine`` owns an output directory (``benchmarks/out`` for the
paper studies). ``run_study`` expands every ``Sweep`` a study declares,
fingerprints each cell (sha256 over the canonical JSON of
``(study, version, scenario, params)``), replays completed cells from
the study's JSONL run store (``<out>/runstore/<study>.jsonl``) and runs
only the missing ones — so an interrupted grid resumes where it stopped
and a re-run of an unchanged study touches zero cells. Results come back
as the unified ``CellResult`` records; the study's ``finalize`` hook
reduces them to its legacy JSON report + CSV rows (and runs its
assertions), and the engine — not the study — writes the report file.

A ``Study`` is what a refactored ``benchmarks/fig*.py`` module declares
instead of hand-rolled grid loops: sweeps (quick-aware), a per-cell
measurement, a cell namer, and the finalize/validate hook.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.sweep.result import CellResult
from repro.sweep.spec import Cell, Sweep


def fingerprint(study: str, version: int, cell: Cell) -> str:
    """Content address of one cell: the study identity + the *complete*
    cell spec (frozen scenario + params). Bumping ``Study.version``
    invalidates every cached cell of that study."""
    blob = json.dumps(
        {"study": study, "version": version,
         "scenario": cell.scenario.to_dict(), "params": cell.params},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class RunStore:
    """Append-only JSONL store of completed cells, keyed by fingerprint.

    One line per CellResult; loading tolerates a truncated final line
    (an interrupted run resumes from the last complete record)."""

    def __init__(self, path: str):
        self.path = path
        self._index: Dict[str, CellResult] = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = CellResult.from_dict(json.loads(line))
                    except (ValueError, TypeError, KeyError):
                        continue  # truncated / stale-schema line
                    self._index[rec.fingerprint] = rec

    def __len__(self) -> int:
        return len(self._index)

    def get(self, fp: str) -> Optional[CellResult]:
        return self._index.get(fp)

    def put(self, result: CellResult) -> None:
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(result.to_dict(),
                               separators=(",", ":")) + "\n")
        self._index[result.fingerprint] = result


def _default_finalize(results, quick, verbose):
    return None, [r.row() for r in results]


@dataclasses.dataclass
class Study:
    """One registered benchmark study: sweeps + cell runner + reducer."""
    name: str
    sweeps: Callable[[bool], Tuple[Sweep, ...]]  # quick -> sweeps
    cell: Callable[[Cell], Dict[str, Any]]       # one cell -> metrics
    cell_name: Optional[Callable[[Cell], str]] = None
    # (results, quick, verbose) -> (report dict | None, CSV rows);
    # runs the study's assertions
    finalize: Callable[..., Tuple[Optional[dict], List[dict]]] = \
        _default_finalize
    out: Optional[str] = None  # report JSON filename under the out dir
    title: str = ""
    version: int = 1           # bump to invalidate cached cells
    order: int = 100           # benchmarks/run.py ordering
    in_quick: bool = True      # part of the --quick CI gate

    def name_of(self, cell: Cell) -> str:
        if self.cell_name is not None:
            return self.cell_name(cell)
        return f"{self.name}/{cell.label()}"


@dataclasses.dataclass
class StudyRunStats:
    n_cells: int = 0
    n_cached: int = 0
    n_ran: int = 0


class Engine:
    """Executes studies (and ad-hoc sweeps) against one output dir."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.last_stats: Optional[StudyRunStats] = None

    # ------------------------------------------------------------------
    def store_path(self, study_name: str) -> str:
        return os.path.join(self.out_dir, "runstore", f"{study_name}.jsonl")

    def run_cells(self, study: Study, cells: List[Cell], *,
                  fresh: bool = False, verbose: bool = True,
                  ) -> List[CellResult]:
        """The dedupe/cache/execute core. Duplicate fingerprints inside
        one expansion run once; completed cells replay from the store."""
        store = RunStore(self.store_path(study.name))
        stats = StudyRunStats(n_cells=len(cells))
        results: List[CellResult] = []
        seen_this_run: Dict[str, CellResult] = {}
        for cell in cells:
            fp = fingerprint(study.name, study.version, cell)
            rec = seen_this_run.get(fp)
            if rec is None and not fresh:
                rec = store.get(fp)
                if rec is not None:
                    stats.n_cached += 1
            if rec is None:
                metrics = study.cell(cell)
                rec = CellResult.from_metrics(
                    study.name, study.name_of(cell), fp,
                    cell.overrides, cell.params, metrics)
                store.put(rec)
                stats.n_ran += 1
            seen_this_run[fp] = rec
            results.append(rec)
        self.last_stats = stats
        if verbose:
            print(f"[{study.name}] {stats.n_cells} cells: {stats.n_ran} "
                  f"run, {stats.n_cached} cached "
                  f"(store: {os.path.relpath(store.path)})")
        return results

    def run_study(self, study: Study, *, quick: bool = False,
                  verbose: bool = True, fresh: bool = False) -> List[dict]:
        """Expand -> run/replay -> finalize -> write the report JSON.
        Returns the CSV rows benchmarks/run.py prints."""
        cells = [c for sw in study.sweeps(quick) for c in sw.expand()]
        results = self.run_cells(study, cells, fresh=fresh, verbose=verbose)
        report, rows = study.finalize(results, quick, verbose)
        if report is not None and study.out:
            path = os.path.join(self.out_dir, study.out)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                json.dump(report, f, indent=2)
            if verbose:
                print(f"[{study.name}] JSON report -> {path}")
        return rows

    # ------------------------------------------------------------------
    def runner(self, study: Study) -> Callable[..., List[dict]]:
        """The legacy ``run(verbose=True, quick=False)`` module surface
        (+ ``fresh=`` so run.py --fresh invalidates per study, not by
        deleting the whole run store)."""
        def run(verbose: bool = True, quick: bool = False,
                fresh: bool = False) -> List[dict]:
            return self.run_study(study, quick=quick, verbose=verbose,
                                  fresh=fresh)
        run.__doc__ = study.title or study.name
        return run

    def main(self, study: Study, argv=None) -> None:
        """``python -m benchmarks.figX [--quick] [--fresh]``."""
        ap = argparse.ArgumentParser(description=study.title or study.name)
        ap.add_argument("--quick", action="store_true",
                        help="reduced grid (the CI smoke)")
        ap.add_argument("--fresh", action="store_true",
                        help="ignore the run store; re-run every cell")
        args = ap.parse_args(argv)
        self.run_study(study, quick=args.quick, fresh=args.fresh)
