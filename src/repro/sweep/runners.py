"""Generic cell runner: one Scenario -> unified metrics, via build_runtime.

The studies under ``benchmarks/`` measure figure-specific quantities and
keep their own ``run_cell``; this module is the *generic* measurement the
sweep CLI (``fl_train --sweep`` / ``python -m repro.sweep``) applies to
every cell: build the scenario's runtime, run its strategy mode end to
end with tier-calibrated simulated training and tier-sized virtual
payloads, and report the unified CellResult block — simulated time,
bytes on the wire, per-stage/state charges, retransmits, round records.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.configs.paper_tiers import TIERS
from repro.core.message import VirtualPayload
from repro.fl.client import FLClient
from repro.fl.scheduler import FLScheduler
from repro.fl.server import FLServer
from repro.scenario import MultiScenario, Runtime, Scenario, build_runtime


def wire_stats(fabric, store=None, job: str = "") -> Dict[str, float]:
    """The fabric's wire-level accounting in CellResult's field names.
    ``job`` selects one tenant's namespaced counters ("" = the global
    view, which every per-job view sums to)."""
    stats = fabric.stats if not job else fabric.stats_for(job)
    out = {"bytes_on_wire": float(stats["bytes"]),
           "retransmits": float(stats["retransmits"]),
           "transfers_failed": float(stats["transfers_failed"]),
           "n_cross_job_hits": float(stats["cross_job_hits"])}
    if store is not None:
        out["s3_retries"] = float(store.stats["retries"])
    return out


def make_clients(rt: Runtime, *, train_s: Optional[float] = None,
                 compression: Optional[str] = None):
    """Tier-calibrated simulated clients over the runtime's backends."""
    tier = TIERS[rt.scenario.fleet.tier]
    if train_s is None:
        train_s = rt.scenario.fleet.train_s \
            or tier.train_s(rt.scenario.topology.kind)
    return [FLClient(h.host_id, rt.make_backend(h.host_id,
                                                compression=compression),
                     sim_train_s=train_s)
            for h in rt.env.clients]


def run_scenario(scenario: Scenario, *,
                 rounds: Optional[int] = None) -> Dict[str, Any]:
    """Run one cell's scenario end to end.

    ``strategy.mode`` picks the loop: ``sync`` runs lockstep
    ``FLServer.run_round`` rounds; the event-driven modes run the
    scheduler under ``make_strategy`` with ``rounds`` aggregations."""
    scenario.validate()
    rt = build_runtime(scenario)
    tier = TIERS[scenario.fleet.tier]
    rounds = scenario.strategy.rounds if rounds is None else rounds
    mode = scenario.strategy.mode

    if mode == "sync":
        clients = make_clients(rt)
        server = FLServer(rt.make_backend("server", compression="none"),
                          clients,
                          quorum_fraction=scenario.strategy.quorum_fraction,
                          round_deadline_s=scenario.strategy.round_deadline_s,
                          local_steps=scenario.fleet.local_steps,
                          live=False)
        reports = []
        for r in range(rounds):
            rep = server.run_round(VirtualPayload(tier.payload_bytes,
                                                  tag=f"sweep-r{r}"))
            reports.append({"round": rep.round,
                            "round_time": rep.round_time,
                            "server": rep.server, "clients": rep.clients,
                            "n_participants": rep.n_participants,
                            "aborted": rep.aborted})
        charges: Dict[str, float] = {}
        for rep in reports:
            for side, states in (("server", rep["server"]),
                                 ("client", rep["clients"])):
                for k, v in states.items():
                    charges[f"{side}.{k}"] = charges.get(f"{side}.{k}", 0.0) \
                        + float(v)
        return {"sim_time_s": server.now, "n_rounds": rounds,
                "round_s": server.now / max(rounds, 1),
                "stage_charges": charges, "round_reports": reports,
                **wire_stats(rt.fabric, rt.store)}

    from repro.fl import make_strategy
    from repro.fl.fault import make_availability
    # the payload codec rides the client update path for the buffered
    # modes; hier compresses its relay WAN hop inside the strategy; the
    # vertical mode compresses BOTH directions — activations up on the
    # clients' channels, activation gradients down on the server's
    strategy_kw: Dict[str, Any] = {}
    if mode == "vertical":
        from repro.fl.vertical import (SIM_BATCH_SIZE, TIER_DEPTH,
                                       bottom_fraction,
                                       sim_activation_nbytes)
        client_comp = scenario.split.activation_codec
        server_comp = scenario.split.activation_codec
        train_s = scenario.fleet.train_s \
            or tier.train_s(scenario.topology.kind)
        depth = TIER_DEPTH.get(scenario.fleet.tier, 8)
        strategy_kw = dict(
            activation_nbytes=sim_activation_nbytes(
                tier.payload_bytes, SIM_BATCH_SIZE,
                scenario.split.cut_layer),
            train_s=train_s,
            bottom_frac=bottom_fraction(scenario.split.cut_layer, depth))
    else:
        client_comp = (scenario.channel.compression
                       if mode in ("fedbuff", "semisync") else "none")
        server_comp = "none"
    clients = make_clients(rt, compression=client_comp)
    strategy = make_strategy(scenario.fl_config(),
                             scenario.topology.num_clients, **strategy_kw)
    availability = make_availability(
        scenario.faults.availability_trace,
        [c.client_id for c in clients],
        horizon_s=scenario.faults.trace_horizon_s, seed=scenario.seed)
    sched = FLScheduler(rt.make_backend("server", compression=server_comp),
                        clients, strategy,
                        local_steps=scenario.fleet.local_steps,
                        availability=availability,
                        cohort_k=scenario.fleet.cohort_k,
                        cohort_seed=scenario.seed,
                        streaming_hub=scenario.strategy.streaming_hub)
    # vertical rounds update parties in place: the "global payload" is an
    # activation-sized bookkeeping record, not a model broadcast
    payload = (VirtualPayload(strategy.activation_nbytes,
                              tag="sweep-vertical")
               if mode == "vertical"
               else VirtualPayload(tier.payload_bytes, tag="sweep"))
    rep = sched.run(payload, max_aggregations=rounds)
    reports = [{"version": e.version, "time": e.time,
                "n_updates": e.n_updates,
                "mean_staleness": e.mean_staleness}
               for e in sched.agg_log]
    out = {"sim_time_s": rep.sim_time, "n_rounds": rep.n_aggregations,
           "round_s": rep.sim_time / max(rep.n_aggregations, 1),
           "aggregations_per_hour": rep.aggregations_per_hour,
           "updates_per_hour": rep.client_updates_per_hour,
           "n_client_updates": rep.n_client_updates,
           "mean_staleness": rep.mean_staleness,
           "n_departures": rep.n_departures,
           "n_rejoins": rep.n_rejoins,
           "n_discarded": rep.n_discarded,
           "round_reports": reports,
           **wire_stats(rt.fabric, rt.store)}
    if scenario.channel.backend == "auto":
        # per-message routing decisions (msg_type -> backend counts), so
        # studies can assert AUTO routes activation traffic by size
        decisions: Dict[str, int] = {}
        for be in [sched.backend] + [c.backend for c in clients]:
            for (mt, _nb, name) in getattr(be, "decisions", []):
                key = f"{mt}:{name}"
                decisions[key] = decisions.get(key, 0) + 1
        out["auto_decisions"] = decisions
    return out


def run_scenario_cell(cell) -> Dict[str, Any]:
    """``Study.cell`` adapter over ``run_scenario`` — module-level so a
    ``--workers`` process pool can pickle the ad-hoc sweep-file study."""
    return run_scenario(cell.scenario)


def run_multi(mspec: MultiScenario, *,
              runtime_out: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Co-schedule every job of a MultiScenario on one shared deployment.

    One topology (jobs[0]'s — validation pins every job to it), ONE
    fabric carrying ``mspec.fabric`` (admission policy + shared links),
    one EventLoop clock. Each job gets its own tenant namespace
    (``fabric.job``), its own tier-calibrated clients and its own
    FLScheduler; all jobs share ONE object store (content-addressed
    dedup works across tenants); tenants otherwise interact only
    through the contended links. The fault model is jobs[0]'s (one physical network
    has one weather system). Returns per-job report blocks plus the
    global wire totals the per-job views sum to. ``runtime_out``, if
    given, is filled with the live fabric + store so callers (the fig12
    admission gates) can inspect granted pipe segments post-run."""
    from repro.core.backends import make_backend
    from repro.core.netsim import NCAL
    from repro.core.objectstore import ObjectStore
    from repro.core.transport import Fabric
    from repro.fl import make_strategy
    from repro.fl.fault import make_availability
    from repro.fl.multijob import MultiScheduler
    from repro.fl.scheduler import EventLoop
    from repro.scenario import fault_model_for

    mspec.validate()
    base = mspec.jobs[0].scenario
    env = base.topology.build()
    fabric = Fabric(env, fault_model=fault_model_for(base),
                    spec=mspec.fabric)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)

    loop = EventLoop()
    multi = MultiScheduler(loop)
    # ONE bucket for the whole deployment: the content-addressed cache is
    # keyed job-blind, so tenants shipping the same wire dedup each
    # other's PUTs (counted as cross_job_hits in the hitter's stats)
    shared_store = ObjectStore(NCAL, fail_rate=base.faults.store_fail_rate)
    stores: Dict[str, ObjectStore] = {}
    for js in mspec.jobs:
        sc = js.scenario
        handle = fabric.job(js.name, priority=js.priority,
                            weight=js.weight)
        store = stores[js.name] = shared_store
        tier = TIERS[sc.fleet.tier]
        ch = sc.channel

        def mk(host_id, compression, *, _sc=sc, _store=store, _h=handle):
            c = _sc.channel
            return make_backend(
                c.backend, env, fabric, host_id, store=_store,
                compression=None if compression in ("", "none")
                else compression,
                wire_codec=c.wire_codec, chunk_mb=c.chunk_mb, job=_h)

        client_comp = ch.compression  # fedbuff/semisync update path
        train_s = sc.fleet.train_s or tier.train_s(sc.topology.kind)
        clients = [FLClient(h.host_id, mk(h.host_id, client_comp),
                            sim_train_s=train_s)
                   for h in env.clients]
        strategy = make_strategy(sc.fl_config(), sc.topology.num_clients)
        availability = make_availability(
            sc.faults.availability_trace, [c.client_id for c in clients],
            horizon_s=sc.faults.trace_horizon_s, seed=sc.seed)
        sched = FLScheduler(mk("server", "none"), clients, strategy,
                            local_steps=sc.fleet.local_steps,
                            availability=availability,
                            cohort_k=sc.fleet.cohort_k,
                            cohort_seed=sc.seed,
                            streaming_hub=sc.strategy.streaming_hub,
                            loop=loop)
        multi.add_job(js.name, sched,
                      VirtualPayload(tier.payload_bytes,
                                     tag=f"multi-{js.name}"),
                      max_aggregations=js.cap(), start_s=js.start_s)

    reports = multi.run()
    if runtime_out is not None:
        runtime_out["fabric"] = fabric
        runtime_out["store"] = shared_store
    jobs_out: Dict[str, Any] = {}
    for name, rep in reports.items():
        jobs_out[name] = {
            "sim_time_s": rep.sim_time, "n_rounds": rep.n_aggregations,
            "round_s": rep.sim_time / max(rep.n_aggregations, 1),
            "n_client_updates": rep.n_client_updates,
            "mean_staleness": rep.mean_staleness,
            **wire_stats(fabric, stores[name], job=name)}
    return {"name": mspec.name,
            "policy": mspec.fabric.policy,
            "shared_links": mspec.fabric.shared_links,
            "jobs": jobs_out,
            **wire_stats(fabric)}
