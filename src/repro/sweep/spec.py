"""Sweep spec: a base Scenario plus axes over its dotted fields.

The paper's deliverable is not any single figure but §VII's *decision
guidelines* — which backend/topology/compression to pick for a given FL
task and network. Answering that takes systematic sweeps, and before this
layer every ``benchmarks/fig*.py`` hand-rolled its own nested grid loop.
A ``Sweep`` is the declarative replacement:

* ``Axis``  — one swept dimension. ``field`` is a dotted ``Scenario``
  path (``channel.backend``, ``faults.link_loss``, ``fleet.tier``) or a
  study parameter (``params.channels``) that does not live in the spec.
  Discrete axes list ``values``; continuous axes give ``lo``/``hi`` (+
  ``steps`` for a grid linspace).
* ``Sweep`` — base scenario + axes. With ``samples == 0`` the axes cross
  into a full grid (declaration order = nesting order, so cell order is
  reproducible); with ``samples > 0`` each cell draws one value per axis
  from a stream seeded by ``(seed, cell index, axis field)`` — seeded
  random search, deterministic and independent of axis evaluation order.

``Sweep.to_dict`` / ``from_dict`` round-trip exactly (including through
JSON), with unknown keys rejected on a readable path, so a sweep file is
as declarative as a scenario file (``fl_train --sweep file.json``).
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Tuple

from repro.scenario import Scenario, ScenarioError, with_overrides

PARAM_PREFIX = "params."


class SweepError(ScenarioError):
    """Invalid sweep spec — the message carries the offending path."""


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept dimension: discrete ``values`` or a ``lo``/``hi`` range.

    ``sub`` makes the axis *conditional*: per-value sub-grids, keyed by
    ``str(value)``. When the grid expansion assigns a value whose key
    appears in ``sub``, that value's axes are crossed in (recursively)
    for those cells only — the declarative form of "chunking only
    applies on the gRPC branch" couplings that otherwise hide inside a
    study's ``_cell`` function. Sub-axes exist in grid sweeps only
    (random search draws axes independently, which a value-conditioned
    sub-grid contradicts)."""
    field: str
    values: Tuple[Any, ...] = ()
    lo: float = 0.0
    hi: float = 0.0
    steps: int = 0  # grid mode: linspace(lo, hi, steps) for a range axis
    # str(value) -> axes crossed in only under that value
    sub: Dict[str, Tuple["Axis", ...]] = dataclasses.field(
        default_factory=dict)

    @property
    def is_range(self) -> bool:
        return not self.values

    def check(self, path: str, seen=()) -> None:
        if not self.field:
            raise SweepError(f"{path}: axis field must be non-empty")
        if self.field in seen:
            raise SweepError(f"{path}: duplicate axis field "
                             f"'{self.field}' on this branch")
        if not self.field.startswith(PARAM_PREFIX):
            _check_scenario_path(self.field, path)
        if self.sub and not self.values:
            raise SweepError(f"{path}: sub-axes need discrete values "
                             f"(a range axis has no value keys)")
        if self.values:
            if any(v is None for v in self.values):
                raise SweepError(f"{path}: axis values must not be None "
                                 f"(None means 'unset' in overrides)")
            if self.lo or self.hi or self.steps:
                raise SweepError(f"{path}: give either values or a "
                                 f"lo/hi range, not both")
            self._check_sub(path, seen)
            return
        if not self.hi > self.lo:
            raise SweepError(f"{path}: range axis needs hi > lo "
                             f"(got lo={self.lo}, hi={self.hi})")

    def _check_sub(self, path: str, seen) -> None:
        keys = {str(v) for v in self.values}
        branch_seen = set(seen) | {self.field}
        for key, axes in self.sub.items():
            if key not in keys:
                raise SweepError(
                    f"{path}.sub['{key}']: no axis value str()s to "
                    f"'{key}' (values: {sorted(keys)})")
            if not isinstance(axes, tuple):
                raise SweepError(f"{path}.sub['{key}']: expected a tuple "
                                 f"of axes")
            # each sub value opens its own branch: a field may repeat
            # across branches but not along one
            sub_seen = set(branch_seen)
            for j, ax in enumerate(axes):
                ax.check(f"{path}.sub['{key}'][{j}]", tuple(sub_seen))
                sub_seen.add(ax.field)

    def grid_values(self, path: str) -> Tuple[Any, ...]:
        if self.values:
            return self.values
        if self.steps < 2:
            raise SweepError(
                f"{path}: a range axis in a grid sweep needs steps >= 2 "
                f"(or set samples > 0 for random search)")
        span = self.hi - self.lo
        return tuple(self.lo + span * i / (self.steps - 1)
                     for i in range(self.steps))

    def draw(self, rng: random.Random) -> Any:
        if self.values:
            return self.values[rng.randrange(len(self.values))]
        return rng.uniform(self.lo, self.hi)

    def grid_values(self, path: str) -> Tuple[Any, ...]:
        if self.values:
            return self.values
        if self.steps < 2:
            raise SweepError(
                f"{path}: a range axis in a grid sweep needs steps >= 2 "
                f"(or set samples > 0 for random search)")
        span = self.hi - self.lo
        return tuple(self.lo + span * i / (self.steps - 1)
                     for i in range(self.steps))

    def draw(self, rng: random.Random) -> Any:
        if self.values:
            return self.values[rng.randrange(len(self.values))]
        return rng.uniform(self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One concrete point of an expanded sweep: a frozen scenario plus
    the non-scenario study parameters that complete its identity."""
    index: int
    scenario: Scenario
    overrides: Dict[str, Any]  # dotted scenario field -> value
    params: Dict[str, Any]     # params.* axis values + sweep constants

    def label(self) -> str:
        parts = [f"{k.split('.')[-1]}={v}" for k, v in
                 list(self.overrides.items()) + list(self.params.items())]
        return ",".join(parts) or f"cell{self.index}"


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A base scenario + axes; grid (samples == 0) or seeded random
    search (samples > 0). ``params`` are constants merged into every
    cell's ``params`` dict (study knobs that are not swept)."""
    name: str = "sweep"
    base: Scenario = Scenario()
    axes: Tuple[Axis, ...] = ()
    samples: int = 0
    seed: int = 0
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def check(self) -> None:
        seen = set()
        for i, ax in enumerate(self.axes):
            path = f"sweep.axes[{i}]"
            ax.check(path, tuple(seen))
            seen.add(ax.field)
        if self.samples < 0:
            raise SweepError("sweep.samples must be >= 0")
        if self.samples > 0 and any(_has_sub(ax) for ax in self.axes):
            raise SweepError(
                "sweep: conditional sub-axes require a grid sweep "
                "(samples == 0); random search draws axes independently")

    # -- expansion ---------------------------------------------------------
    def expand(self) -> List[Cell]:
        """Axes -> concrete cells. Grid: cross-product in declaration
        order, with each axis value's conditional ``sub`` axes crossed
        in (recursively) under that value only. Random: ``samples``
        cells, each axis drawn from its own ``(seed, index,
        field)``-seeded stream."""
        self.check()
        if self.samples > 0:
            assignments = [
                [(ax.field,
                  ax.draw(random.Random(f"{self.seed}:{i}:{ax.field}")))
                 for ax in self.axes]
                for i in range(self.samples)]
        else:
            assignments = _grid_assignments(self.axes)
        cells = []
        for i, assign in enumerate(assignments):
            overrides = {f: v for f, v in assign
                         if not f.startswith(PARAM_PREFIX)}
            params = dict(self.params)
            params.update({f[len(PARAM_PREFIX):]: v for f, v in assign
                           if f.startswith(PARAM_PREFIX)})
            sc = with_overrides(self.base, overrides) if overrides \
                else self.base
            cells.append(Cell(index=i, scenario=sc, overrides=overrides,
                              params=params))
        return cells

    # -- (de)serialisation -------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["base"] = self.base.to_dict()
        return d

    @classmethod
    def from_dict(cls, data: dict) -> "Sweep":
        if not isinstance(data, dict):
            raise SweepError(
                f"sweep: expected an object, got {type(data).__name__}")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - fields)
        if unknown:
            raise SweepError(f"sweep: unknown key(s) {unknown}; valid "
                             f"keys: {sorted(fields)}")
        kw: dict = {}
        for k, v in data.items():
            if k == "base":
                kw[k] = Scenario.from_dict(v)
            elif k == "axes":
                if not isinstance(v, (list, tuple)):
                    raise SweepError("sweep.axes: expected a list")
                kw[k] = tuple(_axis_from_dict(a, f"sweep.axes[{i}]")
                              for i, a in enumerate(v))
            elif k == "params":
                if not isinstance(v, dict):
                    raise SweepError("sweep.params: expected an object")
                kw[k] = dict(v)
            else:
                kw[k] = v
        try:
            sweep = cls(**kw)
        except TypeError as e:
            raise SweepError(f"sweep: {e}") from None
        sweep.check()
        return sweep

    def to_json(self, **kwargs) -> str:
        kwargs.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kwargs)

    @classmethod
    def from_json(cls, text: str) -> "Sweep":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "Sweep":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _has_sub(ax: Axis) -> bool:
    return bool(ax.sub)


def _grid_assignments(axes) -> List[list]:
    """Cross the axes into [(field, value)] assignment lists, declaration
    order = nesting order; a value's ``sub`` axes nest directly under it
    (so cells of one branch stay contiguous and cell order stays
    reproducible)."""
    assignments: List[list] = [[]]
    for ax in axes:
        vals = ax.grid_values(f"sweep.axes[{ax.field}]")
        nxt: List[list] = []
        for prefix in assignments:
            for v in vals:
                branch = prefix + [(ax.field, v)]
                sub_axes = ax.sub.get(str(v), ())
                if sub_axes:
                    nxt.extend(branch + tail
                               for tail in _grid_assignments(sub_axes))
                else:
                    nxt.append(branch)
        assignments = nxt
    return assignments


def _axis_from_dict(data: dict, path: str) -> Axis:
    if not isinstance(data, dict):
        raise SweepError(f"{path}: expected an object")
    fields = {f.name for f in dataclasses.fields(Axis)}
    unknown = sorted(set(data) - fields)
    if unknown:
        raise SweepError(f"{path}: unknown key(s) {unknown}; valid keys: "
                         f"{sorted(fields)}")
    kw = {k: (tuple(v) if isinstance(v, list) else v)
          for k, v in data.items()}
    sub = kw.get("sub")
    if sub is not None:
        if not isinstance(sub, dict):
            raise SweepError(f"{path}.sub: expected an object mapping "
                             f"str(value) -> list of axes")
        parsed = {}
        for key, axes in sub.items():
            if not isinstance(axes, (list, tuple)):
                raise SweepError(f"{path}.sub['{key}']: expected a list "
                                 f"of axes")
            parsed[key] = tuple(
                _axis_from_dict(a, f"{path}.sub['{key}'][{j}]")
                for j, a in enumerate(axes))
        kw["sub"] = parsed
    try:
        return Axis(**kw)
    except TypeError as e:
        raise SweepError(f"{path}: {e}") from None


def _check_scenario_path(field: str, path: str) -> None:
    """A dotted axis field must name a real Scenario field (typos fail at
    declaration, not mid-run)."""
    node: Any = Scenario()
    parts = field.split(".")
    for i, part in enumerate(parts):
        if not dataclasses.is_dataclass(node):
            raise SweepError(f"{path}: '{field}' descends past the leaf "
                             f"field '{parts[i - 1]}'")
        if not any(f.name == part for f in dataclasses.fields(node)):
            raise SweepError(
                f"{path}: '{field}' is not a Scenario field (no "
                f"'{part}' on {type(node).__name__}; use the 'params.' "
                f"prefix for study parameters)")
        node = getattr(node, part)
