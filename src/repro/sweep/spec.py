"""Sweep spec: a base Scenario plus axes over its dotted fields.

The paper's deliverable is not any single figure but §VII's *decision
guidelines* — which backend/topology/compression to pick for a given FL
task and network. Answering that takes systematic sweeps, and before this
layer every ``benchmarks/fig*.py`` hand-rolled its own nested grid loop.
A ``Sweep`` is the declarative replacement:

* ``Axis``  — one swept dimension. ``field`` is a dotted ``Scenario``
  path (``channel.backend``, ``faults.link_loss``, ``fleet.tier``) or a
  study parameter (``params.channels``) that does not live in the spec.
  Discrete axes list ``values``; continuous axes give ``lo``/``hi`` (+
  ``steps`` for a grid linspace).
* ``Sweep`` — base scenario + axes. With ``samples == 0`` the axes cross
  into a full grid (declaration order = nesting order, so cell order is
  reproducible); with ``samples > 0`` each cell draws one value per axis
  from a stream seeded by ``(seed, cell index, axis field)`` — seeded
  random search, deterministic and independent of axis evaluation order.

``Sweep.to_dict`` / ``from_dict`` round-trip exactly (including through
JSON), with unknown keys rejected on a readable path, so a sweep file is
as declarative as a scenario file (``fl_train --sweep file.json``).
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Tuple

from repro.scenario import Scenario, ScenarioError, with_overrides

PARAM_PREFIX = "params."


class SweepError(ScenarioError):
    """Invalid sweep spec — the message carries the offending path."""


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept dimension: discrete ``values`` or a ``lo``/``hi`` range."""
    field: str
    values: Tuple[Any, ...] = ()
    lo: float = 0.0
    hi: float = 0.0
    steps: int = 0  # grid mode: linspace(lo, hi, steps) for a range axis

    @property
    def is_range(self) -> bool:
        return not self.values

    def check(self, path: str) -> None:
        if not self.field:
            raise SweepError(f"{path}: axis field must be non-empty")
        if not self.field.startswith(PARAM_PREFIX):
            _check_scenario_path(self.field, path)
        if self.values:
            if any(v is None for v in self.values):
                raise SweepError(f"{path}: axis values must not be None "
                                 f"(None means 'unset' in overrides)")
            if self.lo or self.hi or self.steps:
                raise SweepError(f"{path}: give either values or a "
                                 f"lo/hi range, not both")
            return
        if not self.hi > self.lo:
            raise SweepError(f"{path}: range axis needs hi > lo "
                             f"(got lo={self.lo}, hi={self.hi})")

    def grid_values(self, path: str) -> Tuple[Any, ...]:
        if self.values:
            return self.values
        if self.steps < 2:
            raise SweepError(
                f"{path}: a range axis in a grid sweep needs steps >= 2 "
                f"(or set samples > 0 for random search)")
        span = self.hi - self.lo
        return tuple(self.lo + span * i / (self.steps - 1)
                     for i in range(self.steps))

    def draw(self, rng: random.Random) -> Any:
        if self.values:
            return self.values[rng.randrange(len(self.values))]
        return rng.uniform(self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One concrete point of an expanded sweep: a frozen scenario plus
    the non-scenario study parameters that complete its identity."""
    index: int
    scenario: Scenario
    overrides: Dict[str, Any]  # dotted scenario field -> value
    params: Dict[str, Any]     # params.* axis values + sweep constants

    def label(self) -> str:
        parts = [f"{k.split('.')[-1]}={v}" for k, v in
                 list(self.overrides.items()) + list(self.params.items())]
        return ",".join(parts) or f"cell{self.index}"


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A base scenario + axes; grid (samples == 0) or seeded random
    search (samples > 0). ``params`` are constants merged into every
    cell's ``params`` dict (study knobs that are not swept)."""
    name: str = "sweep"
    base: Scenario = Scenario()
    axes: Tuple[Axis, ...] = ()
    samples: int = 0
    seed: int = 0
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def check(self) -> None:
        seen = set()
        for i, ax in enumerate(self.axes):
            path = f"sweep.axes[{i}]"
            ax.check(path)
            if ax.field in seen:
                raise SweepError(f"{path}: duplicate axis field "
                                 f"'{ax.field}'")
            seen.add(ax.field)
        if self.samples < 0:
            raise SweepError("sweep.samples must be >= 0")

    # -- expansion ---------------------------------------------------------
    def expand(self) -> List[Cell]:
        """Axes -> concrete cells. Grid: cross-product in declaration
        order. Random: ``samples`` cells, each axis drawn from its own
        ``(seed, index, field)``-seeded stream."""
        self.check()
        if self.samples > 0:
            assignments = [
                [(ax.field,
                  ax.draw(random.Random(f"{self.seed}:{i}:{ax.field}")))
                 for ax in self.axes]
                for i in range(self.samples)]
        else:
            assignments = [[]]
            for ax in self.axes:
                vals = ax.grid_values(f"sweep.axes[{ax.field}]")
                assignments = [a + [(ax.field, v)]
                               for a in assignments for v in vals]
        cells = []
        for i, assign in enumerate(assignments):
            overrides = {f: v for f, v in assign
                         if not f.startswith(PARAM_PREFIX)}
            params = dict(self.params)
            params.update({f[len(PARAM_PREFIX):]: v for f, v in assign
                           if f.startswith(PARAM_PREFIX)})
            sc = with_overrides(self.base, overrides) if overrides \
                else self.base
            cells.append(Cell(index=i, scenario=sc, overrides=overrides,
                              params=params))
        return cells

    # -- (de)serialisation -------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["base"] = self.base.to_dict()
        return d

    @classmethod
    def from_dict(cls, data: dict) -> "Sweep":
        if not isinstance(data, dict):
            raise SweepError(
                f"sweep: expected an object, got {type(data).__name__}")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - fields)
        if unknown:
            raise SweepError(f"sweep: unknown key(s) {unknown}; valid "
                             f"keys: {sorted(fields)}")
        kw: dict = {}
        for k, v in data.items():
            if k == "base":
                kw[k] = Scenario.from_dict(v)
            elif k == "axes":
                if not isinstance(v, (list, tuple)):
                    raise SweepError("sweep.axes: expected a list")
                kw[k] = tuple(_axis_from_dict(a, f"sweep.axes[{i}]")
                              for i, a in enumerate(v))
            elif k == "params":
                if not isinstance(v, dict):
                    raise SweepError("sweep.params: expected an object")
                kw[k] = dict(v)
            else:
                kw[k] = v
        try:
            sweep = cls(**kw)
        except TypeError as e:
            raise SweepError(f"sweep: {e}") from None
        sweep.check()
        return sweep

    def to_json(self, **kwargs) -> str:
        kwargs.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kwargs)

    @classmethod
    def from_json(cls, text: str) -> "Sweep":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "Sweep":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _axis_from_dict(data: dict, path: str) -> Axis:
    if not isinstance(data, dict):
        raise SweepError(f"{path}: expected an object")
    fields = {f.name for f in dataclasses.fields(Axis)}
    unknown = sorted(set(data) - fields)
    if unknown:
        raise SweepError(f"{path}: unknown key(s) {unknown}; valid keys: "
                         f"{sorted(fields)}")
    kw = {k: (tuple(v) if isinstance(v, list) else v)
          for k, v in data.items()}
    try:
        return Axis(**kw)
    except TypeError as e:
        raise SweepError(f"{path}: {e}") from None


def _check_scenario_path(field: str, path: str) -> None:
    """A dotted axis field must name a real Scenario field (typos fail at
    declaration, not mid-run)."""
    node: Any = Scenario()
    parts = field.split(".")
    for i, part in enumerate(parts):
        if not dataclasses.is_dataclass(node):
            raise SweepError(f"{path}: '{field}' descends past the leaf "
                             f"field '{parts[i - 1]}'")
        if not any(f.name == part for f in dataclasses.fields(node)):
            raise SweepError(
                f"{path}: '{field}' is not a Scenario field (no "
                f"'{part}' on {type(node).__name__}; use the 'params.' "
                f"prefix for study parameters)")
        node = getattr(node, part)
