"""jit'd public wrappers over the Pallas kernels: pytree-level quantise /
dequantise / aggregate with padding + flattening handled here.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU is
the compile target) and False on TPU.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fedavg_reduce as fr
from repro.kernels import quantize as qz
from repro.kernels import ref as kref
from repro.kernels import topk as tk


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flat-array helpers
# ---------------------------------------------------------------------------

def _pad_to(x, multiple):
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,) + x.shape[1:], x.dtype)])
    return x, n


def quantize_flat(x, *, block: int = 256, interpret=None):
    """x: (T,) float -> dict(q=(T',) int8, scales, block, orig_len)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, orig = _pad_to(x.reshape(-1), block * qz.ROW_TILE)
    rows = xp.shape[0] // block
    q, s = qz.quantize_blocks(xp.reshape(rows, block), interpret=interpret)
    return {"q": q.reshape(-1), "scales": s.reshape(-1), "block": block,
            "orig_len": orig}


def dequantize_flat(packed, *, out_dtype=jnp.float32, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    block = packed["block"]
    q = packed["q"].reshape(-1, block)
    s = packed["scales"].reshape(-1, 1)
    x = qz.dequantize_blocks(q, s, out_dtype=out_dtype, interpret=interpret)
    return x.reshape(-1)[: packed["orig_len"]]


# ---------------------------------------------------------------------------
# batched flat-array API (the channel's fused encode path)
# ---------------------------------------------------------------------------
#
# One round's outstanding encodes arrive as a *list* of flat vectors. Each
# is padded independently to a whole number of (ROW_TILE, block) row-tiles
# and the tiles are concatenated into one (rows, block) array, so a single
# kernel dispatch quantises the lot — and, because quantisation is
# row-wise, every row is bit-identical to what the per-message call would
# have produced. Dispatch:
#
# * TPU (``interpret`` resolves False)  — the real Pallas kernel, fused.
# * CPU (``interpret`` resolves True)   — the jitted XLA reference
#   (kernels/ref.py): same f32 math, parity-tested bit-exact against the
#   interpret-mode kernel, but compiled instead of interpreted (the
#   interpreter walks the grid in Python; it is a correctness tool, not a
#   perf path). Pass ``interpret=True`` explicitly to force the Pallas
#   interpreter (the parity tests do).

_jit_quantize_ref = jax.jit(kref.quantize_blocks_ref)
_jit_dequantize_ref = jax.jit(kref.dequantize_blocks_ref)


def _quantize_rows(rows_x, interpret):
    """(rows, block) -> (q, scales) through the fastest bit-exact path."""
    if interpret is True:
        return qz.quantize_blocks(rows_x, interpret=True)
    if interpret is False or not _default_interpret():
        return qz.quantize_blocks(rows_x, interpret=False)
    return _jit_quantize_ref(rows_x)


def _dequantize_rows(q, s, interpret):
    if interpret is True:
        return qz.dequantize_blocks(q, s, interpret=True)
    if interpret is False or not _default_interpret():
        return qz.dequantize_blocks(q, s, interpret=False)
    return _jit_dequantize_ref(q, s)


def quantize_flat_batch(flats: Sequence, *, block: int = 256,
                        interpret=None):
    """[x_i] -> [packed_i], one fused kernel dispatch for the whole batch.

    Per-item results are bit-identical to ``quantize_flat(x_i)`` (padding
    is per-item and row-aligned; quantisation is row-wise)."""
    if not flats:
        return []
    mult = block * qz.ROW_TILE
    # pad + concatenate on the host: per-item jnp pads would cost one
    # dispatch each and dominate the small-message regime this API is
    # for; a single zeros+memcpy feeds one device transfer instead
    arrs = [np.asarray(x, np.float32).reshape(-1) for x in flats]
    pad_lens = [-(-a.size // mult) * mult for a in arrs]
    big = np.zeros(sum(pad_lens), np.float32)
    off = 0
    for a, pl in zip(arrs, pad_lens):
        big[off:off + a.size] = a
        off += pl
    q, s = _quantize_rows(jnp.asarray(big.reshape(-1, block)), interpret)
    q, s = np.asarray(q), np.asarray(s)  # one transfer; slices are views
    out, row = [], 0
    for a, pl in zip(arrs, pad_lens):
        rows = pl // block
        out.append({"q": q[row:row + rows].reshape(-1),
                    "scales": s[row:row + rows].reshape(-1),
                    "block": block, "orig_len": a.size})
        row += rows
    return out


def dequantize_flat_batch(packed_list: Sequence[dict], *,
                          out_dtype=jnp.float32, interpret=None):
    """[packed_i] -> [x_i], fused when every item shares one block size."""
    if not packed_list:
        return []
    blocks = {int(p["block"]) for p in packed_list}
    if len(blocks) > 1:  # mixed block sizes cannot share a (rows, block)
        return [dequantize_flat(p, out_dtype=out_dtype, interpret=interpret)
                for p in packed_list]
    block = blocks.pop()
    qs = [np.asarray(p["q"]).reshape(-1, block) for p in packed_list]
    ss = [np.asarray(p["scales"]).reshape(-1, 1) for p in packed_list]
    q = qs[0] if len(qs) == 1 else np.concatenate(qs)
    s = ss[0] if len(ss) == 1 else np.concatenate(ss)
    x = _dequantize_rows(jnp.asarray(q), jnp.asarray(s), interpret)
    if out_dtype != jnp.float32:
        x = x.astype(out_dtype)
    x = np.asarray(x)
    out, row = [], 0
    for p, qi in zip(packed_list, qs):
        rows = qi.shape[0]
        out.append(x[row:row + rows].reshape(-1)[: p["orig_len"]])
        row += rows
    return out


# ---------------------------------------------------------------------------
# batched top-k selection (the TopkCodec's fused encode path)
# ---------------------------------------------------------------------------

_jit_topk_ref = jax.jit(kref.topk_rows_ref, static_argnames=("k",))


def _topk_rows(rows_x, k, interpret):
    """(B, T) -> (idx, vals) through the fastest bit-exact path (same
    dispatch rule as ``_quantize_rows``)."""
    if interpret is True:
        return tk.topk_rows(rows_x, k, interpret=True)
    if interpret is False or not _default_interpret():
        return tk.topk_rows(rows_x, k, interpret=False)
    return _jit_topk_ref(rows_x, k=k)


def topk_flat_batch(flats: Sequence, *, k_frac: float = 0.05,
                    interpret=None):
    """[x_i] -> [{idx, vals, n}], the top-k sparse wire form, batched.

    Items are grouped by (length, k) — k is ``max(1, int(size *
    k_frac))``, a per-length wire constant — and each group runs as ONE
    stacked kernel dispatch. No padding is ever applied: padding would
    change k and the selection set, so unequal lengths simply land in
    different groups. Per-item results are bit-identical to the
    per-message ``top_k(|flat|)`` + gather path (same tie rule)."""
    if not flats:
        return []
    arrs = [np.asarray(x, np.float32).reshape(-1) for x in flats]
    groups: dict = {}
    for i, a in enumerate(arrs):
        k = max(1, int(a.size * k_frac))
        groups.setdefault((a.size, k), []).append(i)
    out = [None] * len(arrs)
    for (size, k), idxs in groups.items():
        stacked = jnp.asarray(np.stack([arrs[i] for i in idxs]))
        gi, gv = _topk_rows(stacked, k, interpret)
        gi, gv = np.asarray(gi), np.asarray(gv)
        for row, i in enumerate(idxs):
            out[i] = {"idx": gi[row], "vals": gv[row], "n": size}
    return out


_jit_accumulate_ref = jax.jit(kref.fedavg_accumulate_ref)


def fedavg_accumulate_flat(acc, x, w, *, interpret=None):
    """One streaming fold ``acc + w * x`` over flat (T,) vectors via the
    fedavg_reduce accumulate kernel (CPU default: the jitted XLA
    reference — same dispatch rule as the quantize wrappers)."""
    if interpret is None and _default_interpret():
        return _jit_accumulate_ref(jnp.asarray(acc, jnp.float32),
                                   jnp.asarray(x, jnp.float32), w)
    accp, orig = _pad_to(jnp.asarray(acc, jnp.float32), fr.COL_TILE)
    xp, _ = _pad_to(jnp.asarray(x, jnp.float32), fr.COL_TILE)
    return fr.fedavg_accumulate(accp, xp, w,
                                interpret=bool(interpret))[:orig]


# ---------------------------------------------------------------------------
# pytree-level API (used by compression/ and fl/)
# ---------------------------------------------------------------------------

def flatten_pytree(tree):
    """-> (flat f32 vector, unflatten_fn). Dtype-preserving on unflatten."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(vec):
        out = []
        off = 0
        for size, shape, dt in zip(sizes, shapes, dtypes):
            out.append(vec[off:off + size].reshape(shape).astype(dt))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def quantize_pytree(tree, *, block: int = 256, interpret=None):
    flat, unflatten = flatten_pytree(tree)
    packed = quantize_flat(flat, block=block, interpret=interpret)
    return packed, unflatten


def fedavg_aggregate(updates: Sequence, weights, *, interpret=None):
    """Weighted average of N pytrees (normalised weights) via the Pallas
    reduction. Returns a pytree like updates[0]."""
    interpret = _default_interpret() if interpret is None else interpret
    weights = jnp.asarray(weights, jnp.float32)
    weights = weights / jnp.sum(weights)
    flats, unflatten = zip(*[flatten_pytree(u) for u in updates])
    stacked = jnp.stack(flats)  # (N, T)
    stacked, orig = _pad_to(stacked.T, fr.COL_TILE)  # pad T
    agg = fr.fedavg_reduce(stacked.T, weights, interpret=interpret)
    return unflatten[0](agg[:orig])


def fedavg_aggregate_q8(packed_list: Sequence[dict], weights, unflatten,
                        *, interpret=None):
    """Aggregate quantised client updates without materialising dequantised
    copies. packed_list: outputs of quantize_flat (same block/orig_len)."""
    interpret = _default_interpret() if interpret is None else interpret
    weights = jnp.asarray(weights, jnp.float32)
    weights = weights / jnp.sum(weights)
    block = packed_list[0]["block"]
    orig = packed_list[0]["orig_len"]
    q = jnp.stack([p["q"] for p in packed_list])  # (N, T') int8
    s = jnp.stack([p["scales"] for p in packed_list])  # (N, T'/block)
    t = q.shape[1]
    if t % fr.COL_TILE:
        pad = (-t) % fr.COL_TILE
        q = jnp.pad(q, ((0, 0), (0, pad)))
        s = jnp.pad(s, ((0, 0), (0, pad // block)))
    agg = fr.fedavg_reduce_q8(q, s, weights, block=block,
                              interpret=interpret)
    return unflatten(agg[:orig])
