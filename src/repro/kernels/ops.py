"""jit'd public wrappers over the Pallas kernels: pytree-level quantise /
dequantise / aggregate with padding + flattening handled here.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU is
the compile target) and False on TPU.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fedavg_reduce as fr
from repro.kernels import quantize as qz


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flat-array helpers
# ---------------------------------------------------------------------------

def _pad_to(x, multiple):
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,) + x.shape[1:], x.dtype)])
    return x, n


def quantize_flat(x, *, block: int = 256, interpret=None):
    """x: (T,) float -> dict(q=(T',) int8, scales, block, orig_len)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, orig = _pad_to(x.reshape(-1), block * qz.ROW_TILE)
    rows = xp.shape[0] // block
    q, s = qz.quantize_blocks(xp.reshape(rows, block), interpret=interpret)
    return {"q": q.reshape(-1), "scales": s.reshape(-1), "block": block,
            "orig_len": orig}


def dequantize_flat(packed, *, out_dtype=jnp.float32, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    block = packed["block"]
    q = packed["q"].reshape(-1, block)
    s = packed["scales"].reshape(-1, 1)
    x = qz.dequantize_blocks(q, s, out_dtype=out_dtype, interpret=interpret)
    return x.reshape(-1)[: packed["orig_len"]]


# ---------------------------------------------------------------------------
# pytree-level API (used by compression/ and fl/)
# ---------------------------------------------------------------------------

def flatten_pytree(tree):
    """-> (flat f32 vector, unflatten_fn). Dtype-preserving on unflatten."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(vec):
        out = []
        off = 0
        for size, shape, dt in zip(sizes, shapes, dtypes):
            out.append(vec[off:off + size].reshape(shape).astype(dt))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def quantize_pytree(tree, *, block: int = 256, interpret=None):
    flat, unflatten = flatten_pytree(tree)
    packed = quantize_flat(flat, block=block, interpret=interpret)
    return packed, unflatten


def fedavg_aggregate(updates: Sequence, weights, *, interpret=None):
    """Weighted average of N pytrees (normalised weights) via the Pallas
    reduction. Returns a pytree like updates[0]."""
    interpret = _default_interpret() if interpret is None else interpret
    weights = jnp.asarray(weights, jnp.float32)
    weights = weights / jnp.sum(weights)
    flats, unflatten = zip(*[flatten_pytree(u) for u in updates])
    stacked = jnp.stack(flats)  # (N, T)
    stacked, orig = _pad_to(stacked.T, fr.COL_TILE)  # pad T
    agg = fr.fedavg_reduce(stacked.T, weights, interpret=interpret)
    return unflatten[0](agg[:orig])


def fedavg_aggregate_q8(packed_list: Sequence[dict], weights, unflatten,
                        *, interpret=None):
    """Aggregate quantised client updates without materialising dequantised
    copies. packed_list: outputs of quantize_flat (same block/orig_len)."""
    interpret = _default_interpret() if interpret is None else interpret
    weights = jnp.asarray(weights, jnp.float32)
    weights = weights / jnp.sum(weights)
    block = packed_list[0]["block"]
    orig = packed_list[0]["orig_len"]
    q = jnp.stack([p["q"] for p in packed_list])  # (N, T') int8
    s = jnp.stack([p["scales"] for p in packed_list])  # (N, T'/block)
    t = q.shape[1]
    if t % fr.COL_TILE:
        pad = (-t) % fr.COL_TILE
        q = jnp.pad(q, ((0, 0), (0, pad)))
        s = jnp.pad(s, ((0, 0), (0, pad // block)))
    agg = fr.fedavg_reduce_q8(q, s, weights, block=block,
                              interpret=interpret)
    return unflatten(agg[:orig])
