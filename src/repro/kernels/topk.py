"""Pallas TPU kernel: batched magnitude top-k selection (sparsification).

One grid step per row: iterative first-index argmax over |x| — k rounds
of (max, select, mask) — emitting the k largest-magnitude entries per
row as (index, signed value) pairs. The selection order is |value|
descending with ties broken toward the lower index, which is exactly
``jax.lax.top_k``'s rule, so the sparse wire form is bit-identical to
the per-message ``top_k(|flat|)`` + gather codec path this kernel fuses
(compression/topk.py).

``k`` is static (a wire-format constant per message length), so the
fori_loop unrolls to a fixed trip count at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, idx_ref, val_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)  # (1, T)
    t = x.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def step(j, carry):
        a_cur, idxs, vals = carry
        m = jnp.max(a_cur)
        # first index attaining the max: |value| desc, lower index first
        sel = jnp.min(jnp.where(a_cur == m, iota, t))
        val = jnp.sum(jnp.where(iota == sel, x, jnp.float32(0.0)))
        idxs = jax.lax.dynamic_update_slice(idxs, sel.reshape(1, 1), (0, j))
        vals = jax.lax.dynamic_update_slice(vals, val.reshape(1, 1), (0, j))
        # mask the winner below any |x| (all >= 0) so it never re-wins
        a_cur = jnp.where(iota == sel, jnp.float32(-1.0), a_cur)
        return a_cur, idxs, vals

    _, idxs, vals = jax.lax.fori_loop(
        0, k, step,
        (jnp.abs(x), jnp.zeros((1, k), jnp.int32),
         jnp.zeros((1, k), jnp.float32)))
    idx_ref[...] = idxs
    val_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_rows(x, k: int, *, interpret: bool = True):
    """x: (B, T) float -> (idx (B, k) i32, vals (B, k) f32) per row."""
    b, t = x.shape
    idx, vals = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.int32),
                   jax.ShapeDtypeStruct((b, k), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return idx, vals
