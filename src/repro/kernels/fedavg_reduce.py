"""Pallas TPU kernel: fused weighted aggregation of N client updates.

The FL server's compute hot-spot: ``agg = sum_i w_i * update_i`` over N
stacked flat updates. Two variants:

* ``fedavg_reduce``   — float inputs (N, T).
* ``fedavg_reduce_q8`` — int8 inputs + per-(client, block) scales, fusing
  dequantisation into the reduction so the dequantised f32 copies are never
  materialised in HBM (N x T x 4 bytes saved vs dequant-then-sum).
* ``fedavg_accumulate`` — the streaming form: fold ONE weighted update
  into a running accumulator, ``acc + w * x``. The fleet-scale hub calls
  this once per arriving update, so server memory is O(model) instead of
  the O(clients x model) stacked buffer the batch reduction needs.

Tiling: grid over T in COL_TILE lanes; each step holds an (N, COL_TILE)
tile in VMEM (N <= ~64 clients keeps tiles < 1 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 1024


def _fedavg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (N, C)
    w = w_ref[...].astype(jnp.float32)  # (N, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_reduce(updates, weights, *, interpret: bool = True):
    """updates: (N, T) float; weights: (N,) -> (T,) f32 weighted sum.
    T must be a multiple of COL_TILE (ops.py pads)."""
    n, t = updates.shape
    assert t % COL_TILE == 0, t
    grid = (t // COL_TILE,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, COL_TILE), lambda i: (0, i)),
                  pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, t), jnp.float32),
        interpret=interpret,
    )(updates, weights.reshape(n, 1))
    return out[0]


def _accum_kernel(a_ref, x_ref, w_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (1, C)
    x = x_ref[...].astype(jnp.float32)  # (1, C)
    w = w_ref[...].astype(jnp.float32)  # (1, 1)
    o_ref[...] = a + w * x


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_accumulate(acc, x, w, *, interpret: bool = True):
    """acc, x: (T,) float; w: scalar -> (T,) f32 ``acc + w * x``.
    T must be a multiple of COL_TILE (ops.py pads)."""
    t = acc.shape[0]
    assert t % COL_TILE == 0, t
    grid = (t // COL_TILE,)
    w = jnp.asarray(w, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
                  pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, t), jnp.float32),
        interpret=interpret,
    )(acc.reshape(1, t), x.reshape(1, t), w)
    return out[0]


def _fedavg_q8_kernel(q_ref, s_ref, w_ref, o_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)  # (N, C)
    s = s_ref[...].astype(jnp.float32)  # (N, C // block)
    w = w_ref[...].astype(jnp.float32)  # (N, 1)
    n, c = q.shape
    x = q.reshape(n, c // block, block) * s[..., None]
    o_ref[...] = jnp.sum(x.reshape(n, c) * w, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_reduce_q8(q, scales, weights, *, block: int = 256,
                     interpret: bool = True):
    """q: (N, T) int8; scales: (N, T // block) f32; weights: (N,).
    Fused dequant + weighted sum -> (T,) f32."""
    n, t = q.shape
    assert t % COL_TILE == 0 and COL_TILE % block == 0
    grid = (t // COL_TILE,)
    sc_per_tile = COL_TILE // block
    out = pl.pallas_call(
        functools.partial(_fedavg_q8_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((n, COL_TILE), lambda i: (0, i)),
                  pl.BlockSpec((n, sc_per_tile), lambda i: (0, i)),
                  pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, COL_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, t), jnp.float32),
        interpret=interpret,
    )(q, scales, weights.reshape(n, 1))
    return out[0]
