"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_blocks_ref(x):
    """x: (rows, block) -> (q int8, scales f32 (rows,1))."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q, scales, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scales).astype(out_dtype)


def fedavg_reduce_ref(updates, weights):
    """updates (N, T), weights (N,) -> (T,) f32."""
    return jnp.sum(updates.astype(jnp.float32)
                   * weights.astype(jnp.float32)[:, None], axis=0)


def fedavg_reduce_q8_ref(q, scales, weights, block: int = 256):
    n, t = q.shape
    x = q.astype(jnp.float32).reshape(n, t // block, block) \
        * scales.astype(jnp.float32)[..., None]
    return jnp.sum(x.reshape(n, t) * weights.astype(jnp.float32)[:, None],
                   axis=0)
