"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)
plus the pure-NumPy legacy codec (the pre-batching per-message baseline the
perf trajectory and the bit-exactness parity tests compare against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_blocks_ref(x):
    """x: (rows, block) -> (q int8, scales f32 (rows,1))."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q, scales, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scales).astype(out_dtype)


def quantize_blocks_np(x):
    """Pure-NumPy twin of ``quantize_blocks_ref`` (single-threaded, no
    XLA): the legacy per-message codec baseline. Same math, same f32
    rounding (np.round is round-half-even like jnp.round), so its int8
    output is bit-identical to the kernel's."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1, keepdims=True)
    scale = amax / np.float32(127.0)
    inv = np.divide(np.float32(1.0), scale, where=scale > 0.0,
                    out=np.zeros_like(scale))
    q = np.clip(np.round(x * inv), -127.0, 127.0).astype(np.int8)
    return q, scale


def dequantize_blocks_np(q, scales, out_dtype=np.float32):
    return (np.asarray(q, np.float32) * np.asarray(scales,
                                                   np.float32)).astype(out_dtype)


def fedavg_reduce_ref(updates, weights):
    """updates (N, T), weights (N,) -> (T,) f32."""
    return jnp.sum(updates.astype(jnp.float32)
                   * weights.astype(jnp.float32)[:, None], axis=0)


def fedavg_accumulate_ref(acc, x, w):
    """acc, x (T,), w scalar -> (T,) f32 ``acc + w * x``."""
    return acc.astype(jnp.float32) + jnp.float32(w) * x.astype(jnp.float32)


def topk_rows_ref(x, k: int):
    """x: (B, T) -> (idx (B, k) i32, vals (B, k) f32): the k largest-|.|
    entries per row, |value|-descending, ties broken toward the lower
    index (jax.lax.top_k's order — and the per-message codec's)."""
    vals_abs, idx = jax.lax.top_k(jnp.abs(x.astype(jnp.float32)), k)
    del vals_abs
    vals = jnp.take_along_axis(x.astype(jnp.float32), idx, axis=-1)
    return idx.astype(jnp.int32), vals


def fedavg_reduce_q8_ref(q, scales, weights, block: int = 256):
    n, t = q.shape
    x = q.astype(jnp.float32).reshape(n, t // block, block) \
        * scales.astype(jnp.float32)[..., None]
    return jnp.sum(x.reshape(n, t) * weights.astype(jnp.float32)[:, None],
                   axis=0)
