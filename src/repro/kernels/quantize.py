"""Pallas TPU kernel: blockwise symmetric int8 quantisation.

This is the compression hot-spot of the communication layer (QSGD-style
int8 payloads for cross-pod/cross-silo sync, §Compression in DESIGN.md).
Layout: input viewed as (rows, block) — one scale per row-block of
``block`` contiguous elements. Tiles are (ROW_TILE, block) in VMEM; the
lane dimension equals the quant block so the reduction is a single in-tile
max (MXU-free, pure VPU work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8  # f32 sublane tile


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (rows, 1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequantize_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(x, *, interpret: bool = True):
    """x: (rows, block) float -> (q int8 (rows, block), scales f32 (rows, 1)).

    rows must be a multiple of ROW_TILE (ops.py pads).
    """
    rows, block = x.shape
    assert rows % ROW_TILE == 0, rows
    grid = (rows // ROW_TILE,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize_blocks(q, scales, *, out_dtype=jnp.float32,
                      interpret: bool = True):
    rows, block = q.shape
    assert rows % ROW_TILE == 0, rows
    grid = (rows // ROW_TILE,)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), out_dtype),
        interpret=interpret,
    )(q, scales)
