"""Declarative scenario API: topology-as-a-graph, one spec from CLI to
fabric (see spec.py for the schema, build.py for the runtime)."""
from repro.core.transport import FabricSpec
from repro.scenario.build import Runtime, build_runtime, fault_model_for
from repro.scenario.spec import (MODES, TOPOLOGY_PRESETS, BlackoutSpec,
                                 ChannelSpec, EdgeSpec, FaultSpec,
                                 FleetSpec, JobSpec, MultiScenario,
                                 Scenario, ScenarioError, SplitSpec,
                                 StrategySpec, TopologySpec,
                                 load_blackouts_file, with_overrides)

__all__ = ["Scenario", "TopologySpec", "FleetSpec", "ChannelSpec",
           "FaultSpec", "StrategySpec", "SplitSpec", "EdgeSpec",
           "BlackoutSpec", "FabricSpec", "JobSpec", "MultiScenario",
           "ScenarioError", "TOPOLOGY_PRESETS", "MODES", "with_overrides",
           "load_blackouts_file", "Runtime", "build_runtime",
           "fault_model_for"]
