"""Declarative scenario API: topology-as-a-graph, one spec from CLI to
fabric (see spec.py for the schema, build.py for the runtime)."""
from repro.scenario.build import Runtime, build_runtime, fault_model_for
from repro.scenario.spec import (MODES, TOPOLOGY_PRESETS, BlackoutSpec,
                                 ChannelSpec, EdgeSpec, FaultSpec,
                                 FleetSpec, Scenario, ScenarioError,
                                 StrategySpec, TopologySpec, with_overrides)

__all__ = ["Scenario", "TopologySpec", "FleetSpec", "ChannelSpec",
           "FaultSpec", "StrategySpec", "EdgeSpec", "BlackoutSpec",
           "ScenarioError", "TOPOLOGY_PRESETS", "MODES", "with_overrides",
           "Runtime", "build_runtime", "fault_model_for"]
