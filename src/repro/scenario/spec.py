"""The Scenario layer: one frozen, declarative description of a deployment.

The paper's central claim is that backend choice is a function of the
*deployment scenario* — model size x network topology x concurrency
(§IV-§VII). Before this layer, that description was scattered across
three hardcoded ``*_env`` constructors, a flag soup in ``fl_train`` and
per-benchmark ad-hoc wiring. A ``Scenario`` gathers the whole experiment
into five frozen sub-specs:

* ``TopologySpec`` — an explicit host/region **link graph** with
  per-edge bandwidth/latency/connection caps. Presets ``lan`` /
  ``geo_proximal`` / ``geo_distributed`` reproduce the legacy
  environments bit-for-bit (regression-tested); ``star`` / ``ring`` /
  ``multi_hub`` are graph-native topologies in the Marfoq & Neglia
  throughput-optimal-topology line (benchmarks/fig9_topology_wan.py).
* ``FleetSpec``    — who trains: tier, local steps.
* ``ChannelSpec``  — what the wire stack looks like: backend, payload
  codec, wire codec, chunking.
* ``FaultSpec``    — what goes wrong: link loss, NACK timing, store
  faults, churn traces.
* ``StrategySpec`` — how aggregation runs: mode + its knobs.

``Scenario.to_dict()`` / ``Scenario.from_dict()`` round-trip exactly
(``from_dict(to_dict(s)) == s``), including through JSON, and
``from_dict`` rejects unknown keys / invalid edges with a readable path
(``topology.edges[2]: unknown key(s) ['bandwith']``). ``fl_train
--scenario file.json`` loads one; individual CLI flags become overrides
on the resolved spec (``with_overrides``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Tuple

from repro.core.netsim import (GB, GEO_REGIONS, LAN_TCP, MB, NCAL, REGIONS,
                               Environment, Host, Link, Region)
from repro.core.transport import FabricSpec

TOPOLOGY_PRESETS = ("lan", "geo_proximal", "geo_distributed",
                    "star", "ring", "multi_hub")
MODES = ("sync", "fedbuff", "semisync", "hier", "vertical")


class ScenarioError(ValueError):
    """Invalid scenario spec — the message carries the offending path."""


# ---------------------------------------------------------------------------
# sub-specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """One declared link-graph edge (layered onto the preset graph).

    Bandwidths in MB/s and latency in ms — Table I's units. ``max_conns``
    caps the multi-connection saturation at ``max_conns * bw_single``
    (folded into the built edge's ``bw_multi``); ``symmetric`` installs
    the reverse edge too; ``lan_class`` edges resolve IB-vs-TCP per
    backend policy like the LAN testbed links.

    Asymmetric directed-pair shorthand: real WAN links are rarely
    symmetric (a silo's uplink is usually thinner than its downlink), and
    spelling that as two ``symmetric=False`` edges doubles every
    declaration. Setting any ``rev_*`` field turns the edge into a
    one-line directed pair — the forward direction carries the main
    rates, the ``dst -> src`` direction the ``rev_*`` rates, with any
    unset ``rev_*`` component inheriting its forward value. The pair
    installs both directions, so combining ``rev_*`` with
    ``symmetric=False`` is a contradiction and rejected at validation."""
    src: str
    dst: str
    bw_single_mb: float
    bw_multi_mb: float
    latency_ms: float
    max_conns: int = 0
    symmetric: bool = True
    lan_class: bool = False
    # directed-pair shorthand (0 / -1 = "same as forward")
    rev_bw_single_mb: float = 0.0
    rev_bw_multi_mb: float = 0.0
    rev_latency_ms: float = -1.0

    @property
    def asymmetric(self) -> bool:
        return (self.rev_bw_single_mb > 0 or self.rev_bw_multi_mb > 0
                or self.rev_latency_ms >= 0)

    def reverse_rates(self) -> Tuple[float, float, float]:
        """(bw_single_mb, bw_multi_mb, latency_ms) of the reverse leg."""
        return (self.rev_bw_single_mb or self.bw_single_mb,
                self.rev_bw_multi_mb or self.bw_multi_mb,
                self.rev_latency_ms if self.rev_latency_ms >= 0
                else self.latency_ms)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Host/region link graph, built from a preset + explicit edges."""
    kind: str = "geo_distributed"
    num_clients: int = 7
    # region names cycled over the clients; () = the preset's default
    # (Table I's seven regions for the WAN presets)
    regions: Tuple[str, ...] = ()
    edges: Tuple[EdgeSpec, ...] = ()
    # upload-side reduction-tree depth for hier mode: 1 = region relays
    # ship straight to the hub (the historical shape, bit-for-bit);
    # D > 1 inserts D-1 tiers of super-relays between them and the hub
    relay_depth: int = 1

    @classmethod
    def preset(cls, name: str, num_clients: int = 7) -> "TopologySpec":
        return cls(kind=name, num_clients=num_clients)

    # -- building ----------------------------------------------------------
    def client_regions(self) -> Tuple[Region, ...]:
        if self.kind == "lan":
            names = self.regions or ("lan_tcp",)
        elif self.kind == "geo_proximal":
            names = self.regions or ("ncal",)
        else:
            names = self.regions or tuple(r.name for r in GEO_REGIONS)
        for n in names:
            if n not in REGIONS:
                raise ScenarioError(
                    f"topology.regions: unknown region '{n}'; known: "
                    f"{sorted(REGIONS)}")
        cycle = tuple(REGIONS[names[i % len(names)]]
                      for i in range(self.num_clients))
        return cycle

    def _hosts(self) -> Tuple[Host, Tuple[Host, ...]]:
        if self.kind == "lan":
            server = Host("server", LAN_TCP, 5.0 * GB, 5.0 * GB)
            clients = tuple(Host(f"client{i}", LAN_TCP, 5.0 * GB, 5.0 * GB)
                            for i in range(self.num_clients))
            return server, clients
        server = Host("server", NCAL, NCAL.bw_multi, NCAL.bw_multi)
        clients = tuple(Host(f"client{i}", r, r.bw_multi, r.bw_multi)
                        for i, r in enumerate(self.client_regions()))
        return server, clients

    def check(self) -> None:
        """Full spec validation without materialising the dense edge map
        (Scenario.validate() runs only this; build() runs it and then
        builds — the graph is constructed once per deployment)."""
        if self.kind not in TOPOLOGY_PRESETS:
            raise ScenarioError(
                f"topology.kind: unknown preset '{self.kind}'; choose "
                f"from {list(TOPOLOGY_PRESETS)}")
        if self.num_clients < 1:
            raise ScenarioError("topology.num_clients must be >= 1")
        if self.relay_depth < 1:
            raise ScenarioError("topology.relay_depth must be >= 1")
        self.client_regions()  # validates region names
        known = {"server"} | {f"client{i}" for i in range(self.num_clients)}
        for i, e in enumerate(self.edges):
            for end in (e.src, e.dst):
                if end not in known:
                    raise ScenarioError(
                        f"topology.edges[{i}]: endpoint '{end}' names no "
                        f"host in this topology (hosts: server, client0.."
                        f"client{self.num_clients - 1})")
            if e.bw_single_mb <= 0 or e.bw_multi_mb <= 0:
                raise ScenarioError(
                    f"topology.edges[{i}]: bandwidths must be positive")
            if e.latency_ms < 0:
                raise ScenarioError(
                    f"topology.edges[{i}]: latency_ms must be >= 0")
            # any touched rev_* field counts as directed-pair intent —
            # a lone negative bandwidth must error, not silently read
            # as a symmetric edge
            rev_touched = (e.rev_bw_single_mb != 0 or e.rev_bw_multi_mb != 0
                           or e.rev_latency_ms >= 0)
            if rev_touched:
                if e.rev_bw_single_mb < 0 or e.rev_bw_multi_mb < 0:
                    raise ScenarioError(
                        f"topology.edges[{i}]: rev_* bandwidths must be "
                        f"positive (0 = same as forward)")
                if not e.symmetric:
                    raise ScenarioError(
                        f"topology.edges[{i}]: the rev_* directed-pair "
                        f"shorthand installs both directions; it "
                        f"contradicts symmetric=False (declare two "
                        f"one-way edges instead)")

    # above this fleet size the dense presets switch to a lazy edge map:
    # the O(n^2) pair loop below would materialise 10^8 Link objects at
    # 10k clients, while _RuleLinks generates the identical edge on
    # first lookup (star/ring build O(n) maps and stay dense at any n)
    LAZY_LINKS_MIN = 65

    def build(self) -> Environment:
        """Materialise the full directed edge map (the explicit graph the
        backends consume instead of the old implicit region-pair rule)."""
        self.check()
        server, clients = self._hosts()
        hosts = [server] + list(clients)
        lazy = (self.num_clients >= self.LAZY_LINKS_MIN
                and self.kind not in ("star", "ring"))
        links: Dict[tuple, Link] = _RuleLinks(
            self.kind, {h.host_id: h for h in hosts}) if lazy else {}

        def put(a: Host, b: Host, region: Region, lan_class=False):
            links[(a.host_id, b.host_id)] = Link(a.host_id, b.host_id,
                                                 region, lan_class=lan_class)

        if lazy:
            pass  # the rule map generates the preset edges on demand
        elif self.kind == "lan":
            for a in hosts:
                for b in hosts:
                    if a is not b:
                        put(a, b, LAN_TCP, lan_class=True)
        elif self.kind in ("geo_proximal", "geo_distributed"):
            # the legacy implicit rule, made explicit: the non-hub end of
            # a transfer dominates (hub = NCAL, the paper's Table I frame)
            for a in hosts:
                for b in hosts:
                    if a is not b:
                        put(a, b, b.region if b.region.name != "ncal"
                            else a.region)
        elif self.kind == "star":
            # pure hub-and-spoke: only hub<->client edges exist
            for c in clients:
                put(server, c, c.region)
                put(c, server, c.region)
        elif self.kind == "ring":
            # hub edges (model distribution + the closing hop) plus a
            # client ring; a client-client WAN edge is the bottleneck of
            # the two Table-I hub links, with both one-way legs of delay
            for c in clients:
                put(server, c, c.region)
                put(c, server, c.region)
            n = len(clients)
            for i, c in enumerate(clients):
                d = clients[(i + 1) % n]
                ring = _bottleneck_region(c.region, d.region)
                put(c, d, ring)
                put(d, c, ring)
        elif self.kind == "multi_hub":
            # hierarchical: per-region relay hubs. WAN edges hub<->client
            # carry the region link; clients sharing a region get
            # DC-class intra-region edges (the relay's LAN-side fan-out)
            for c in clients:
                put(server, c, c.region)
                put(c, server, c.region)
            by_region: Dict[str, list] = {}
            for c in clients:
                by_region.setdefault(c.region.name, []).append(c)
            for group in by_region.values():
                for a in group:
                    for b in group:
                        if a is not b:
                            put(a, b, LAN_TCP)

        def edge_region(src, dst, bw_single_mb, bw_multi_mb, latency_ms,
                        max_conns):
            bw_multi = bw_multi_mb * MB
            if max_conns > 0:
                bw_multi = min(bw_multi, max_conns * bw_single_mb * MB)
            return Region(f"edge:{src}>{dst}", bw_single_mb * MB,
                          bw_multi, latency_ms * 1e-3)

        for e in self.edges:
            region = edge_region(e.src, e.dst, e.bw_single_mb,
                                 e.bw_multi_mb, e.latency_ms, e.max_conns)
            links[(e.src, e.dst)] = Link(e.src, e.dst, region,
                                         lan_class=e.lan_class)
            if e.asymmetric:
                # directed-pair shorthand: the reverse leg gets its own
                # rates (unset components inherit the forward values)
                rs, rm, rl = e.reverse_rates()
                rev = edge_region(e.dst, e.src, rs, rm, rl, e.max_conns)
                links[(e.dst, e.src)] = Link(e.dst, e.src, rev,
                                             lan_class=e.lan_class)
            elif e.symmetric:
                links[(e.dst, e.src)] = Link(e.dst, e.src, region,
                                             lan_class=e.lan_class)

        return Environment(
            name=self.kind, server=server, clients=clients,
            has_object_store=self.kind != "lan",
            trusted=self.kind in ("lan", "geo_proximal"),
            links=links)


def _bottleneck_region(a: Region, b: Region) -> Region:
    return Region(f"{a.name}~{b.name}", min(a.bw_single, b.bw_single),
                  min(a.bw_multi, b.bw_multi), a.latency + b.latency)


class _RuleLinks(dict):
    """Lazy edge map for the dense presets at fleet scale.

    ``get`` generates an edge on first lookup by the exact rule the
    dense ``build`` loop applies for the same preset (bit-identical
    Link values), then caches it, so a 10k-client topology never
    materialises its 10^8 host pairs. Explicit EdgeSpec overrides are
    stored eagerly through ``__setitem__`` and shadow the rule. Pairs
    the preset declares no edge for (e.g. cross-region client pairs in
    ``multi_hub``) return ``default`` — the same implicit-rule fallback
    ``Environment.link`` applies to a dense map without that key."""

    def __init__(self, kind: str, hosts: Dict[str, Host]):
        super().__init__()
        self._kind = kind
        self._hosts = hosts

    def __bool__(self):  # an empty cache still answers for every edge
        return True

    def get(self, key, default=None):
        hit = super().get(key)
        if hit is not None:
            return hit
        src_id, dst_id = key
        a = self._hosts.get(src_id)
        b = self._hosts.get(dst_id)
        if a is None or b is None or src_id == dst_id:
            return default
        if self._kind == "lan":
            edge = Link(src_id, dst_id, LAN_TCP, lan_class=True)
        elif self._kind in ("geo_proximal", "geo_distributed"):
            edge = Link(src_id, dst_id,
                        b.region if b.region.name != "ncal" else a.region)
        elif self._kind == "multi_hub":
            if "server" in (src_id, dst_id):
                spoke = b if src_id == "server" else a
                edge = Link(src_id, dst_id, spoke.region)
            elif a.region.name == b.region.name:
                edge = Link(src_id, dst_id, LAN_TCP)
            else:
                return default  # cross-region client pair: no edge
        else:
            return default
        self[key] = edge
        return edge


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Who trains: the model tier + local work per dispatch."""
    tier: str = "small"
    local_steps: int = 4
    # cohort sampling (the cross-device regime at fleet scale): each
    # aggregation round draws a seeded K-of-N client sample; 0 (or
    # K >= N) keeps the whole fleet in play, bit-for-bit today's runs
    cohort_k: int = 0
    # per-dispatch simulated compute seconds; 0.0 = the tier's
    # calibrated train time. A near-zero override turns a job into a
    # traffic generator (checkpoint sync / dataset replication tenants
    # in the multi-job studies: all wire, no training gaps)
    train_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """The wire stack every backend in the deployment drives."""
    backend: str = "grpc+s3"
    compression: str = "none"   # payload codec: qsgd[:block] | topk[:frac]
    wire_codec: str = "none"    # byte codec on the serialized wire: zlib[:lvl]
    chunk_mb: float = 0.0


@dataclasses.dataclass(frozen=True)
class BlackoutSpec:
    """One link outage window: nothing departs on the named edge during
    ``[t0, t1)``; departures shift to the window's end (a transient WAN
    partition). ``dst="*"`` darkens every link touching ``src`` (the
    per-host form — LinkFaultModel's original machinery); a concrete
    ``dst`` darkens only that edge. ``symmetric`` darkens both
    directions of the pair (partitions usually do)."""
    src: str
    dst: str = "*"
    t0: float = 0.0
    t1: float = 0.0
    symmetric: bool = True


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What goes wrong (all deterministic from the scenario seed)."""
    link_loss: float = 0.0       # per-chunk loss on every graph edge
    max_retries: int = 4
    nack_rtts: float = 1.0       # receiver-driven NACK turnaround (edge RTTs)
    store_fail_rate: float = 0.0
    availability_trace: str = ""  # fl/fault.AvailabilityTrace spec
    trace_horizon_s: float = 3600.0
    blackouts: Tuple[BlackoutSpec, ...] = ()  # per-edge/-host outages
    # JSONL outage replay: one {"src", "dst", "t0", "t1", "symmetric"}
    # object per line, parsed into BlackoutSpecs and appended to the
    # inline list ("" = none). Relative paths resolve against the
    # scenario file's directory at Scenario.load time.
    blackouts_file: str = ""

    def all_blackouts(self) -> Tuple[BlackoutSpec, ...]:
        """Inline blackouts + the parsed trace file (in that order)."""
        if not self.blackouts_file:
            return self.blackouts
        return self.blackouts + load_blackouts_file(self.blackouts_file)


def load_blackouts_file(path: str) -> Tuple[BlackoutSpec, ...]:
    """Parse a JSONL blackout trace into BlackoutSpecs.

    One JSON object per line; blank lines and ``#`` comment lines are
    skipped. Every malformed line is a loud ``ScenarioError`` carrying
    ``path:lineno`` — an outage replay that silently drops windows would
    invalidate the whole study."""
    try:
        f = open(path)
    except OSError as e:
        raise ScenarioError(
            f"faults.blackouts_file: cannot read '{path}' "
            f"({e.strerror or e})") from None
    out = []
    with f:
        for ln, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            try:
                data = json.loads(line)
            except json.JSONDecodeError as e:
                raise ScenarioError(
                    f"{path}:{ln}: not valid JSON ({e.msg})") from None
            out.append(_from_dict(BlackoutSpec, data, f"{path}:{ln}"))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """How aggregation runs (fl/async_strategies.py + the sync loop)."""
    mode: str = "sync"
    rounds: int = 3
    buffer_k: int = 0
    staleness_exponent: float = 0.5
    max_staleness: int = 0
    staleness_adaptive: bool = False
    quorum_fraction: float = 1.0
    round_deadline_s: float = 0.0
    region_quorum: float = 0.5
    relay_conns: int = 8
    # fold arriving updates into an O(model) streaming accumulator at
    # the hub instead of buffering O(clients) payloads (fedbuff/semisync)
    streaming_hub: bool = False


@dataclasses.dataclass(frozen=True)
class SplitSpec:
    """The vertical/split-FL cut (fl/vertical.py; mode="vertical" only).

    ``cut_layer`` is the boundary index into the model's layer list: the
    feature parties own layers ``[0, cut_layer)`` (the bottom), the label
    party owns ``[cut_layer, L)`` (the top). ``batches_per_round`` is how
    many forward-activation / backward-gradient exchanges each party runs
    per aggregation round; ``activation_codec`` compresses the per-batch
    activation/gradient wires through the same CompressStage machinery as
    model updates ("none" | qsgd[:block] | topk[:frac])."""
    cut_layer: int = 1
    batches_per_round: int = 8
    activation_codec: str = "none"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One complete, declarative experiment description."""
    name: str = "scenario"
    seed: int = 0
    topology: TopologySpec = TopologySpec()
    fleet: FleetSpec = FleetSpec()
    channel: ChannelSpec = ChannelSpec()
    faults: FaultSpec = FaultSpec()
    strategy: StrategySpec = StrategySpec()
    split: SplitSpec = SplitSpec()

    def __post_init__(self):
        # tolerate dict-form nested specs: Scenario(**sc.to_dict()) with
        # only *some* fields re-specified as dataclasses is an
        # established idiom, and it silently leaves the rest as plain
        # dicts — coerce them through the strict deserializer
        for field in ("topology", "fleet", "channel", "faults",
                      "strategy", "split"):
            v = getattr(self, field)
            if isinstance(v, dict):  # _NESTED is defined below; only
                # reached at call time, never during module import
                object.__setattr__(self, field,
                                   _from_dict(_NESTED[field], v, field))

    # -- validation --------------------------------------------------------
    def validate(self) -> "Scenario":
        from repro.compression.stages import make_codec, split_codecs
        from repro.core.backends import BACKEND_NAMES
        if self.channel.backend not in BACKEND_NAMES:
            raise ScenarioError(
                f"channel.backend: unknown backend "
                f"'{self.channel.backend}'; choose from {BACKEND_NAMES}")
        for field, spec in (("compression", self.channel.compression),
                            ("wire_codec", self.channel.wire_codec)):
            try:
                make_codec(spec)
            except KeyError as e:
                raise ScenarioError(f"channel.{field}: {e.args[0]}") from None
        try:
            split_codecs(self.channel.compression, self.channel.wire_codec)
        except ValueError as e:
            raise ScenarioError(f"channel: {e}") from None
        if self.strategy.mode not in MODES:
            raise ScenarioError(
                f"strategy.mode: unknown mode '{self.strategy.mode}'; "
                f"choose from {list(MODES)}")
        if self.split.cut_layer < 1:
            raise ScenarioError("split.cut_layer must be >= 1")
        if self.split.batches_per_round < 1:
            raise ScenarioError("split.batches_per_round must be >= 1")
        try:
            make_codec(self.split.activation_codec)
        except KeyError as e:
            raise ScenarioError(
                f"split.activation_codec: {e.args[0]}") from None
        if not 0.0 <= self.faults.link_loss < 1.0:
            raise ScenarioError("faults.link_loss must be in [0, 1)")
        if not 0.0 < self.strategy.quorum_fraction <= 1.0:
            raise ScenarioError("strategy.quorum_fraction must be in (0, 1]")
        if self.fleet.cohort_k < 0:
            raise ScenarioError("fleet.cohort_k must be >= 0")
        if self.fleet.train_s < 0:
            raise ScenarioError("fleet.train_s must be >= 0 (0 = tier default)")
        if self.fleet.cohort_k > self.topology.num_clients:
            raise ScenarioError(
                f"fleet.cohort_k ({self.fleet.cohort_k}) exceeds "
                f"topology.num_clients ({self.topology.num_clients})")
        if 0 < self.fleet.cohort_k < self.topology.num_clients and \
                self.strategy.mode not in ("fedbuff", "semisync"):
            raise ScenarioError(
                "fleet.cohort_k: cohort sampling applies to the event-"
                "driven fedbuff/semisync modes only")
        self.topology.check()  # bad preset/regions/edges, without building
        hosts = {"server"} | {f"client{i}"
                              for i in range(self.topology.num_clients)}
        n_inline = len(self.faults.blackouts)
        for i, b in enumerate(self.faults.all_blackouts()):
            # file-sourced windows validate by the same rules; label them
            # by their position in the trace so errors stay actionable
            where = (f"faults.blackouts[{i}]" if i < n_inline else
                     f"faults.blackouts_file entry {i - n_inline + 1} "
                     f"('{self.faults.blackouts_file}')")
            if b.t1 < b.t0 or b.t0 < 0:
                raise ScenarioError(
                    f"{where}: need 0 <= t0 <= t1 "
                    f"(got [{b.t0}, {b.t1}))")
            for end, name in ((b.src, "src"), (b.dst, "dst")):
                if end != "*" and end not in hosts:
                    raise ScenarioError(
                        f"{where}.{name}: '{end}' names no "
                        f"host in this topology (hosts: server, client0.."
                        f"client{self.topology.num_clients - 1}, or '*')")
            if b.src == "*":
                raise ScenarioError(
                    f"{where}.src must name a host "
                    f"(use dst='*' for the per-host form)")
        return self

    # -- (de)serialisation -------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "Scenario":
        return _from_dict(cls, data, "scenario")

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "Scenario":
        with open(path) as f:
            sc = cls.from_dict(json.load(f))
        return _anchor_blackouts_file(sc, path)

    @classmethod
    def from_fl_config(cls, cfg, *, tier: str = "small",
                       local_steps: int = 4,
                       store_fail_rate: float = 0.0) -> "Scenario":
        """The inverse bridge: lift a flat FLConfig into the declarative
        spec (legacy entry points — tests, examples — resolve through the
        same scenario runtime as ``--scenario`` files)."""
        return cls(
            name=f"fl:{cfg.mode}", seed=cfg.seed,
            topology=TopologySpec(kind=cfg.environment,
                                  num_clients=cfg.num_clients,
                                  relay_depth=getattr(cfg, "relay_depth",
                                                      1)),
            fleet=FleetSpec(tier=tier, local_steps=local_steps,
                            cohort_k=getattr(cfg, "cohort_k", 0)),
            channel=ChannelSpec(backend=cfg.backend,
                                compression=cfg.compression,
                                wire_codec=getattr(cfg, "wire_codec",
                                                   "none"),
                                chunk_mb=cfg.chunk_mb),
            faults=FaultSpec(link_loss=cfg.link_loss_rate,
                             store_fail_rate=store_fail_rate,
                             availability_trace=cfg.availability_trace),
            strategy=StrategySpec(
                mode=cfg.mode, rounds=cfg.rounds, buffer_k=cfg.buffer_k,
                staleness_exponent=cfg.staleness_exponent,
                max_staleness=cfg.max_staleness,
                staleness_adaptive=cfg.staleness_adaptive,
                quorum_fraction=cfg.quorum_fraction,
                round_deadline_s=cfg.round_deadline_s,
                region_quorum=cfg.region_quorum,
                relay_conns=getattr(cfg, "relay_conns", 8),
                streaming_hub=getattr(cfg, "streaming_hub", False)),
            split=SplitSpec(
                cut_layer=getattr(cfg, "cut_layer", 1),
                batches_per_round=getattr(cfg, "batches_per_round", 8),
                activation_codec=getattr(cfg, "activation_codec", "none")))

    # -- the bridge to the runtime config ----------------------------------
    def fl_config(self):
        """The equivalent flat FLConfig (what the strategies/driver read)."""
        from repro.configs.base import FLConfig
        return FLConfig(
            num_clients=self.topology.num_clients,
            backend=self.channel.backend,
            environment=self.topology.kind,
            rounds=self.strategy.rounds,
            quorum_fraction=self.strategy.quorum_fraction,
            round_deadline_s=self.strategy.round_deadline_s,
            seed=self.seed,
            mode=self.strategy.mode,
            buffer_k=self.strategy.buffer_k,
            staleness_exponent=self.strategy.staleness_exponent,
            max_staleness=self.strategy.max_staleness,
            staleness_adaptive=self.strategy.staleness_adaptive,
            compression=self.channel.compression,
            wire_codec=self.channel.wire_codec,
            chunk_mb=self.channel.chunk_mb,
            availability_trace=self.faults.availability_trace,
            link_loss_rate=self.faults.link_loss,
            region_quorum=self.strategy.region_quorum,
            relay_conns=self.strategy.relay_conns,
            relay_depth=self.topology.relay_depth,
            cohort_k=self.fleet.cohort_k,
            streaming_hub=self.strategy.streaming_hub,
            cut_layer=self.split.cut_layer,
            batches_per_round=self.split.batches_per_round,
            activation_codec=self.split.activation_codec)


# ---------------------------------------------------------------------------
# multi-tenant scenarios: N jobs on one fabric
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One tenant job of a multi-tenant deployment: a full Scenario plus
    its co-scheduling knobs. ``priority`` feeds the fabric's admission
    policy (higher preempts under ``policy="priority"``); ``weight``
    scales the job's fair-share grant (``cap * w_i / sum(w)`` under
    ``policy="fair-share"`` — weight 1.0 everywhere reproduces the
    unweighted ``cap / k`` split exactly); ``start_s`` offsets the job's
    bootstrap on the shared clock; ``rounds`` caps the job's
    aggregations (0 = the scenario's own ``strategy.rounds``)."""
    name: str
    scenario: Scenario = Scenario()
    priority: int = 0
    weight: float = 1.0
    start_s: float = 0.0
    rounds: int = 0

    def cap(self) -> int:
        return self.rounds or self.scenario.strategy.rounds


@dataclasses.dataclass(frozen=True)
class MultiScenario:
    """N co-scheduled jobs sharing one topology, one fabric, one clock.

    Every job must declare the *same* topology — tenants contend for one
    physical network, they don't each get their own. The fabric spec
    defaults to fifo admission over shared links (contention on), since
    a multi-tenant run with isolated links is just N solo runs."""
    name: str = "multi"
    fabric: FabricSpec = FabricSpec(policy="fifo", shared_links=True)
    jobs: Tuple[JobSpec, ...] = ()

    def validate(self) -> "MultiScenario":
        if not self.jobs:
            raise ScenarioError("jobs: a MultiScenario needs >= 1 job")
        names = [j.name for j in self.jobs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ScenarioError(f"jobs: duplicate job name(s) {dupes}")
        base = self.jobs[0].scenario.topology
        for i, j in enumerate(self.jobs):
            where = f"jobs[{i}] ('{j.name}')"
            if not j.name or "::" in j.name:
                raise ScenarioError(
                    f"{where}: job names must be non-empty and free of "
                    f"'::' (the fabric's tenant separator)")
            if j.cap() < 1:
                raise ScenarioError(
                    f"{where}: needs a positive aggregation cap "
                    f"(rounds= or scenario.strategy.rounds)")
            if not j.weight > 0:
                raise ScenarioError(
                    f"{where}: weight must be > 0 (got {j.weight})")
            if j.scenario.strategy.mode not in ("fedbuff", "semisync"):
                raise ScenarioError(
                    f"{where}: co-scheduling drives the event-driven "
                    f"fedbuff/semisync modes (got "
                    f"'{j.scenario.strategy.mode}')")
            if j.scenario.topology != base:
                raise ScenarioError(
                    f"{where}: topology differs from jobs[0]'s — tenants "
                    f"share ONE physical network; declare the same "
                    f"topology in every job")
            try:
                j.scenario.validate()
            except ScenarioError as e:
                raise ScenarioError(f"{where}: {e}") from None
        return self

    # -- (de)serialisation -------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "MultiScenario":
        return _from_dict(cls, data, "multi")

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "MultiScenario":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "MultiScenario":
        with open(path) as f:
            ms = cls.from_dict(json.load(f))
        jobs = tuple(dataclasses.replace(
            j, scenario=_anchor_blackouts_file(j.scenario, path))
            for j in ms.jobs)
        return dataclasses.replace(ms, jobs=jobs)


def _anchor_blackouts_file(sc: Scenario, spec_path: str) -> Scenario:
    """Resolve a relative ``faults.blackouts_file`` against the spec
    file's directory, so a scenario pack stays relocatable."""
    bf = sc.faults.blackouts_file
    if not bf or os.path.isabs(bf):
        return sc
    anchored = os.path.join(os.path.dirname(os.path.abspath(spec_path)), bf)
    return dataclasses.replace(
        sc, faults=dataclasses.replace(sc.faults, blackouts_file=anchored))


# ---------------------------------------------------------------------------
# strict recursive deserialisation
# ---------------------------------------------------------------------------

_NESTED = {"topology": TopologySpec, "fleet": FleetSpec,
           "channel": ChannelSpec, "faults": FaultSpec,
           "strategy": StrategySpec, "split": SplitSpec}


def _from_dict(cls, data, path):
    if not isinstance(data, dict):
        raise ScenarioError(
            f"{path}: expected an object, got {type(data).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - set(fields))
    if unknown:
        raise ScenarioError(
            f"{path}: unknown key(s) {unknown}; valid keys: "
            f"{sorted(fields)}")
    kw = {}
    for k, v in data.items():
        sub = _NESTED.get(k) if cls is Scenario else None
        if sub is not None:
            kw[k] = _from_dict(sub, v, f"{path}.{k}")
        elif cls is TopologySpec and k == "edges":
            if not isinstance(v, (list, tuple)):
                raise ScenarioError(f"{path}.edges: expected a list")
            kw[k] = tuple(_from_dict(EdgeSpec, e, f"{path}.edges[{i}]")
                          for i, e in enumerate(v))
        elif cls is FaultSpec and k == "blackouts":
            if not isinstance(v, (list, tuple)):
                raise ScenarioError(f"{path}.blackouts: expected a list")
            kw[k] = tuple(_from_dict(BlackoutSpec, b,
                                     f"{path}.blackouts[{i}]")
                          for i, b in enumerate(v))
        elif cls is MultiScenario and k == "jobs":
            if not isinstance(v, (list, tuple)):
                raise ScenarioError(f"{path}.jobs: expected a list")
            kw[k] = tuple(_from_dict(JobSpec, j, f"{path}.jobs[{i}]")
                          for i, j in enumerate(v))
        elif cls is MultiScenario and k == "fabric":
            kw[k] = _from_dict(FabricSpec, v, f"{path}.fabric")
        elif cls is JobSpec and k == "scenario":
            kw[k] = _from_dict(Scenario, v, f"{path}.scenario")
        elif isinstance(v, list):
            kw[k] = tuple(v)
        else:
            kw[k] = v
    try:
        return cls(**kw)
    except (TypeError, ValueError) as e:
        raise ScenarioError(f"{path}: {e}") from None


# ---------------------------------------------------------------------------
# CLI override layering
# ---------------------------------------------------------------------------

def with_overrides(scenario: Scenario, overrides: dict) -> Scenario:
    """Layer dotted-path overrides onto a scenario: ``{"channel.backend":
    "grpc"}``. ``None`` values are skipped — exactly the contract
    ``fl_train`` needs, where an unset CLI flag must not clobber the
    loaded spec."""
    for path, value in overrides.items():
        if value is None:
            continue
        parts = path.split(".")
        scenario = _replace_path(scenario, parts, value)
    return scenario


def _replace_path(node, parts, value):
    if len(parts) == 1:
        if not any(f.name == parts[0] for f in dataclasses.fields(node)):
            raise ScenarioError(
                f"override: '{parts[0]}' is not a field of "
                f"{type(node).__name__}")
        return dataclasses.replace(node, **{parts[0]: value})
    child = getattr(node, parts[0])
    return dataclasses.replace(
        node, **{parts[0]: _replace_path(child, parts[1:], value)})
