"""Scenario -> runtime: one place that turns the declarative spec into
the live objects (environment graph, fabric + fault model, object store,
backends). Every entry point — ``fl_train``, the paper-figure benchmarks,
tests — goes through here, so the spec really is the single description
from CLI to fabric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.netsim import NCAL, Environment, LinkFaultModel
from repro.core.objectstore import ObjectStore
from repro.core.transport import Fabric
from repro.scenario.spec import Scenario


@dataclasses.dataclass
class Runtime:
    """The built deployment a scenario describes."""
    scenario: Scenario
    env: Environment
    fabric: Fabric
    store: ObjectStore

    def make_backend(self, host_id: str, *, compression=None,
                     chunk_mb: Optional[float] = None, **kw):
        """A backend on this runtime's fabric carrying the scenario's
        channel spec. ``compression`` defaults to the spec's payload
        codec; pass ``compression=None`` explicitly via
        ``compression="none"`` when a path must stay uncompressed."""
        from repro.core.backends import make_backend
        ch = self.scenario.channel
        comp = ch.compression if compression is None else compression
        return make_backend(
            ch.backend, self.env, self.fabric, host_id, store=self.store,
            compression=None if comp in ("", "none") else comp,
            wire_codec=ch.wire_codec,
            chunk_mb=ch.chunk_mb if chunk_mb is None else chunk_mb, **kw)


def fault_model_for(scenario: Scenario) -> Optional[LinkFaultModel]:
    """The deterministic fault injector the spec asks for (None when the
    scenario is fault-free — the exact legacy timing path)."""
    f = scenario.faults
    host_bo: Dict[str, list] = {}
    edge_bo: Dict[tuple, list] = {}
    for b in f.all_blackouts():
        window = (float(b.t0), float(b.t1))
        if b.dst == "*":
            # per-host form: every link touching src goes dark — this is
            # LinkFaultModel's original blackout machinery
            host_bo.setdefault(b.src, []).append(window)
        else:
            edge_bo.setdefault((b.src, b.dst), []).append(window)
            if b.symmetric:
                edge_bo.setdefault((b.dst, b.src), []).append(window)
    if f.link_loss <= 0.0 and not host_bo and not edge_bo:
        return None
    return LinkFaultModel(chunk_loss_rate=f.link_loss,
                          max_retries=f.max_retries,
                          nack_rtts=f.nack_rtts, seed=scenario.seed,
                          blackouts=host_bo, edge_blackouts=edge_bo)


def build_runtime(scenario: Scenario) -> Runtime:
    """Validate + build the deployment: topology graph, fabric (with the
    fault model installed), object store, endpoints registered."""
    scenario.validate()
    env = scenario.topology.build()
    fabric = Fabric(env, fault_model=fault_model_for(scenario))
    store = ObjectStore(NCAL, fail_rate=scenario.faults.store_fail_rate)
    for h in [env.server] + list(env.clients):
        fabric.register(h.host_id)
    return Runtime(scenario, env, fabric, store)
