"""The assigned input-shape set and the (arch x shape) applicability matrix."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# families with sub-quadratic sequence mixing (may run long_500k)
_SUBQUADRATIC = {"ssm", "hybrid"}


def applicability(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (runnable, reason). Reason explains documented skips (DESIGN.md)."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, f"{cfg.name} is encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, (
            f"{cfg.name} uses full attention; long_500k requires sub-quadratic "
            "sequence mixing (run only for ssm/hybrid archs)")
    return True, ""


def runnable_cells(configs: dict):
    """All (arch, shape) pairs; yields (cfg, shape, runnable, reason)."""
    for name in configs:
        cfg = configs[name]
        for sname in SHAPE_ORDER:
            shape = SHAPES[sname]
            ok, reason = applicability(cfg, shape)
            yield cfg, shape, ok, reason
