"""Architecture configs: ``get_config(arch_id)`` + reduced smoke variants.

All 10 assigned archs (+ the paper's 4 payload tiers in paper_tiers.py).
Sources per the assignment brief; deviations documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (FLConfig, MeshConfig, ModelConfig,
                                ShapeConfig, TrainConfig)
from repro.configs.shapes import (SHAPES, SHAPE_ORDER, applicability,
                                  runnable_cells)

# ---------------------------------------------------------------------------
# the 10 assigned architectures
# ---------------------------------------------------------------------------

XLSTM_1_3B = ModelConfig(
    name="xlstm-1.3b", family="ssm",  # [arXiv:2405.04517; unverified]
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4, d_ff=0,
    vocab_size=50304, slstm_every=8, ssm_expand=2, mlstm_chunk=256)

QWEN3_8B = ModelConfig(
    name="qwen3-8b", family="dense",  # [hf:Qwen/Qwen3-8B; hf]
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=12288,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6)

DEEPSEEK_67B = ModelConfig(
    name="deepseek-67b", family="dense",  # [arXiv:2401.02954; hf]
    num_layers=95, d_model=8192, num_kv_heads=8, num_heads=64, d_ff=22016,
    vocab_size=102400, head_dim=128)

GRANITE_3_8B = ModelConfig(
    name="granite-3-8b", family="dense",  # [hf:ibm-granite/granite-3.0; hf]
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=12800,
    vocab_size=49155, head_dim=128, tie_embeddings=True)

STABLELM_12B = ModelConfig(
    name="stablelm-12b", family="dense",  # [hf:stabilityai/stablelm-2; hf]
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, d_ff=13824,
    vocab_size=100352, head_dim=160)

ZAMBA2_1_2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid",  # [arXiv:2411.15242; hf]
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192,
    vocab_size=32000, head_dim=64, ssm_state=64, ssm_head_dim=64,
    ssm_expand=2, attn_every=6, shared_attn_lora_rank=64)

GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",  # [hf:ibm-granite; hf]
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, d_ff=512,
    vocab_size=49155, head_dim=64, num_experts=32, experts_per_token=8,
    moe_interleave=1, tie_embeddings=True)

LLAMA4_MAVERICK = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",  # [hf:meta-llama; unverified]
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, num_experts=128, experts_per_token=1,
    moe_interleave=2, d_ff_dense=16384, num_shared_experts=1,
    capacity_factor=1.25)

HUBERT_XLARGE = ModelConfig(
    name="hubert-xlarge", family="audio",  # [arXiv:2106.07447; unverified]
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, external_embeddings=True)

LLAMA32_VISION_11B = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",  # [hf:meta-llama; unverified]
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=128256, head_dim=128, cross_attn_every=5,
    num_image_tokens=1601)

ARCHS = {c.name: c for c in (
    XLSTM_1_3B, QWEN3_8B, DEEPSEEK_67B, GRANITE_3_8B, STABLELM_12B,
    ZAMBA2_1_2B, GRANITE_MOE_1B, LLAMA4_MAVERICK, HUBERT_XLARGE,
    LLAMA32_VISION_11B)}
ARCH_ORDER = list(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {list(ARCHS)}")
    return ARCHS[name]


# ---------------------------------------------------------------------------
# reduced same-family smoke configs (CPU: one fwd/train step, tiny shapes)
# ---------------------------------------------------------------------------

def smoke_config(name: str) -> ModelConfig:
    cfg = get_config(name)
    common = dict(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                  vocab_size=128, remat="none", attn_chunk=32,
                  moe_group_size=64)
    if cfg.family == "ssm":
        return dataclasses.replace(
            cfg, name=f"{cfg.name}-smoke", num_layers=4, slstm_every=2,
            mlstm_chunk=8, **{**common, "num_kv_heads": 4})
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, name=f"{cfg.name}-smoke", num_layers=5, attn_every=2,
            ssm_state=8, ssm_head_dim=16, ssm_chunk=8, d_ff=128,
            shared_attn_lora_rank=4,
            **{**common, "num_kv_heads": 4})
    if cfg.family == "moe":
        k = cfg.moe_interleave
        return dataclasses.replace(
            cfg, name=f"{cfg.name}-smoke", num_layers=2 * k, d_ff=32,
            d_ff_dense=64 if cfg.d_ff_dense else 0, num_experts=4,
            experts_per_token=min(cfg.experts_per_token, 2), **common)
    if cfg.family == "vlm":
        return dataclasses.replace(
            cfg, name=f"{cfg.name}-smoke", num_layers=2 * cfg.cross_attn_every,
            d_ff=128, num_image_tokens=8, **common)
    # dense / audio
    return dataclasses.replace(cfg, name=f"{cfg.name}-smoke", num_layers=2,
                               d_ff=128, **common)
