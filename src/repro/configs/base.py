"""Config dataclasses for models, shapes, meshes and training.

Everything in the framework is driven by these frozen dataclasses; the CLI
(``--arch``, ``--shape``, ``--mesh``) resolves to instances defined in
``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the LM families (dense/moe/ssm/hybrid/audio/vlm)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qk_norm: bool = False
    causal: bool = True
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_interleave: int = 1  # MoE every k-th layer (1 = every layer)
    d_ff_dense: int = 0  # FFN width of non-MoE layers when interleaved
    num_shared_experts: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0  # zamba2: shared attention block every k mamba blocks
    shared_attn_lora_rank: int = 0
    slstm_every: int = 0  # xlstm: sLSTM block every k blocks (others mLSTM)
    mlstm_chunk: int = 256

    # VLM
    cross_attn_every: int = 0  # cross-attention layer every k layers
    num_image_tokens: int = 0
    vision_d_model: int = 0

    # audio (encoder-only): inputs are precomputed frame embeddings
    external_embeddings: bool = False

    # embeddings / io
    tie_embeddings: bool = False
    mlp_gelu: bool = False  # 2-matrix GELU MLP (ViT/BERT) instead of SwiGLU

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # memory policy
    remat: str = "full"  # none | dots | full
    attn_chunk: int = 1024  # flash-style KV chunking for prefill/train
    block_causal: bool = True  # lower-triangular block schedule (skip masked blocks)

    # MoE dispatch
    moe_group_size: int = 2048
    capacity_factor: float = 1.25

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads must be divisible by num_kv_heads")

    # ------------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def moe_layer_mask(self) -> Sequence[bool]:
        """True for layers that carry a MoE FFN."""
        if self.num_experts == 0:
            return [False] * self.num_layers
        k = self.moe_interleave
        # MoE on layers (k-1, 2k-1, ...) — matches Llama-4 style interleaving.
        return [(i % k) == (k - 1) for i in range(self.num_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (used for payload tiers + MODEL_FLOPS)."""
        from repro.models import registry  # lazy to avoid cycles

        return registry.param_count(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Physical mesh + logical-axis resolution plan."""

    shape: tuple
    axis_names: tuple
    # mesh axes that implement FSDP-style parameter/optimizer sharding
    fsdp_axes: tuple = ("data",)
    # mesh axes that implement tensor parallelism
    tensor_axes: tuple = ("model",)
    # mesh axes over which the batch is split
    batch_axes: tuple = ("pod", "data")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        if name not in self.axis_names:
            return 1
        return self.shape[self.axis_names.index(name)]


SINGLE_POD_MESH = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD_MESH = MeshConfig(
    shape=(2, 16, 16),
    axis_names=("pod", "data", "model"),
    fsdp_axes=("data",),
)
# FSDP over pod+data: used for the very largest models (llama4-maverick).
MULTI_POD_MESH_FSDP_POD = dataclasses.replace(MULTI_POD_MESH, fsdp_axes=("pod", "data"))
SMOKE_MESH = MeshConfig(shape=(1, 1), axis_names=("data", "model"))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / step configuration."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"  # adamw | sgd
    moment_dtype: str = "float32"  # float32 | bfloat16 (memory-reduced states)
    microbatches: int = 1  # gradient accumulation steps per global step
    # cross-pod (cross-silo) sync policy — the paper's FL round at pod scale
    crosspod_sync_every: int = 1  # 1 = fully synchronous DP over 'pod'
    crosspod_compression: str = "none"  # none | int8 | topk


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Cross-silo federated learning round configuration."""

    num_clients: int = 7
    clients_per_round: int = 7
    local_epochs: int = 1
    local_steps: int = 10
    rounds: int = 5
    backend: str = "grpc+s3"
    # topology preset (scenario.TOPOLOGY_PRESETS): the legacy trio plus
    # the graph-native star | ring | multi_hub
    environment: str = "geo_distributed"
    quorum_fraction: float = 1.0  # server aggregates once this fraction reported
    round_deadline_s: float = 0.0  # 0 = no deadline (wait for quorum only)
    server_lr: float = 1.0
    seed: int = 0

    # event-driven runtime (fl/scheduler.py; mode != "sync" selects a
    # strategy from fl/async_strategies.py)
    mode: str = "sync"  # sync | fedbuff | semisync | hier | vertical
    buffer_k: int = 0  # fedbuff merge buffer; 0 -> max(2, num_clients // 2)
    staleness_exponent: float = 0.5  # alpha in the (1+s)^-alpha discount
    max_staleness: int = 0  # discard updates staler than this; 0 = keep all
    # FedAsync-style adaptivity: scale alpha by each update's percentile
    # rank among observed staleness (fl/async_strategies.py)
    staleness_adaptive: bool = False
    # fleet-scale knobs (fl/scheduler.py): seeded K-of-N cohort sampling
    # for fedbuff/semisync (0 = whole fleet), and streaming hub
    # aggregation (fold updates into one O(model) accumulator instead of
    # buffering O(clients) payloads at the server)
    cohort_k: int = 0
    streaming_hub: bool = False

    # vertical / split FL (fl/vertical.py; mode == "vertical"): layer
    # boundary of the bottom/top cut, per-batch exchanges per round, and
    # the codec on the activation/gradient wires
    cut_layer: int = 1
    batches_per_round: int = 8
    activation_codec: str = "none"

    # wire pipeline (core/channel.py): gradient compression on the client
    # update path — and, in hier mode, on the relay WAN hop only (the LAN
    # reduce stays exact) — plus chunked send pipelining
    compression: str = "none"  # none | qsgd[:block] | topk[:frac]
    # byte-domain wire codec on every backend channel (lossless, so it
    # rides all modes and both directions): none | zlib[:level]
    wire_codec: str = "none"
    chunk_mb: float = 0.0  # 0 = unchunked wires

    # fault & churn injection (fl/fault.py, core/netsim.LinkFaultModel)
    # availability trace spec: "" = no churn; "auto:UP/DOWN" = generated
    # exponential up/down periods; else explicit "client0:leave@T,join@T"
    availability_trace: str = ""
    link_loss_rate: float = 0.0  # per-chunk wire loss on every direct link
    region_quorum: float = 0.5  # hier: min live fraction per region
    relay_conns: int = 8  # hier: WAN-hop connection multiplexing per relay
    relay_depth: int = 1  # hier: relay-tree levels (1 = single-tier)

    # -- the one FLConfig <-> Scenario conversion ------------------------
    def to_scenario(self, *, tier: str = "small", local_steps: int = 4,
                    store_fail_rate: float = 0.0):
        """Lift this flat config into the declarative ``Scenario`` spec.

        This and its inverse, ``Scenario.fl_config()``, are THE two
        conversion points between the flat runtime config and the
        declarative spec — every entry point (``fl_train``, tests,
        examples) routes through them, so a field added to one side must
        be added to both or the round-trip tests fail. Implemented by
        ``Scenario.from_fl_config`` (the Scenario side owns the field
        mapping); ``tier`` / ``local_steps`` / ``store_fail_rate`` are
        deployment knobs with no FLConfig field."""
        from repro.scenario import Scenario
        return Scenario.from_fl_config(self, tier=tier,
                                       local_steps=local_steps,
                                       store_fail_rate=store_fail_rate)
