"""The paper's four payload tiers (§IV-B) and their builders.

Tier sizes from the paper: Small=ResNet56 (591,322 params, 2.39 MB),
Medium=MobileNetV3 (5,152,518, 19.85 MB), Big=DistilBERT (66,362,880,
253.19 MB), Large=ViT-Large (307,432,234, 1,243.14 MB).

``payload_bytes`` below are the *paper's exact numbers* — the netsim
benchmarks transfer exactly these byte counts so Table I / Fig 4 / Fig 5
reproduce the paper's regime. The real JAX models land within a few percent
of the reference counts (implementation deltas documented in DESIGN.md) and
are used by the live FL training path.
"""
from __future__ import annotations

import dataclasses

MB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class Tier:
    name: str
    model: str
    ref_params: int
    payload_bytes: int  # fp32 payload, paper's Table/§IV-B numbers
    dataset: str
    # simulated 1-epoch local training time (s), calibrated from Fig 5's
    # training bars. The LAN testbed machines carry 8x RTX 5000 each while
    # the cloud clients are single-T4 g4dn.2xlarge (§IV-A), hence the
    # per-environment split — this is what lets the paper's "~9x slower
    # gRPC on LAN, large" and "3.5-3.8x gRPC+S3 geo, large" coexist.
    train_s_cloud: float
    train_s_lan: float

    def train_s(self, environment: str) -> float:
        return self.train_s_lan if environment == "lan" else self.train_s_cloud

    @property
    def train_s_per_round(self) -> float:  # back-compat: cloud value
        return self.train_s_cloud

    def async_knobs(self, environment: str, num_clients: int = 7) -> dict:
        """Recommended event-driven runtime knobs for this tier: merge
        buffer of half the fleet (FedBuff's sweet spot at cross-silo
        scale), a semi-sync deadline of ~2.5x the calibrated local epoch
        (covers compute jitter without stalling on stragglers), and the
        standard polynomial staleness discount."""
        return {"buffer_k": max(2, num_clients // 2),
                "round_deadline_s": 2.5 * self.train_s(environment),
                "staleness_exponent": 0.5}


SMALL = Tier("small", "resnet56", 591_322, int(2.39 * MB), "gld23k",
             20.0, 2.5)
MEDIUM = Tier("medium", "mobilenetv3", 5_152_518, int(19.85 * MB), "gld23k",
              30.0, 3.8)
BIG = Tier("big", "distilbert", 66_362_880, int(253.19 * MB), "20news",
           60.0, 7.5)
LARGE = Tier("large", "vit-large", 307_432_234, int(1243.14 * MB), "gld23k",
             130.0, 16.0)

TIERS = {t.name: t for t in (SMALL, MEDIUM, BIG, LARGE)}
TIER_ORDER = ["small", "medium", "big", "large"]


def build_tier_model(name: str):
    """Returns (model_obj, init_fn(rng)->params). Real JAX models."""
    from repro.models.bert import BertConfig, DistilBert
    from repro.models.vision import (MobileNetConfig, MobileNetV3, ResNet,
                                     ResNetConfig, ViT, ViTConfig)

    if name == "small":
        m = ResNet(ResNetConfig())
        return m, m.init
    if name == "medium":
        m = MobileNetV3(MobileNetConfig())
        return m, m.init
    if name == "big":
        m = DistilBert(BertConfig())
        return m, m.init
    if name == "large":
        m = ViT(ViTConfig())
        return m, m.init
    raise KeyError(name)
