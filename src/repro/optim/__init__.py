from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    clip_by_global_norm, make_optimizer,
                                    sgd_init, sgd_update)
from repro.optim.schedules import cosine_warmup

__all__ = ["OptState", "adamw_init", "adamw_update", "sgd_init",
           "sgd_update", "make_optimizer", "clip_by_global_norm",
           "cosine_warmup"]
