"""Pytree optimizers (no optax dependency). Moments can be kept in bf16 to
halve optimizer-state HBM (used by the 400B config); states shard exactly
like their parameters (FSDP), so the axes tree reuses the param axes tree.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    count: jax.Array
    m: dict
    v: dict  # empty dict for sgd


def clip_by_global_norm(grads, max_norm: float):
    if not max_norm:
        return grads, jnp.asarray(0.0)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, cfg: TrainConfig) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return OptState(count=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def adamw_update(grads, state: OptState, params, lr, cfg: TrainConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(count=count, m=new_m, v=new_v), gnorm


# ---------------------------------------------------------------------------
# SGD (FL clients commonly run plain local SGD)
# ---------------------------------------------------------------------------

def sgd_init(params, cfg: TrainConfig) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    return OptState(count=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
                    v={})


def sgd_update(grads, state: OptState, params, lr, cfg: TrainConfig,
               momentum: float = 0.9):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, m):
        g32 = g.astype(jnp.float32)
        m32 = momentum * m.astype(jnp.float32) + g32
        new_p = p.astype(jnp.float32) - lr * m32
        return new_p.astype(p.dtype), m32.astype(m.dtype)

    out = jax.tree.map(upd, params, grads, state.m)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(count=state.count + 1, m=new_m, v={}), gnorm


def make_optimizer(cfg: TrainConfig):
    if cfg.optimizer == "adamw":
        return adamw_init, adamw_update
    if cfg.optimizer == "sgd":
        return sgd_init, lambda g, s, p, lr, c: sgd_update(g, s, p, lr, c)
    raise ValueError(cfg.optimizer)


def opt_state_axes(param_axes, cfg: TrainConfig):
    """Logical axes tree for OptState (moments shard like params)."""
    if cfg.optimizer == "adamw":
        return OptState(count=None, m=param_axes, v=param_axes)
    return OptState(count=None, m=param_axes, v={})
