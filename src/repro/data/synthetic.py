"""Deterministic synthetic data pipeline.

Two flavours:
* LM token streams (markov-ish structure so loss actually decreases) for the
  at-scale archs;
* per-silo non-IID labelled datasets (images or token sequences) for the
  cross-silo FL path — each silo gets a Dirichlet-skewed label distribution,
  the standard FL heterogeneity model.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                       vocab: int):
    """Structured token stream: next token = (3*prev + noise) % vocab, which
    a causal model can learn quickly (used to check loss decreases)."""
    t0 = rng.integers(0, vocab, size=(batch, 1))
    toks = [t0]
    for _ in range(seq):
        nxt = (3 * toks[-1] + rng.integers(0, 7, size=(batch, 1))) % vocab
        toks.append(nxt)
    toks = np.concatenate(toks, axis=1)
    return {"tokens": toks[:, :seq].astype(np.int32),
            "targets": toks[:, 1:seq + 1].astype(np.int32)}


def lm_batch_iterator(seed: int, batch: int, seq: int, vocab: int) -> Iterator:
    rng = np.random.default_rng(seed)
    while True:
        yield synthetic_lm_batch(rng, batch, seq, vocab)


# ---------------------------------------------------------------------------
# per-silo FL datasets
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SiloDataset:
    """One silo's local shard."""
    silo_id: int
    kind: str  # image | text
    features: np.ndarray  # images (N,H,W,3) or tokens (N,S)
    labels: np.ndarray  # (N,)
    num_classes: int

    def num_examples(self) -> int:
        return len(self.labels)

    def batches(self, batch_size: int, seed: int = 0) -> Iterator[dict]:
        rng = np.random.default_rng(seed * 1000 + self.silo_id)
        n = self.num_examples()
        while True:
            idx = rng.choice(n, size=min(batch_size, n), replace=False)
            key = "images" if self.kind == "image" else "tokens"
            yield {key: self.features[idx], "labels": self.labels[idx]}


def make_silo_datasets(num_silos: int, *, kind: str = "image",
                       examples_per_silo: int = 128, num_classes: int = 16,
                       image_size: int = 32, seq_len: int = 64,
                       vocab: int = 30522, alpha: float = 0.5,
                       seed: int = 0):
    """Dirichlet(alpha) label skew across silos; class-conditional synthetic
    features so that learning is possible (class-dependent mean patterns)."""
    rng = np.random.default_rng(seed)
    proportions = rng.dirichlet([alpha] * num_classes, size=num_silos)
    class_dirs = rng.normal(size=(num_classes, 8)).astype(np.float32)
    silos = []
    for sid in range(num_silos):
        labels = rng.choice(num_classes, size=examples_per_silo,
                            p=proportions[sid]).astype(np.int32)
        if kind == "image":
            base = rng.normal(
                size=(examples_per_silo, image_size, image_size, 3)
            ).astype(np.float32) * 0.3
            # class-dependent low-frequency pattern
            xs = np.linspace(0, np.pi * 2, image_size, dtype=np.float32)
            grid = np.stack([np.sin(np.outer(xs * (k % 4 + 1), xs))
                             for k in range(num_classes)])
            feats = base + grid[labels][..., None]
            silos.append(SiloDataset(sid, "image", feats, labels, num_classes))
        else:
            toks = rng.integers(0, vocab, size=(examples_per_silo, seq_len))
            # class-dependent token bias in the first positions
            toks[:, :8] = (labels[:, None] * 37 +
                           np.arange(8)[None]) % min(vocab, 1000)
            silos.append(SiloDataset(sid, "text", toks.astype(np.int32),
                                     labels, num_classes))
    return silos
