from repro.data.synthetic import (SiloDataset, lm_batch_iterator,
                                  make_silo_datasets, synthetic_lm_batch)

__all__ = ["SiloDataset", "make_silo_datasets", "lm_batch_iterator",
           "synthetic_lm_batch"]
