"""Checkpointing: manifest + per-leaf npz, async writes, integrity checksums,
keep-last-k GC, and **resharding restore** (a checkpoint saved on one mesh
can be restored onto any other mesh — the elastic-scaling path).

Layout:
    <dir>/step_000123/
        manifest.json   # step, leaf index, shapes/dtypes, crc32s, meta
        arrays.npz      # flattened key -> host ndarray
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, *, meta: Optional[dict] = None,
                    blocking: bool = True):
    """Device arrays are fetched to host then written (npz + manifest)."""
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    named = _flatten_with_names(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in named.items()}

    def _write():
        manifest = {"step": step, "meta": meta or {}, "leaves": {}}
        savable = {}
        for k, v in host.items():
            manifest["leaves"][k] = {
                "shape": list(v.shape), "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
            # npz can't represent ml_dtypes (bfloat16 etc.): store raw bytes
            if v.dtype.kind not in "biufc":
                v = np.ascontiguousarray(v).view(np.uint8)
            savable[k] = v
        np.savez(os.path.join(tmp, "arrays.npz"), **savable)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def load_checkpoint(directory: str, template, *, step: Optional[int] = None,
                    shardings=None, verify: bool = True):
    """Restore into ``template``'s structure. ``shardings``: optional pytree
    of NamedSharding (same structure) — enables cross-mesh restore: arrays
    are device_put with the *new* sharding regardless of how they were saved.
    Returns (tree, step, meta)."""
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    named_t = _flatten_with_names(template)
    named_s = _flatten_with_names(shardings) if shardings is not None else {}
    out = {}
    for k, tmpl in named_t.items():
        if k not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {k}")
        v = arrays[k]
        info = manifest["leaves"][k]
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(v).tobytes())
            if crc != info["crc32"]:
                raise IOError(f"checksum mismatch for {k}")
        want = np.dtype(jax.numpy.dtype(info["dtype"]))
        if v.dtype != want:  # uint8-stored ml_dtypes leaf: reinterpret
            v = np.frombuffer(np.ascontiguousarray(v).tobytes(),
                              dtype=want).reshape(info["shape"])
        if tuple(v.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {v.shape} vs template {tmpl.shape}")
        # cast via jnp: numpy lacks cast rules for bfloat16 & friends
        arr = jax.numpy.asarray(v)
        if arr.dtype != tmpl.dtype:
            arr = arr.astype(tmpl.dtype)
        if k in named_s and named_s[k] is not None:
            out[k] = jax.device_put(arr, named_s[k])
        else:
            out[k] = arr
    # rebuild tree in template structure
    flat_t, treedef = jax.tree.flatten(template)
    keys = list(_flatten_with_names(template).keys())
    leaves = [out[k] for k in keys]
    return jax.tree.unflatten(treedef, leaves), step, manifest["meta"]


class CheckpointManager:
    """Async checkpointing with keep-last-k GC and crash-safe publish."""

    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_writes = async_writes
        self._pending: list = []
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree, meta: Optional[dict] = None):
        t = save_checkpoint(self.directory, step, tree, meta=meta,
                            blocking=not self.async_writes)
        if t is not None:
            self._pending.append(t)
        self._gc()

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def restore(self, template, *, step=None, shardings=None):
        self.wait()
        return load_checkpoint(self.directory, template, step=step,
                               shardings=shardings)

    def latest_step(self) -> Optional[int]:
        steps = list_steps(self.directory)
        return steps[-1] if steps else None

    def _gc(self):
        self.wait()
        steps = list_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
