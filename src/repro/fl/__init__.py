from repro.fl.aggregator import fedavg, fedavg_quantized
from repro.fl.client import FLClient
from repro.fl.server import FLServer, RoundReport

__all__ = ["FLServer", "FLClient", "RoundReport", "fedavg",
           "fedavg_quantized"]
