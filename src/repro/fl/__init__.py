from repro.fl.aggregator import (fedavg, fedavg_quantized, staleness_weight)
from repro.fl.async_strategies import (AggregationStrategy, FedBuffStrategy,
                                       HierarchicalStrategy, SemiSyncStrategy,
                                       make_strategy)
from repro.fl.client import FLClient
from repro.fl.fault import (AvailabilityTrace, FaultPlan, make_availability)
from repro.fl.scheduler import (AsyncRunReport, EventLoop, FLScheduler,
                                UpdateRecord)
from repro.fl.server import FLServer, RoundReport, quorum_cutoff
from repro.fl.vertical import (SplitPlan, VerticalLive, VerticalStrategy,
                               bottom_fraction, sim_activation_nbytes)

__all__ = ["FLServer", "FLClient", "RoundReport", "fedavg",
           "fedavg_quantized", "staleness_weight", "quorum_cutoff",
           "FLScheduler", "EventLoop", "AsyncRunReport", "UpdateRecord",
           "AggregationStrategy", "FedBuffStrategy", "SemiSyncStrategy",
           "HierarchicalStrategy", "make_strategy", "AvailabilityTrace",
           "FaultPlan", "make_availability", "SplitPlan", "VerticalLive",
           "VerticalStrategy", "bottom_fraction", "sim_activation_nbytes"]
